"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Per (arch x shape x mesh): the three terms (compute / memory / collective,
seconds), the dominant bottleneck, MODEL_FLOPS = 6*N_active*D, the
useful-FLOPs ratio, and the roofline fraction. This is the §Roofline source
of truth for EXPERIMENTS.md."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import record


def load_records(dryrun_dir="results/dryrun", tag="baseline"):
    recs = []
    for f in sorted(Path(dryrun_dir).glob(f"{tag}_*.json")):
        r = json.loads(f.read_text())
        recs.append(r)
    return recs


def table(recs, mesh="single"):
    rows = []
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "skipped", "reason": r["reason"]})
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "error"})
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "useful_ratio": t.get("useful_flops_ratio"),
            "roofline_frac": t.get("roofline_fraction"),
            "mem_gib": r["memory"]["total_per_device_bytes"] / 2 ** 30,
        })
    return rows


def main():
    recs = load_records()
    rows = table(recs, "single")
    ok = [r for r in rows if r["status"] == "ok"]
    for r in ok:
        record(f"roofline/{r['arch']}/{r['shape']}",
               r[r['dominant']] * 1e6,
               f"dominant={r['dominant']};frac={r['roofline_frac']:.4f};"
               f"useful={r['useful_ratio']:.3f};mem={r['mem_gib']:.1f}GiB"
               if r["roofline_frac"] is not None else
               f"dominant={r['dominant']}")
    n_multi = sum(1 for r in recs
                  if r.get("mesh") == "multi" and r.get("status") == "ok")
    record("roofline/multi_pod_cells_ok", n_multi, "2x16x16 mesh compiles")
    return rows


if __name__ == "__main__":
    main()
