"""Superstep hot-path roofline: per-leg flops + bytes vs machine ceilings.

Walks the cost model's per-term raw ledger (``PlanCost.detail``) for one
modeled superstep — recv_groupby / join_compute / send / sender_combine /
connector / exchange — and reports each leg's flops and bytes on every
machine axis against the machine-model ceilings (peak_flops, hbm_bw,
link_bw, ...), for BOTH kernel implementations ("ref" jnp path vs
"pallas" kernel path) on BOTH machine models (the TPU-v5e default, where
"pallas" resolves to compiled pallas_tpu, and the emulated single-host
machine, where it stays in interpret mode and carries the interpreter
penalty). That is the quantitative version of the dispatch story: the
send leg's random-gather byte amplification turns into MXU matmul flops,
the sender-combine fold drops to a single streamed pass, and the fused
pack caps the connector at the bucket capacity.

A full run cross-checks the modeled totals against the trip-count-aware
HLO analyzer on a real lowered superstep (``hlo_calibrate``) for both
implementations; ``--smoke`` skips the compile-heavy cross-check.

Writes ``BENCH_roofline.json`` (schema ``roofline/v1``); ``--validate
PATH`` re-opens an artifact and checks the schema (the CI gate).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math

from benchmarks.common import record

SCHEMA = "roofline/v1"

# the per-leg ledger axes and the machine ceiling each one is priced at
AXES = (
    ("flops", "peak_flops"),
    ("hbm_bytes", "hbm_bw"),
    ("exchange_bytes", "link_bw"),
    ("host_bytes", "host_bw"),
    ("disk_bytes", "disk_bw"),
    ("serial_bytes", "host_mem_bw"),
)
IMPLS = ("ref", "pallas")
# the superstep legs the kernel dispatch actually touches
HOT_LEGS = ("send", "sender_combine", "connector")


def _algos(n_vertices: int):
    from repro.graph import SSSP, ConnectedComponents, PageRank
    return {
        "pagerank": PageRank(n_vertices, iterations=15),
        "sssp": SSSP(source=0),
        "cc": ConnectedComponents(),
    }


def _stats(smoke: bool):
    from repro.planner import GraphStats
    if smoke:
        return GraphStats(n_vertices=4_000, n_edges=24_000, n_partitions=4,
                          vertex_capacity=1_300, edge_capacity=7_200)
    # WEB-scale per-partition shapes (paper Table 1 ballpark, scaled to
    # one host): the analytic model is shape-linear, so the leg RATIOS —
    # which is what the roofline reads — are representative
    return GraphStats(n_vertices=130_000, n_edges=800_000, n_partitions=8,
                      vertex_capacity=16_250, edge_capacity=100_000)


def leg_rows(cost, machine) -> dict:
    """Per-leg roofline rows from a PlanCost's raw ledger."""
    m = dataclasses.asdict(machine)
    legs = {}
    for term, d in cost.detail.items():
        axis_s = {ax: d[ax] / m[ceil] for ax, ceil in AXES}
        bound = max(axis_s, key=axis_s.get)
        row = {ax: d[ax] for ax, _ in AXES}
        row["seconds"] = cost.terms.get(term, 0.0)
        row["bound"] = bound
        # classic roofline coordinates for the device legs: operational
        # intensity vs the attainable flop ceiling at that intensity
        if d["hbm_bytes"] > 0:
            oi = d["flops"] / d["hbm_bytes"]
            row["intensity_flop_per_byte"] = oi
            row["attainable_flops"] = min(machine.peak_flops,
                                          oi * machine.hbm_bw)
        legs[term] = row
    return legs


def model_superstep(program, g, machine, impl: str, *, join="full_outer"):
    """One modeled superstep for (machine, kernel impl): the plan, the
    resolved implementation, and the per-leg ledger."""
    from repro.core import PhysicalPlan
    from repro.kernels import backend as kbackend
    from repro.planner import Observation, estimate

    plan = PhysicalPlan(join=join, groupby="sort",
                        connector="partitioning", sender_combine=True,
                        kernel_impl=impl).validate(program.combine_op)
    cost = estimate(plan, g, Observation(frontier_density=1.0), machine)
    return {
        "impl": impl,
        "resolved": kbackend.resolve(impl, tpu=machine.mxu),
        "plan": dataclasses.asdict(plan),
        "legs": leg_rows(cost, machine),
        "totals": {
            "flops": cost.flops,
            "hbm_bytes": cost.bytes,
            "exchange_bytes": cost.exchange_bytes,
            "seconds": cost.seconds(machine),
        },
    }


def hlo_check(program, g, impls=IMPLS) -> list:
    """Ground-truth the modeled totals on a real lowered superstep: the
    trip-count-aware HLO analyzer over the CPU-lowered step for each
    kernel impl ("pallas" lowers the interpret-mode kernels — same
    dataflow shape the model prices for the emulated machine)."""
    from repro.core import PhysicalPlan
    from repro.planner import EMULATED_MACHINE, Observation, estimate
    from repro.planner.cost import hlo_calibrate

    out = []
    for impl in impls:
        plan = PhysicalPlan(join="full_outer", groupby="sort",
                            connector="partitioning", sender_combine=True,
                            kernel_impl=impl)
        meas = hlo_calibrate(program, plan, g)
        cost = estimate(plan, g, Observation(frontier_density=1.0),
                        EMULATED_MACHINE)
        P = max(g.n_partitions, 1)
        out.append({
            "impl": impl,
            "measured_flops_per_part": meas.flops / P,
            "measured_bytes_per_part": meas.bytes / P,
            "modeled_flops": cost.flops,
            "modeled_hbm_bytes": cost.bytes,
        })
    return out


def build(smoke: bool, algos=None, with_hlo=None) -> dict:
    from repro.planner import DEFAULT_MACHINE, EMULATED_MACHINE

    g = _stats(smoke)
    progs = _algos(g.n_vertices)
    names = list(algos) if algos else list(progs)
    machines = {"tpu-v5e": DEFAULT_MACHINE, "emulated": EMULATED_MACHINE}
    if with_hlo is None:
        with_hlo = not smoke

    results = []
    for name in names:
        program = progs[name]
        for mname, machine in machines.items():
            for impl in IMPLS:
                r = model_superstep(program, g, machine, impl)
                r["algo"] = name
                r["machine"] = mname
                results.append(r)

    art = {
        "schema": SCHEMA,
        "generated_by": "benchmarks/roofline.py",
        "smoke": bool(smoke),
        "graph": dataclasses.asdict(g),
        "machines": {k: dataclasses.asdict(m)
                     for k, m in machines.items()},
        "results": results,
        "hlo_check": (hlo_check(progs[names[0]], _stats(True))
                      if with_hlo else []),
    }
    return art


def console(art: dict):
    for r in art["results"]:
        tag = f"roofline/{r['algo']}/{r['machine']}/{r['impl']}"
        hot_s = sum(r["legs"][l]["seconds"] for l in HOT_LEGS
                    if l in r["legs"])
        bounds = ";".join(f"{l}={r['legs'][l]['bound']}"
                          for l in HOT_LEGS if l in r["legs"])
        record(tag, hot_s * 1e6, f"resolved={r['resolved']};{bounds}")
    for h in art["hlo_check"]:
        record(f"roofline/hlo_check/{h['impl']}",
               h["measured_bytes_per_part"] / 2 ** 20,
               f"model_bytes={h['modeled_hbm_bytes'] / 2 ** 20:.1f}MiB;"
               f"meas_flops={h['measured_flops_per_part']:.3g}")


def validate(art: dict) -> list:
    """Schema check for BENCH_roofline.json (the CI gate). Returns a list
    of human-readable problems; empty = valid."""
    errs = []
    if art.get("schema") != SCHEMA:
        errs.append(f"schema={art.get('schema')!r}, want {SCHEMA!r}")
    for key in ("graph", "machines", "results"):
        if not art.get(key):
            errs.append(f"missing/empty {key!r}")
    if errs:
        return errs
    for mname, m in art["machines"].items():
        for _, ceil in AXES:
            if not (isinstance(m.get(ceil), (int, float)) and m[ceil] > 0):
                errs.append(f"machines[{mname}].{ceil} not positive")
    seen = set()
    for i, r in enumerate(art["results"]):
        where = f"results[{i}]"
        for key in ("algo", "machine", "impl", "resolved", "plan",
                    "legs", "totals"):
            if key not in r:
                errs.append(f"{where} missing {key!r}")
        if not all(k in r for k in ("algo", "machine", "impl", "legs")):
            continue
        seen.add((r["machine"], r["impl"]))
        for leg in HOT_LEGS:
            if leg not in r["legs"]:
                errs.append(f"{where} missing hot leg {leg!r}")
        for lname, leg in r["legs"].items():
            for key in [ax for ax, _ in AXES] + ["seconds", "bound"]:
                if key not in leg:
                    errs.append(f"{where}.legs[{lname}] missing {key!r}")
                    continue
                v = leg[key]
                if key != "bound" and not (
                        isinstance(v, (int, float)) and
                        math.isfinite(v) and v >= 0):
                    errs.append(
                        f"{where}.legs[{lname}].{key}={v!r} not a "
                        "finite non-negative number")
    for machine in art["machines"]:
        for impl in IMPLS:
            if (machine, impl) not in seen:
                errs.append(f"no result for machine={machine!r} "
                            f"impl={impl!r}")
    return errs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, skip the HLO cross-check (CI)")
    ap.add_argument("--algos", nargs="*", default=None,
                    help="subset of pagerank/sssp/cc (default: all)")
    ap.add_argument("--hlo", dest="hlo", action="store_true", default=None,
                    help="force the lowered-superstep HLO cross-check "
                         "(default: on unless --smoke)")
    ap.add_argument("--out", default="BENCH_roofline.json")
    ap.add_argument("--validate", metavar="PATH", default=None,
                    help="validate an existing artifact and exit")
    args = ap.parse_args(argv)

    if args.validate:
        with open(args.validate) as f:
            art = json.load(f)
        errs = validate(art)
        if errs:
            for e in errs:
                print(f"INVALID: {e}")
            raise SystemExit(1)
        print(f"{args.validate}: valid {art['schema']} "
              f"({len(art['results'])} results, "
              f"{len(art['hlo_check'])} hlo checks)")
        return 0

    art = build(args.smoke, algos=args.algos, with_hlo=args.hlo)
    errs = validate(art)
    if errs:   # never ship an artifact the CI gate would reject
        raise SystemExit("generated artifact failed its own schema: "
                         + "; ".join(errs))
    console(art)
    with open(args.out, "w") as f:
        json.dump(art, f, indent=1)
    print(f"wrote {args.out} ({len(art['results'])} results)")
    return 0


if __name__ == "__main__":
    main()
