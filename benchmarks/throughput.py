"""Paper Figure 13: multi-job throughput (jobs/hour) vs concurrency level.
Jobs share the one device; the engine's bounded memory use is what lets
concurrent jobs coexist at all (the paper's point vs process-centric
systems that OOM)."""
from __future__ import annotations

import threading
import time

from repro.core import load_graph, run_jit
from repro.graph import PageRank, rmat_graph

from benchmarks.common import record


def _one_job(n, edges, out, i):
    prog = PageRank(n, iterations=6)
    vert = load_graph(edges, n, P=2, value_dims=2)
    res = run_jit(vert, prog, prog.suggested_plan, max_supersteps=8)
    out[i] = res.wall_s


def main(scale: int = 1):
    n = 8_000 * scale
    edges = rmat_graph(n, 8 * n, seed=7)
    results = {}
    # warm the compile cache so jph measures execution, as the paper does
    _one_job(n, edges, {}, 0)
    for conc in (1, 2, 3):
        t0 = time.time()
        outs = {}
        threads = [threading.Thread(target=_one_job,
                                    args=(n, edges, outs, i))
                   for i in range(conc)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.time() - t0
        jph = conc / wall * 3600
        results[conc] = jph
        record(f"throughput/concurrency_{conc}", wall * 1e6,
               f"jobs_per_hour={jph:.0f}")
    return results


if __name__ == "__main__":
    main()
