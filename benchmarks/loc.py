"""Paper Section 7.6 (software simplicity): LOC of the core engine vs the
reported Giraph-core 32,197 and Pregelix-core 8,514."""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import record

GIRAPH_CORE_LOC = 32_197
PREGELIX_CORE_LOC = 8_514


def _count(paths):
    n = 0
    for p in paths:
        for f in Path(p).rglob("*.py"):
            for line in f.read_text().splitlines():
                s = line.strip()
                if s and not s.startswith("#"):
                    n += 1
    return n


def main():
    root = Path(__file__).resolve().parent.parent / "src" / "repro"
    core = _count([root / "core", root / "graph", root / "runtime"])
    total = _count([root])
    record("loc/engine_core", core,
           f"giraph_core={GIRAPH_CORE_LOC};pregelix_core={PREGELIX_CORE_LOC}")
    record("loc/framework_total", total, "includes LM stack + kernels")
    return {"core": core, "total": total}


if __name__ == "__main__":
    main()
