"""Auto-plan vs the static plan space (paper Figures 14/15 regime).

For each algorithm the full static join x connector x sender-combine space
is run to find the best and worst static plans, then ``plan="auto"`` runs
against them. Expected: auto lands within 20% of the best static plan's
steady-state per-superstep time on all three algorithms — message-dense
PageRank stays on the full-outer join, SSSP on the high-diameter lattice
switches to left-outer mid-run, CC starts dense and collapses.

Reported per algorithm:
  steady        mean non-recompile superstep seconds (time_supersteps)
  auto_steady   the same for the auto run, after its last plan switch
                (the regime the planner converged to)
"""
from __future__ import annotations

from repro.core import PhysicalPlan, load_graph, run_host
from repro.graph import SSSP, ConnectedComponents, PageRank, rmat_graph, \
    uniform_graph
from repro.graph.generators import grid_graph
from repro.planner import plan_space

from benchmarks.common import record, time_supersteps


def _steady_after_last_switch(res):
    """Steady-state per-superstep seconds once the planner settled.
    Returns (seconds, note); the note flags degraded fallbacks so the
    acceptance metric is never silently computed on the wrong regime."""
    last = 0
    for s in res.stats:
        if s.get("event") == "plan-switch":
            last = s["superstep"]
    post = [s for s in res.stats if "wall_s" in s and s["superstep"] > last]
    walls = [s["wall_s"] for s in post if not s.get("recompiled", False)]
    if walls:
        return sum(walls) / len(walls), ""
    if post:   # only recompile-tainted supersteps after the switch
        return (sum(s["wall_s"] for s in post) / len(post),
                "fallback: post-switch walls include recompiles")
    return time_supersteps(res), "fallback: no post-switch supersteps"


def main(scale: int = 1):
    n = 8_000 * scale
    web = rmat_graph(n, 10 * n, seed=1)
    btc = uniform_graph(n, 4 * n, seed=2, undirected=True)
    side = int((6_000 * scale) ** 0.5)
    road = grid_graph(side)
    cases = [
        ("pagerank", lambda: PageRank(n, iterations=10), web, n, 2, 12),
        ("sssp", lambda: SSSP(source=0), road, side * side, 1,
         2 * side + 10),
        ("cc", lambda: ConnectedComponents(), btc, n, 1, 30),
    ]
    summary = {}
    for name, mk_prog, edges, nv, vd, max_ss in cases:
        static = {}   # key -> (steady seconds, plan)
        # groupby fixed to scatter: for named monoid combines the sort
        # group-by computes the same thing at strictly higher cost, so
        # the best/worst envelope is unaffected
        for plan in plan_space(mk_prog(), groupbys=("scatter",)):
            vert = load_graph(edges, nv, P=4, value_dims=vd)
            res = run_host(vert, mk_prog(), plan, max_supersteps=max_ss)
            t = time_supersteps(res)
            key = (f"{plan.join}/{plan.connector}/"
                   f"sc={int(plan.sender_combine)}")
            static[key] = (t, plan)
            record(f"planner/{name}/static/{key}", t * 1e6,
                   f"supersteps={res.supersteps}")
        vert = load_graph(edges, nv, P=4, value_dims=vd)
        res = run_host(vert, mk_prog(), "auto", max_supersteps=max_ss)
        t_auto = time_supersteps(res)
        t_auto_steady, steady_note = _steady_after_last_switch(res)
        switches = [s for s in res.stats
                    if s.get("event") == "plan-switch"]
        best_key = min(static, key=lambda k: static[k][0])
        worst_key = max(static, key=lambda k: static[k][0])
        worst = static[worst_key][0]
        # re-measure the best static plan ADJACENT to the auto run: wall
        # times drift over a long process (compile-cache and allocator
        # pressure), so the fair baseline is the fresher measurement
        vert = load_graph(edges, nv, P=4, value_dims=vd)
        rerun = run_host(vert, mk_prog(), static[best_key][1],
                         max_supersteps=max_ss)
        best = time_supersteps(rerun)
        record(f"planner/{name}/auto", t_auto * 1e6,
               f"switches={len(switches)} final={res.plan.join}")
        record(f"planner/{name}/auto_steady", t_auto_steady * 1e6,
               f"vs best {best_key}" +
               (f"; {steady_note}" if steady_note else ""))
        record(f"planner/{name}/auto_over_best",
               t_auto_steady / max(best, 1e-12) * 100,
               "x100; <=120 is within 20% of the best static plan")
        record(f"planner/{name}/worst_over_best",
               worst / max(best, 1e-12) * 100,
               f"x100; worst={worst_key}")
        summary[name] = {"best": best, "worst": worst, "auto": t_auto,
                         "auto_steady": t_auto_steady,
                         "switches": len(switches),
                         "final_plan": res.plan}
    ok = all(s["auto_steady"] <= 1.2 * s["best"] for s in summary.values())
    record("planner/auto_within_20pct_of_best_everywhere", float(ok),
           "1.0 = acceptance holds")
    return summary


if __name__ == "__main__":
    main()
