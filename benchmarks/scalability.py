"""Paper Figure 12: scaling with worker count, raced on the REAL sharded
driver (``core/sharded.py``) instead of the old emulated-only sweep: a
1-D host mesh of N devices runs the bucketed exchange as a tiled
all_to_all inside one shard_map'd superstep, so the curve measures the
actual multi-device hot path (this container has ONE core, so wall-clock
parallel speedup is bounded by the host; exchange-stall seconds and wire
bytes are the structural quantities that carry to a real mesh).

Writes the same ``BENCH_sharded.json`` schema as
``out_of_core.py --sharded`` (reuses its curve helper + validator), plus
a scale-up leg (graph grows with the mesh, Fig 12c) as extra records.
"""
from __future__ import annotations

import json
import os
import sys

# before the repro import chain pulls in jax: the race needs a
# multi-device host platform (same hack as out_of_core --sharded)
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from benchmarks.common import record, time_supersteps
from benchmarks.out_of_core import sharded_scaling, validate_sharded


def scaleup(scale: float, P: int = 8):
    """Scale-up shape (Fig 12c): graph grows proportionally to the mesh,
    per-superstep wall time should stay roughly flat on a real cluster."""
    import jax

    from repro.core import load_graph, run_sharded
    from repro.graph import PageRank, rmat_graph

    out = {}
    avail = len(jax.devices())
    base = max(int(12_000 * scale), 16 * P)
    for N in (1, 2, 4):
        if N > avail:
            break
        nk = base * N
        edges = rmat_graph(nk, 10 * nk, seed=6)
        vert = load_graph(edges, nk, P=P, value_dims=2)
        prog = PageRank(nk, iterations=6)
        res = run_sharded(vert, prog, prog.suggested_plan, devices=N,
                          max_supersteps=8)
        t = time_supersteps(res)
        out[str(N)] = {"devices": N, "n_vertices": nk, "wall_s": t}
        record(f"scale/scaleup/devices_{N}", t * 1e6, f"vertices={nk}")
    return out


def main(scale: float = 1.0, out_path: str = "BENCH_sharded.json"):
    payload = {"scale": scale, **sharded_scaling(scale)}
    payload["scaleup"] = scaleup(scale)
    validate_sharded(payload)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out_path}", flush=True)
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI")
    ap.add_argument("--out", default="BENCH_sharded.json")
    args = ap.parse_args()
    main(0.05 if args.smoke else args.scale, args.out)
