"""Paper Figure 12: scaling with worker count. This container has ONE core,
so wall-clock parallel speedup is not measurable; we report the structural
scaling quantities the paper discusses: per-superstep message volume and
exchanged bytes vs partition count (the combiner's falling effectiveness as
P grows — the cause of Fig 12a's gap to ideal), plus scale-up (graph grows
with P) superstep times."""
from __future__ import annotations

from repro.core import load_graph, run_host
from repro.graph import PageRank, rmat_graph

from benchmarks.common import record, time_supersteps


def main(scale: int = 1):
    n = 12_000 * scale
    edges = rmat_graph(n, 10 * n, seed=5)
    out = {}
    # speedup-shape: fixed graph, growing P -> message volume after
    # sender-combine grows (combiner less effective), as in Fig 12a
    for P in (1, 2, 4, 8):
        prog = PageRank(n, iterations=6)
        vert = load_graph(edges, n, P=P, value_dims=2)
        res = run_host(vert, prog, prog.suggested_plan, max_supersteps=8)
        msgs = max(s.get("messages", 0) for s in res.stats)
        out[("fixed", P)] = msgs
        record(f"scale/fixed_graph/P{P}", time_supersteps(res) * 1e6,
               f"peak_combined_msgs={msgs}")
    # scale-up: graph grows proportionally to P (Fig 12c)
    for k, P in ((1, 1), (2, 2), (4, 4)):
        nk = n * k
        ek = rmat_graph(nk, 10 * nk, seed=6)
        prog = PageRank(nk, iterations=6)
        vert = load_graph(ek, nk, P=P, value_dims=2)
        res = run_host(vert, prog, prog.suggested_plan, max_supersteps=8)
        out[("scaleup", P)] = time_supersteps(res)
        record(f"scale/scaleup/P{P}", time_supersteps(res) * 1e6,
               f"vertices={nk}")
    return out


if __name__ == "__main__":
    main()
