"""Paper Figure 10/11: overall and per-iteration execution time vs dataset
size (Webmap ladder for PageRank, BTC ladder for SSSP/CC)."""
from __future__ import annotations

from repro.core import load_graph, run_host
from repro.graph import DATASETS, SSSP, ConnectedComponents, PageRank

from benchmarks.common import record, time_supersteps

LADDERS = {
    "pagerank": ["webmap-tiny", "webmap-xsmall", "webmap-small"],
    "sssp": ["btc-tiny", "btc-xsmall", "btc-small"],
    "cc": ["btc-tiny", "btc-xsmall", "btc-small"],
}


def _prog(name, n):
    if name == "pagerank":
        return PageRank(n, iterations=8), 2
    if name == "sssp":
        return SSSP(source=0), 1
    return ConnectedComponents(), 1


def main(full: bool = False):
    out = {}
    for algo, ladder in LADDERS.items():
        if full:
            ladder = ladder + [ladder[-1].rsplit("-", 1)[0] + "-medium"]
        for ds in ladder:
            edges, n = DATASETS[ds]()
            prog, vd = _prog(algo, n)
            plan = prog.suggested_plan
            vert = load_graph(edges, n, P=4, value_dims=vd)
            res = run_host(vert, prog, plan, max_supersteps=30)
            per_it = time_supersteps(res)
            out[(algo, ds)] = (res.wall_s, per_it)
            record(f"exec_time/{algo}/{ds}", per_it * 1e6,
                   f"overall_s={res.wall_s:.2f};supersteps={res.supersteps};"
                   f"edges={len(edges)}")
    return out


if __name__ == "__main__":
    main()
