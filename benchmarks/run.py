"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--full`` runs the bigger
dataset ladders; default sizes finish on a single CPU core in ~10 minutes.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args()

    from benchmarks import (execution_time, groupby_strategies, loc,
                            out_of_core, plan_flexibility, roofline,
                            scalability, throughput)
    benches = {
        "loc": lambda: loc.main(),
        "roofline": lambda: roofline.main(),
        "plan_flexibility": lambda: plan_flexibility.main(),
        "groupby_strategies": lambda: groupby_strategies.main(),
        "execution_time": lambda: execution_time.main(full=args.full),
        "out_of_core": lambda: out_of_core.main(),
        "scalability": lambda: scalability.main(),
        "throughput": lambda: throughput.main(),
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
