"""Chaos/recovery benchmark: cost of surviving a seeded fault plan.

Runs each algorithm twice through the out-of-core driver — once clean,
once under a seeded chaos plan (transient disk reads retried by the I/O
ladder, one permanent page corruption that poisons the newest
checkpoint, and a WorkerFailure mid-run) with ``recover=True`` — and
reports the recovery story: whether the recovered run converged
BIT-FOR-BIT with the unfailed one (the paper's Section 5.7 claim), which
snapshot recovery restored, what the injector actually fired, and the
wall-clock overhead of failing + restoring + replaying.

Writes ``BENCH_faults.json`` (schema ``faults/v1``); ``--validate PATH``
re-opens an artifact and checks the schema — including that every
scenario's ``parity`` flag is True, so CI fails when a recovered run
diverges. ``--smoke`` uses test-sized graphs (the CI chaos job).
"""
from __future__ import annotations

import argparse
import json
import math
import time

SCHEMA = "faults/v1"

# one deterministic chaos plan for every scenario: the superstep-4 tick
# kills worker 1 right after the corruption lands in the newest
# checkpoint, so recovery must exercise the fail-over-to-previous rule
# AND the retry ladder (the restore reads pages through the transient
# spill.read faults)
def _chaos_plan():
    from repro.runtime import faults
    return faults.FaultPlan(seed=42, faults=[
        faults.FaultSpec(site="spill.read", kind="transient", times=2),
        faults.FaultSpec(site="page.corrupt", kind="corrupt", times=1,
                         match="inbox_dst_4"),
        faults.FaultSpec(site="superstep", kind="worker", superstep=4,
                         worker=1, match="ooc", times=1)])


def _algos(n_vertices: int):
    from repro.graph import SSSP, ConnectedComponents, PageRank
    return {
        "pagerank": PageRank(n_vertices, iterations=8),
        "sssp": SSSP(source=0),
        "cc": ConnectedComponents(),
    }


def _scenario(algo: str, prog, vert_fn, workdir, n_vertices: int) -> dict:
    import numpy as np

    from repro.core import gather_values
    from repro.core.ooc import run_out_of_core
    from repro.runtime import faults

    faults.clear()
    t0 = time.time()
    clean = run_out_of_core(vert_fn(), prog, prog.suggested_plan,
                            budget_partitions=2, max_supersteps=16,
                            disk_dir=str(workdir / f"{algo}_clean"))
    clean_wall = time.time() - t0

    faults.install(_chaos_plan())
    t0 = time.time()
    res = run_out_of_core(vert_fn(), prog, prog.suggested_plan,
                          budget_partitions=2, max_supersteps=16,
                          disk_dir=str(workdir / f"{algo}_chaos"),
                          checkpoint_every=1,
                          checkpoint_dir=str(workdir / f"{algo}_ckpt"),
                          recover=True)
    chaos_wall = time.time() - t0
    summary = faults.summary()
    faults.clear()

    a = gather_values(clean.vertex, n_vertices)[:, 0]
    b = gather_values(res.vertex, n_vertices)[:, 0]
    return {
        "algo": algo,
        "clean_wall_s": clean_wall,
        "chaos_wall_s": chaos_wall,
        "recovery_overhead": chaos_wall / clean_wall if clean_wall else 0.0,
        "parity": bool(np.array_equal(a, b)),
        "recovery": list(res.recovery),
        "injected": summary,
    }


def build(smoke: bool, algos=None) -> dict:
    import pathlib
    import tempfile

    from repro.graph import rmat_graph

    if smoke:
        n_vertices, n_edges = 120, 700
    else:
        n_vertices, n_edges = 4_000, 24_000

    from repro.core import load_graph
    edges = rmat_graph(n_vertices, n_edges, seed=3)

    progs = _algos(n_vertices)
    if algos:
        progs = {k: v for k, v in progs.items() if k in algos}

    results = []
    with tempfile.TemporaryDirectory(prefix="bench_faults_") as td:
        workdir = pathlib.Path(td)
        for name, prog in progs.items():
            results.append(_scenario(
                name, prog,
                lambda: load_graph(edges, n_vertices, P=4, value_dims=2),
                workdir, n_vertices))
    plan = _chaos_plan()
    return {
        "schema": SCHEMA,
        "smoke": bool(smoke),
        "n_vertices": n_vertices,
        "n_edges": n_edges,
        "plan": json.loads(plan.to_json()),
        "results": results,
    }


def validate(art: dict) -> list:
    """Schema gate for BENCH_faults.json. Empty list = valid."""
    errs = []
    if art.get("schema") != SCHEMA:
        errs.append(f"schema={art.get('schema')!r}, want {SCHEMA!r}")
    for key in ("smoke", "plan", "results"):
        if key not in art:
            errs.append(f"missing top-level {key!r}")
    if errs:
        return errs
    if not isinstance(art["results"], list) or not art["results"]:
        return ["results empty"]
    for i, r in enumerate(art["results"]):
        where = f"results[{i}]"
        for key in ("algo", "clean_wall_s", "chaos_wall_s",
                    "recovery_overhead", "parity", "recovery", "injected"):
            if key not in r:
                errs.append(f"{where} missing {key!r}")
        if r.get("parity") is not True:
            errs.append(f"{where}: recovered run diverged from the "
                        "unfailed run (parity != True)")
        if not r.get("recovery"):
            errs.append(f"{where}: no recovery event — the fault plan "
                        "never triggered the supervisor")
        for key in ("clean_wall_s", "chaos_wall_s", "recovery_overhead"):
            v = r.get(key)
            if key in r and not (isinstance(v, (int, float))
                                 and math.isfinite(v) and v >= 0):
                errs.append(f"{where}.{key}={v!r} not a finite "
                            "non-negative number")
        inj = r.get("injected") or {}
        fired = sum(s.get("fired", 0) for s in inj.get("specs", []))
        if "injected" in r and fired < 1:
            errs.append(f"{where}: injector reports zero fired faults")
    return errs


def console(art: dict):
    for r in art["results"]:
        ev = r["recovery"][0] if r["recovery"] else {}
        print(f"{r['algo']:>9}: parity={r['parity']} "
              f"overhead={r['recovery_overhead']:.2f}x "
              f"restored_from={ev.get('restored_from')} "
              f"blacklist={ev.get('blacklist')}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="test-sized graphs (CI chaos job)")
    ap.add_argument("--algos", nargs="*", default=None,
                    help="subset of pagerank/sssp/cc (default: all)")
    ap.add_argument("--out", default="BENCH_faults.json")
    ap.add_argument("--validate", metavar="PATH", default=None,
                    help="validate an existing artifact and exit")
    args = ap.parse_args(argv)

    if args.validate:
        with open(args.validate) as f:
            art = json.load(f)
        errs = validate(art)
        if errs:
            for e in errs:
                print(f"INVALID: {e}")
            raise SystemExit(1)
        print(f"{args.validate}: valid {art['schema']} "
              f"({len(art['results'])} scenarios, all parity)")
        return 0

    art = build(args.smoke, algos=args.algos)
    errs = validate(art)
    if errs:   # never ship an artifact the CI gate would reject
        raise SystemExit("generated artifact failed its own schema: "
                         + "; ".join(errs))
    console(art)
    with open(args.out, "w") as f:
        json.dump(art, f, indent=1)
    print(f"wrote {args.out} ({len(art['results'])} scenarios)")
    return 0


if __name__ == "__main__":
    main()
