"""Paper Figure 14/15: index full-outer join vs index left-outer join, per
algorithm. Expected (the paper's claims C1-C3):
  SSSP (message-sparse): left-outer much faster per iteration
  PageRank (message-dense): full-outer wins
  CC: starts dense, ends sparse -> the two plans land close
"""
from __future__ import annotations

from repro.core import PhysicalPlan, load_graph, run_host
from repro.graph import SSSP, ConnectedComponents, PageRank, rmat_graph, \
    uniform_graph
from repro.graph.generators import grid_graph

from benchmarks.common import record, time_supersteps


def main(scale: int = 1):
    n = 20_000 * scale
    web = rmat_graph(n, 12 * n, seed=1)
    btc = uniform_graph(n, 5 * n, seed=2, undirected=True)
    # SSSP runs on a high-diameter lattice (road-network regime, where the
    # paper reports the 15x left-outer win); small-world graphs saturate
    # the frontier in ~3 supersteps and neither plan can be sparse.
    side = int((9_000 * scale) ** 0.5)
    road = grid_graph(side)
    n_road = side * side
    cases = [
        ("sssp", SSSP(source=0), road, n_road, 1, 2 * side + 10),
        ("pagerank", PageRank(n, iterations=10), web, n, 2, 12),
        ("cc", ConnectedComponents(), btc, n, 1, 30),
    ]
    results = {}
    for name, prog, edges, nv, vd, max_ss in cases:
        for join in ("full_outer", "left_outer"):
            plan = PhysicalPlan(join=join, groupby="scatter",
                                sender_combine=True)
            vert = load_graph(edges, nv, P=4, value_dims=vd)
            res = run_host(vert, prog, plan, max_supersteps=max_ss)
            t = time_supersteps(res)
            results[(name, join)] = t
            record(f"plan_flex/{name}/{join}", t * 1e6,
                   f"supersteps={res.supersteps}")
    for name in ("sssp", "pagerank", "cc"):
        ratio = results[(name, "full_outer")] / \
            max(results[(name, "left_outer")], 1e-9)
        record(f"plan_flex/{name}/full_over_left", ratio * 100,
               "x100 ratio; >100 means left-outer faster")
    return results


if __name__ == "__main__":
    main()
