"""Paper Figure 7 + [13]: the four parallel group-by strategies
(sort/scatter x partitioning/merging connector) on PageRank."""
from __future__ import annotations

from repro.core import PhysicalPlan, load_graph, run_host
from repro.graph import PageRank, rmat_graph

from benchmarks.common import record, time_supersteps


def main(scale: int = 1):
    n = 20_000 * scale
    edges = rmat_graph(n, 12 * n, seed=3)
    out = {}
    for gb in ("scatter", "sort"):
        for conn in ("partitioning", "partitioning_merging"):
            plan = PhysicalPlan(join="full_outer", groupby=gb,
                                connector=conn, sender_combine=True)
            vert = load_graph(edges, n, P=4, value_dims=2)
            prog = PageRank(n, iterations=8)
            res = run_host(vert, prog, plan, max_supersteps=10)
            t = time_supersteps(res)
            out[(gb, conn)] = t
            record(f"groupby/{gb}/{conn}", t * 1e6,
                   f"supersteps={res.supersteps}")
    return out


if __name__ == "__main__":
    main()
