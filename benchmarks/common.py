"""Shared benchmark helpers."""
from __future__ import annotations

import time

import numpy as np

ROWS = []


def record(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_supersteps(run_result) -> float:
    """Mean steady-state per-superstep wall seconds: drops supersteps whose
    wall time includes a jit compile (first step, capacity regrows,
    frontier refits)."""
    walls = [s["wall_s"] for s in run_result.stats
             if "wall_s" in s and not s.get("recompiled", False)]
    if not walls:
        walls = [s["wall_s"] for s in run_result.stats if "wall_s" in s][1:]
    return float(np.mean(walls)) if walls else run_result.wall_s
