"""Paper Figure 10 (the headline claim): graceful in-memory -> out-of-core
degradation. We fix the graph and shrink the device-memory budget
(budget_partitions): in-memory (budget=P) vs increasingly streamed
executions. Process-centric systems fall off a cliff past ratio 1.0; an
out-of-core dataflow degrades with a gentle slope. Also measures the
delta-storage (LSM analogue) writeback savings."""
from __future__ import annotations

import dataclasses

from repro.core import PhysicalPlan, load_graph, run_host
from repro.core.ooc import run_out_of_core
from repro.graph import PageRank, rmat_graph

from benchmarks.common import record, time_supersteps


def main(scale: int = 1):
    n = 16_000 * scale
    P = 8
    edges = rmat_graph(n, 10 * n, seed=4)
    prog = PageRank(n, iterations=6)
    plan = prog.suggested_plan
    vert = load_graph(edges, n, P=P, value_dims=2)
    mem = run_host(vert, prog, plan, max_supersteps=8)
    t_mem = time_supersteps(mem)
    record("ooc/in_memory", t_mem * 1e6, "budget=all")
    out = {"in_memory": t_mem}
    for budget in (P, P // 2, P // 4, P // 8):
        vert2 = load_graph(edges, n, P=P, value_dims=2)
        res = run_out_of_core(vert2, prog, plan, budget_partitions=budget,
                              max_supersteps=8)
        t = time_supersteps(res)
        ratio = P / budget
        out[f"budget_1_{ratio:g}"] = t
        record(f"ooc/budget_ratio_{ratio:g}x", t * 1e6,
               f"slowdown_vs_mem={t / t_mem:.2f}")
    # delta vs full writeback (LSM analogue) on a sparse-update workload
    from repro.graph import SSSP
    sp = SSSP(source=0)
    for storage in ("inplace", "delta"):
        vert3 = load_graph(edges, n, P=P, value_dims=1)
        res = run_out_of_core(vert3, sp,
                              dataclasses.replace(plan, join="full_outer",
                                                  storage=storage),
                              budget_partitions=P // 2, max_supersteps=20)
        last = res.stats[-1]
        bytes_shipped = (last["delta_bytes"] if storage == "delta"
                         else last["full_bytes"])
        record(f"ooc/writeback_{storage}", bytes_shipped,
               "bytes shipped device->host")
    return out


if __name__ == "__main__":
    main()
