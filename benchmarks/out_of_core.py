"""Paper Figure 10 (the headline claim): graceful in-memory -> out-of-core
degradation, the streaming-vs-synchronous executor race, and the OOC
auto-planner race.

Part 1 fixes the graph and shrinks the device-memory budget
(budget_partitions): in-memory (budget=P) vs increasingly streamed
executions. Process-centric systems fall off a cliff past ratio 1.0; an
out-of-core dataflow degrades with a gentle slope. Also measures the
delta-storage (LSM analogue) writeback savings.

Part 2 races the PIPELINED streaming executor (``stream=True``: prefetch
the next super-partition's upload and drain the previous result while the
current one computes) against the synchronous loop across
PageRank / SSSP / CC and super-partition counts, reporting the speedup
and the dispatch / compute-wait / commit wall-time split.

Part 3 races ``plan="auto"`` against representative static plans OUT-OF-
CORE — the full join x group-by x connector x sender-combine x storage
space is searchable there — and reports auto's steady-state slowdown vs
the best static plan plus any mid-run connector/storage picks.

Part 5 (``pipeline_race`` -> ``BENCH_pipeline.json``) races the
BARRIER-FREE superstep pipeline against the PR-4 pipelined executor:
per-destination inbox readiness + the background page-I/O engine vs the
global inter-superstep barrier + synchronous page I/O, in DRAM and on
the disk tier, reporting wall times, readiness-stall seconds and I/O
queue-depth percentiles.

``--sharded`` (-> ``BENCH_sharded.json``) races the REAL multi-device
driver (``core/sharded.py``): the same fixed graph on a 1/2/4/8-device
host mesh, per-device-count wall time, exchange-stall seconds and
all_to_all wire bytes, plus the planner's predicted exchange seconds
(net axis, calibrated the way the adaptive controller does it: a
net_scale fit on the first half of the measured exchange stalls,
validated against the second half).

Everything lands in machine-readable ``BENCH_ooc.json`` (per-config
steady-state wall times, streaming speedups, picked plans) so CI can
archive the perf trajectory across PRs. ``--smoke`` runs a tiny config
(CI keeps the OOC path and the README examples honest without burning
minutes).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile

# must land before the repro import chain pulls in jax: the sharded race
# needs a multi-device host platform (same hack as launch/pregel_run)
if "--sharded" in sys.argv and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

from repro.core import PhysicalPlan, load_graph, run_host
from repro.core.ooc import run_out_of_core
from repro.graph import SSSP, ConnectedComponents, PageRank, rmat_graph
from repro.graph.generators import grid_graph

from benchmarks.common import record, time_supersteps


def budget_sweep(scale: float, P: int = 8):
    n = max(int(16_000 * scale), 16 * P)
    edges = rmat_graph(n, 10 * n, seed=4)
    prog = PageRank(n, iterations=6)
    plan = prog.suggested_plan
    vert = load_graph(edges, n, P=P, value_dims=2)
    mem = run_host(vert, prog, plan, max_supersteps=8)
    t_mem = time_supersteps(mem)
    record("ooc/in_memory", t_mem * 1e6, "budget=all")
    out = {"in_memory": t_mem}
    for budget in (P, P // 2, P // 4, P // 8):
        vert2 = load_graph(edges, n, P=P, value_dims=2)
        res = run_out_of_core(vert2, prog, plan, budget_partitions=budget,
                              max_supersteps=8)
        t = time_supersteps(res)
        ratio = P / budget
        out[f"budget_1_{ratio:g}"] = t
        record(f"ooc/budget_ratio_{ratio:g}x", t * 1e6,
               f"slowdown_vs_mem={t / t_mem:.2f}")
    # delta vs full writeback (LSM analogue) on a sparse-update workload
    sp = SSSP(source=0)
    out["writeback_bytes"] = {}
    for storage in ("inplace", "delta"):
        vert3 = load_graph(edges, n, P=P, value_dims=1)
        res = run_out_of_core(vert3, sp,
                              dataclasses.replace(plan, join="full_outer",
                                                  storage=storage),
                              budget_partitions=P // 2, max_supersteps=20)
        last = res.stats[-1]
        bytes_shipped = (last["delta_bytes"] if storage == "delta"
                         else last["full_bytes"])
        out["writeback_bytes"][storage] = bytes_shipped
        record(f"ooc/writeback_{storage}", bytes_shipped,
               "bytes shipped device->host")
    return out


def _io_split(res):
    """Steady-state per-superstep (dispatch, wait, commit) means."""
    recs = [s for s in res.stats
            if "wall_s" in s and not s.get("recompiled", False)]
    if not recs:
        recs = [s for s in res.stats if "wall_s" in s][1:]
    k = max(len(recs), 1)
    return {f: sum(s.get(f, 0.0) for s in recs) / k
            for f in ("dispatch_s", "collect_wait_s", "commit_s")}


def streaming_race(scale: float, P: int = 8):
    """The tentpole claim: the pipelined executor hides host<->device
    transfer behind compute, so per-superstep wall time approaches
    max(compute, transfer) instead of their sum."""
    n = max(int(16_000 * scale), 16 * P)
    workloads = [
        ("pagerank", PageRank(n, iterations=6), 2, 8,
         rmat_graph(n, 10 * n, seed=4), n),
        ("sssp", SSSP(source=0), 1, 12,
         rmat_graph(n, 10 * n, seed=4), n),
        ("cc", ConnectedComponents(), 1, 12,
         rmat_graph(n, 8 * n, seed=11), n),
    ]
    out = {}
    for name, prog, vd, ms, edges, nv in workloads:
        plan = dataclasses.replace(prog.suggested_plan, join="full_outer")
        per_budget = {}
        for budget in (P // 2, P // 4):
            n_sp = P // budget
            times = {}
            for mode, streaming in (("sync", False), ("stream", True)):
                vert = load_graph(edges, nv, P=P, value_dims=vd)
                res = run_out_of_core(vert, prog, plan,
                                      budget_partitions=budget,
                                      max_supersteps=ms,
                                      stream=streaming)
                times[mode] = time_supersteps(res)
                times[f"{mode}_io"] = _io_split(res)
            speedup = times["sync"] / max(times["stream"], 1e-12)
            per_budget[f"super_partitions_{n_sp}"] = {
                "sync_s": times["sync"], "stream_s": times["stream"],
                "speedup": speedup,
                "sync_io": times["sync_io"], "stream_io": times["stream_io"],
            }
            record(f"ooc/stream_{name}_sp{n_sp}", times["stream"] * 1e6,
                   f"sync={times['sync'] * 1e6:.1f}us,"
                   f"speedup={speedup:.2f}x")
        out[name] = per_budget
    best = max((cfg["speedup"] for w in out.values() for cfg in w.values()),
               default=0.0)
    out["best_speedup"] = best
    record("ooc/stream_best_speedup", best,
           "max streaming speedup over the synchronous loop")
    return out


def auto_race(scale: float, P: int = 8):
    """plan='auto' vs representative static plans, out-of-core."""
    n_pr = max(int(16_000 * scale), 16 * P)
    side = max(int(40 * scale ** 0.5), 12)
    workloads = [
        # message-dense, every value changes -> inplace/full_outer regime
        ("pagerank", PageRank(n_pr, iterations=6), 2, 8,
         rmat_graph(n_pr, 10 * n_pr, seed=4), n_pr),
        # high-diameter lattice: frontier + change density collapse ->
        # the left_outer + delta regime the planner must discover
        ("sssp_lattice", SSSP(source=0), 1, 100,
         grid_graph(side), side * side),
    ]
    out = {}
    for name, prog, vd, ms, edges, n in workloads:
        base = prog.suggested_plan
        statics = {
            "suggested": base,
            "merging": dataclasses.replace(
                base, connector="partitioning_merging"),
            "delta": dataclasses.replace(base, storage="delta"),
            "full_outer_inplace": dataclasses.replace(
                base, join="full_outer", storage="inplace"),
        }
        times = {}
        for cname, plan in statics.items():
            vert = load_graph(edges, n, P=P, value_dims=vd)
            res = run_out_of_core(vert, prog, plan,
                                  budget_partitions=P // 2,
                                  max_supersteps=ms)
            times[cname] = time_supersteps(res)
        vert = load_graph(edges, n, P=P, value_dims=vd)
        auto = run_out_of_core(vert, prog, "auto",
                               budget_partitions=P // 2, max_supersteps=ms)
        t_auto = time_supersteps(auto)
        best_name = min(times, key=times.get)
        best = times[best_name]
        switches = [s for s in auto.stats
                    if s.get("event") == "plan-switch"]
        picked_merging = (auto.plan.connector == "partitioning_merging" or
                          any(s.get("connector") == "partitioning_merging"
                              for s in switches))
        picked_delta = (auto.plan.storage == "delta" or
                        any(s.get("storage") == "delta" for s in switches))
        record(f"ooc/auto_{name}", t_auto * 1e6,
               f"vs_best_static({best_name})={t_auto / best:.2f},"
               f"switches={len(switches)},merging={picked_merging},"
               f"delta={picked_delta}")
        out[name] = {"auto": t_auto, "best_static": best,
                     "ratio": t_auto / best, "switches": len(switches),
                     "picked_merging": picked_merging,
                     "picked_delta": picked_delta,
                     "final_plan": dataclasses.asdict(auto.plan)}
    return out


def _tier_stats(res):
    """Mean pager hit rate + total spill traffic of one run."""
    recs = [s for s in res.stats if "cache_hit_rate" in s]
    if not recs:
        return {"hit_rate": 1.0, "spill_read_bytes": 0,
                "spill_write_bytes": 0}
    return {
        "hit_rate": sum(s["cache_hit_rate"] for s in recs) / len(recs),
        "spill_read_bytes": sum(s["spill_read_bytes"] for s in recs),
        "spill_write_bytes": sum(s["spill_write_bytes"] for s in recs),
    }


def disk_tier_race(scale: float, P: int = 8):
    """Part 4 (the disk-tier claim): the DRAM-only store vs the buffer
    cache spilling to disk under a tight memory budget, per eviction
    policy. The spill directory is a tmpdir torn down on exit — success
    OR failure — so CI never leaks page files. Writes the wall times,
    pager hit rates and spill traffic that BENCH_storage.json archives."""
    n = max(int(16_000 * scale), 16 * P)
    edges = rmat_graph(n, 10 * n, seed=4)
    prog = PageRank(n, iterations=6)
    plan = dataclasses.replace(prog.suggested_plan, join="full_outer")
    budget_parts = P // 2

    vert = load_graph(edges, n, P=P, value_dims=2)
    dram = run_out_of_core(vert, prog, plan,
                           budget_partitions=budget_parts,
                           max_supersteps=8)
    t_dram = time_supersteps(dram)
    record("storage/dram_only", t_dram * 1e6, "no disk tier")
    # size the DRAM budget to half the working set so the run must spill
    # (floor low enough that even the --smoke graph actually pages)
    working = sum(int(np.asarray(getattr(vert, k)).nbytes) for k in
                  ("vid", "halt", "value", "edge_src", "edge_dst",
                   "edge_val"))
    budget = max(working // 2, 96 * 1024)
    out = {"dram_only_s": t_dram, "working_set_bytes": working,
           "memory_budget_bytes": budget, "disk": {}}
    for policy in ("lru", "mru"):
        with tempfile.TemporaryDirectory(prefix="pregelix-spill-") as td:
            vert2 = load_graph(edges, n, P=P, value_dims=2)
            res = run_out_of_core(vert2, prog, plan,
                                  budget_partitions=budget_parts,
                                  max_supersteps=8,
                                  memory_budget_bytes=budget,
                                  disk_dir=td, eviction=policy)
            t = time_supersteps(res)
            tier = _tier_stats(res)
            out["disk"][policy] = {
                "wall_s": t, "slowdown_vs_dram": t / max(t_dram, 1e-12),
                **tier}
            record(f"storage/disk_{policy}", t * 1e6,
                   f"hit_rate={tier['hit_rate']:.2f},"
                   f"slowdown={t / max(t_dram, 1e-12):.2f}x")
    return out


def _stall_stats(res):
    """Total + steady-state-mean readiness stall (the device-idle gap
    between a superstep's last collect and the next superstep's first
    dispatch — what the barrier-free pipeline minimizes)."""
    recs = [s for s in res.stats if "readiness_stall_s" in s]
    steady = [s for s in recs if not s.get("recompiled", False)] or recs[1:]
    return {
        "total_s": sum(s["readiness_stall_s"] for s in recs),
        "steady_mean_s": (sum(s["readiness_stall_s"] for s in steady)
                          / max(len(steady), 1)),
    }


def _queue_depth_percentiles(res):
    """I/O queue-depth distribution of a run. Since PR 6 every superstep
    record carries real within-superstep percentiles
    (``io_queue_depth_p50/p90/max`` from the engine's depth histogram);
    report their run-level mean/max. Falls back to percentiles of the
    per-superstep peaks for runs without the engine histogram."""
    recs = [s for s in res.stats
            if "wall_s" in s and "io_queue_depth_p90" in s]
    if recs:
        k = len(recs)
        return {
            "p50": sum(s["io_queue_depth_p50"] for s in recs) / k,
            "p90": sum(s["io_queue_depth_p90"] for s in recs) / k,
            "max": max(s["io_queue_depth_max"] for s in recs),
        }
    depths = sorted(s.get("io_queue_depth", 0) for s in res.stats
                    if "wall_s" in s)
    if not depths:
        return {"p50": 0, "p90": 0, "max": 0}
    pick = lambda f: depths[min(int(f * (len(depths) - 1)), len(depths) - 1)]
    return {"p50": pick(0.5), "p90": pick(0.9), "max": depths[-1]}


def pipeline_race(scale: float, P: int = 8):
    """The PR-5 tentpole claim: removing the inter-superstep barrier
    (per-destination inbox readiness) and moving disk I/O to the
    background engine shortens the serial leg of every superstep.
    Races the PR-4 pipelined executor (stream=True, barrier_free=False)
    against the barrier-free one, in DRAM and on the disk tier (with
    and without the I/O engine), reporting wall times, readiness-stall
    seconds and I/O queue-depth percentiles for BENCH_pipeline.json."""
    n = max(int(64_000 * scale), 24 * P)
    edges = rmat_graph(n, 10 * n, seed=4)
    prog_of = lambda: PageRank(n, iterations=8)
    plan = dataclasses.replace(prog_of().suggested_plan, join="full_outer")
    budget_parts = P // 4 if P >= 4 else 1
    ms = 10

    def leg(name, **kw):
        vert = load_graph(edges, n, P=P, value_dims=2)
        res = run_out_of_core(vert, prog_of(), plan,
                              budget_partitions=budget_parts,
                              max_supersteps=ms, stream=True,
                              prefetch_depth=3, **kw)
        out = {"wall_s": time_supersteps(res),
               "readiness_stall": _stall_stats(res),
               "io_queue_depth": _queue_depth_percentiles(res)}
        record(f"pipeline/{name}", out["wall_s"] * 1e6,
               f"stall={out['readiness_stall']['steady_mean_s'] * 1e6:.1f}"
               f"us/superstep")
        return out

    out = {"n_vertices": n, "super_partitions": P // budget_parts}
    # DRAM tier: isolates the barrier removal alone. Compute dominates
    # here, so the win is the (small) serial rebuild share.
    out["dram"] = {
        "barrier": leg("dram_barrier", barrier_free=False),
        "barrier_free": leg("dram_barrier_free", barrier_free=True),
    }
    out["dram"]["speedup"] = (
        out["dram"]["barrier"]["wall_s"]
        / max(out["dram"]["barrier_free"]["wall_s"], 1e-12))
    record("pipeline/dram_speedup", out["dram"]["speedup"],
           "barrier removal alone (DRAM tier)")
    # DISK tier — the headline race: the PR-4 pipelined executor
    # (global barrier + synchronous page I/O on the dispatcher/collector
    # thread) vs this PR's executor (per-destination readiness + the
    # background I/O engine), under real paging pressure. This is where
    # the two serialization points the PR removes actually bind.
    vert = load_graph(edges, n, P=P, value_dims=2)
    working = sum(int(np.asarray(getattr(vert, k)).nbytes) for k in
                  ("vid", "halt", "value", "edge_src", "edge_dst",
                   "edge_val"))
    budget = max(working // 2, 96 * 1024)
    del vert
    out["disk"] = {"memory_budget_bytes": budget}
    for name, kw in (
            ("barrier_sync_io", dict(barrier_free=False, io_threads=0)),
            ("barrier_free_sync_io", dict(barrier_free=True,
                                          io_threads=0)),
            ("barrier_free_engine", dict(barrier_free=True,
                                         io_threads=1)),
    ):
        with tempfile.TemporaryDirectory(prefix="pregelix-pipe-") as td:
            out["disk"][name] = leg(
                f"disk_{name}", memory_budget_bytes=budget, disk_dir=td,
                eviction="mru", **kw)
    out["disk"]["speedup"] = (
        out["disk"]["barrier_sync_io"]["wall_s"]
        / max(out["disk"]["barrier_free_engine"]["wall_s"], 1e-12))
    out["speedup"] = out["disk"]["speedup"]
    # steady-state means, NOT totals: the first superstep's stall is
    # dominated by the jit compile, which both legs pay equally and
    # which would wash the ratio out to ~1
    out["stall_reduction"] = (
        out["disk"]["barrier_sync_io"]["readiness_stall"]["steady_mean_s"]
        / max(out["disk"]["barrier_free_sync_io"]["readiness_stall"]
              ["steady_mean_s"], 1e-12))
    record("pipeline/speedup", out["speedup"],
           "barrier-free + io engine vs the PR-4 executor "
           "(barrier + sync page io, disk tier)")
    return out


def trace_capture(scale: float, trace_out: str, P: int = 8,
                  report_out: str = None):
    """Traced disk-tier run -> Chrome trace-event JSON artifact.

    A DEDICATED run, separate from every timed leg, so span recording
    never skews the BENCH numbers. Barrier-free pipeline on the disk
    tier with TWO I/O-engine workers and a tight DRAM budget: the trace
    must show the dispatcher/collector main thread plus both
    ``pregelix-io-*`` workers (>= 3 OS threads) with fault / readahead /
    writeback spans overlapping compute and the readiness-stall gap.
    CI validates the artifact with ``python -m repro.obs.export``.

    With ``report_out`` the SAME run also feeds the plan-audit ledger
    and the memory watcher, and a ``pregelix-run-report/v1`` JSON lands
    there — validated with ``python -m repro.obs.report --validate``."""
    from repro.obs import (explain, memwatch, report, trace,
                           write_chrome_trace)
    n = max(int(16_000 * scale), 16 * P)
    edges = rmat_graph(n, 10 * n, seed=4)
    prog = PageRank(n, iterations=6)
    plan = dataclasses.replace(prog.suggested_plan, join="full_outer")
    vert = load_graph(edges, n, P=P, value_dims=2)
    working = sum(int(np.asarray(getattr(vert, k)).nbytes) for k in
                  ("vid", "halt", "value", "edge_src", "edge_dst",
                   "edge_val"))
    # quarter-of-working-set budget: enough paging pressure that the
    # engine's fault/readahead/writeback spans actually appear
    budget = max(working // 4, 64 * 1024)
    trace.start()
    if report_out:
        explain.start()
        memwatch.start()
    res = None
    try:
        with tempfile.TemporaryDirectory(prefix="pregelix-trace-") as td:
            res = run_out_of_core(vert, prog, plan,
                                  budget_partitions=max(P // 4, 1),
                                  max_supersteps=6, stream=True,
                                  barrier_free=True,
                                  memory_budget_bytes=budget,
                                  disk_dir=td,
                                  eviction="mru", io_threads=2)
    finally:
        tracer = trace.stop()
        aud = explain.stop() if report_out else None
        mem = memwatch.stop() if report_out else None
    summary = write_chrome_trace(trace_out, tracer)
    record("obs/trace_spans", summary["spans"],
           f"threads={summary['span_threads']},"
           f"cats={','.join(sorted(summary['categories']))}")
    if report_out and res is not None:
        rep = report.build_report(
            stats=res.stats, explain=aud, memwatch=mem,
            meta={"bench": "trace_capture", "scale": scale,
                  "n_vertices": n, "parts": P,
                  "memory_budget_bytes": budget,
                  "supersteps": res.supersteps,
                  "wall_s": res.wall_s})
        report.write_report(report_out, rep)
        errs = report.validate_report(rep)
        if errs:
            raise SystemExit(f"{report_out}: {len(errs)} schema "
                             f"violation(s): {errs}")
        record("obs/report_supersteps", len(rep["supersteps"]),
               f"mean_drift={rep['summary']['mean_drift']:.3f}")
    return summary


def _steady_exchange(res):
    """Per-superstep (stall_s, bytes) lists, recompile steps dropped —
    same steady-state policy as time_supersteps."""
    recs = [s for s in res.stats
            if "wall_s" in s and not s.get("recompiled", False)]
    if not recs:
        recs = [s for s in res.stats if "wall_s" in s][1:]
    return ([float(s.get("exchange_stall_s", 0.0)) for s in recs],
            [int(s.get("exchange_bytes", 0)) for s in recs])


def sharded_scaling(scale: float, P: int = 8,
                    device_counts=(1, 2, 4, 8)):
    """The ISSUE-8 tentpole curve: the SAME graph raced across mesh
    sizes on the real sharded driver (``run_sharded``: all_to_all
    exchange inside one shard_map'd superstep). Per device count:
    steady-state wall seconds, exchange-stall seconds, all_to_all wire
    bytes, and the planner's predicted exchange seconds — net_scale fit
    on the FIRST half of the measured stalls (the controller's clamp,
    [0.125, 8]), checked against the SECOND half so 'predicted within 2x
    of measured' is a held-out claim, not a tautology."""
    import jax

    from repro.core import run_sharded
    from repro.planner.cost import (EMULATED_MACHINE, GraphStats,
                                    Observation, estimate)

    n = max(int(16_000 * scale), 16 * P)
    edges = rmat_graph(n, 10 * n, seed=4)
    prog = PageRank(n, iterations=6)
    plan = prog.suggested_plan
    avail = len(jax.devices())
    counts = [d for d in device_counts if d <= avail and P % d == 0]
    out = {"n_vertices": n, "P": P, "devices_available": avail,
           "curve": {}}
    g = None
    for N in counts:
        vert = load_graph(edges, n, P=P, value_dims=2)
        if g is None:
            g = GraphStats(
                n_vertices=n,
                n_edges=int((np.asarray(vert.edge_src) >= 0).sum()),
                n_partitions=P,
                vertex_capacity=int(vert.vid.shape[1]),
                edge_capacity=int(vert.edge_src.shape[1]),
                value_dims=prog.value_dims, msg_dims=prog.msg_dims)
        res = run_sharded(vert, prog, plan, devices=N, max_supersteps=8)
        wall = time_supersteps(res)
        stalls, xbytes = _steady_exchange(res)
        mean_stall = float(np.mean(stalls)) if stalls else 0.0
        row = {"devices": N, "wall_s": wall,
               "supersteps": res.supersteps,
               "exchange_stall_s": float(np.sum(stalls)),
               "exchange_stall_mean_s": mean_stall,
               "exchange_bytes": int(np.sum(xbytes))}
        # planner's exchange prediction (net axis) vs the measured span
        obs = Observation(frontier_density=1.0, sharded=N > 1,
                          n_workers=N)
        analytic = estimate(plan, g, obs, EMULATED_MACHINE).net_seconds
        row["analytic_exchange_s"] = analytic
        if N > 1 and analytic > 0 and len(stalls) >= 2:
            half = max(len(stalls) // 2, 1)
            fit = float(np.clip(np.mean(stalls[:half]) / analytic,
                                0.125, 8.0))
            held_out = float(np.mean(stalls[half:]) or mean_stall)
            predicted = analytic * fit
            ratio = predicted / max(held_out, 1e-12)
            row.update(net_scale_fit=fit, predicted_exchange_s=predicted,
                       predicted_over_measured=ratio,
                       within_2x=bool(0.5 <= ratio <= 2.0))
        else:
            row.update(net_scale_fit=1.0, predicted_exchange_s=analytic,
                       predicted_over_measured=None, within_2x=None)
        out["curve"][str(N)] = row
        record(f"sharded/devices_{N}", wall * 1e6,
               f"exchange_stall_s={row['exchange_stall_s']:.4f},"
               f"exchange_MiB={row['exchange_bytes'] / 2**20:.2f}")
    return out


def validate_sharded(payload: dict) -> bool:
    """Schema check for BENCH_sharded.json (CI gate; scalability.py
    reuses it). Raises SystemExit on a malformed artifact."""
    curve = payload.get("curve")
    if not isinstance(curve, dict) or not curve:
        raise SystemExit("BENCH_sharded.json: missing/empty 'curve'")
    need = ("devices", "wall_s", "supersteps", "exchange_stall_s",
            "exchange_bytes", "analytic_exchange_s",
            "predicted_exchange_s")
    for key, row in curve.items():
        for f in need:
            if f not in row:
                raise SystemExit(
                    f"BENCH_sharded.json: curve[{key}] missing '{f}'")
        if not row["wall_s"] > 0:
            raise SystemExit(
                f"BENCH_sharded.json: curve[{key}] wall_s <= 0")
        if row["devices"] > 1 and not row["exchange_bytes"] > 0:
            raise SystemExit(
                f"BENCH_sharded.json: curve[{key}] has {row['devices']} "
                "workers but zero all_to_all wire bytes")
    multi = [r for r in curve.values()
             if r["devices"] > 1 and r.get("within_2x") is not None]
    if multi:
        ok = sum(1 for r in multi if r["within_2x"])
        print(f"sharded: predicted exchange within 2x of measured for "
              f"{ok}/{len(multi)} multi-device points", flush=True)
    return True


def main(scale: float = 1.0, out_path: str = "BENCH_ooc.json",
         disk: bool = False, storage_out: str = "BENCH_storage.json",
         pipeline_out: str = "BENCH_pipeline.json",
         trace_out: str = "BENCH_trace.json",
         sharded: bool = False, sharded_out: str = "BENCH_sharded.json",
         report_out: str = "BENCH_report.json"):
    if sharded:
        sh = {"scale": scale, **sharded_scaling(scale)}
        validate_sharded(sh)
        with open(sharded_out, "w") as f:
            json.dump(sh, f, indent=1)
        walls = {r["devices"]: r["wall_s"] for r in sh["curve"].values()}
        print(f"wrote {sharded_out} (device counts {sorted(walls)}, "
              f"wall_s {', '.join(f'{walls[d]:.4f}' for d in sorted(walls))})",
              flush=True)
        return sh
    out = {"scale": scale}
    out["budget_sweep"] = budget_sweep(scale)
    out["streaming"] = streaming_race(scale)
    out["auto"] = auto_race(scale)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {out_path} (best streaming speedup "
          f"{out['streaming']['best_speedup']:.2f}x)", flush=True)
    pipe = {"scale": scale, "pipeline": pipeline_race(scale)}
    with open(pipeline_out, "w") as f:
        json.dump(pipe, f, indent=1)
    print(f"wrote {pipeline_out} (barrier-free speedup "
          f"{pipe['pipeline']['speedup']:.2f}x, stall reduction "
          f"{pipe['pipeline']['stall_reduction']:.1f}x)", flush=True)
    if disk:
        st = {"scale": scale, "disk_tier": disk_tier_race(scale)}
        with open(storage_out, "w") as f:
            json.dump(st, f, indent=1)
        hit = max(v["hit_rate"] for v in st["disk_tier"]["disk"].values())
        print(f"wrote {storage_out} (best disk-tier hit rate "
              f"{hit:.2f})", flush=True)
        ts = trace_capture(scale, trace_out, report_out=report_out)
        print(f"wrote {trace_out} ({ts['spans']} spans on "
              f"{ts['span_threads']} threads, categories "
              f"{','.join(sorted(ts['categories']))})", flush=True)
        if report_out:
            print(f"wrote {report_out} (plan-audit + memory-pressure "
                  f"run report from the traced run)", flush=True)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default="BENCH_ooc.json",
                    help="machine-readable results (CI uploads this)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (graph ~800 vertices)")
    ap.add_argument("--disk", action="store_true",
                    help="also race the disk tier (tmpdir spill dir, "
                         "cleaned up even on failure) and write "
                         "--storage-out")
    ap.add_argument("--storage-out", default="BENCH_storage.json",
                    help="disk-tier results (CI uploads this)")
    ap.add_argument("--pipeline-out", default="BENCH_pipeline.json",
                    help="barrier-free vs barrier pipeline race results "
                         "(wall times, readiness-stall seconds, I/O "
                         "queue-depth percentiles; CI uploads this)")
    ap.add_argument("--trace-out", default="BENCH_trace.json",
                    help="Chrome trace-event JSON from a dedicated "
                         "traced disk-tier run (with --disk; CI "
                         "validates and uploads this)")
    ap.add_argument("--sharded", action="store_true",
                    help="race ONLY the multi-device sharded driver "
                         "across 1/2/4/8 host devices and write "
                         "--sharded-out (sets XLA_FLAGS pre-import)")
    ap.add_argument("--sharded-out", default="BENCH_sharded.json",
                    help="sharded scaling curve (CI uploads this)")
    ap.add_argument("--report-out", default="BENCH_report.json",
                    help="pregelix-run-report/v1 JSON from the traced "
                         "disk-tier run (with --disk): plan-audit "
                         "ledger + memory-pressure peaks; CI validates "
                         "with python -m repro.obs.report and uploads "
                         "this. Empty string disables")
    ap.add_argument("--validate-sharded", metavar="PATH", default=None,
                    help="validate an existing BENCH_sharded.json and "
                         "exit (CI gate)")
    args = ap.parse_args()
    if args.validate_sharded:
        with open(args.validate_sharded) as f:
            validate_sharded(json.load(f))
        print(f"{args.validate_sharded}: ok", flush=True)
        raise SystemExit(0)
    main(0.05 if args.smoke else args.scale, args.out,
         disk=args.disk, storage_out=args.storage_out,
         pipeline_out=args.pipeline_out, trace_out=args.trace_out,
         sharded=args.sharded, sharded_out=args.sharded_out,
         report_out=args.report_out)
