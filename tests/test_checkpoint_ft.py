"""Fault tolerance: checkpoint/restore equivalence, ELASTIC recovery onto a
different partition count, failure-manager blacklisting, and out-of-core
equivalence (paper Sections 5.4/5.5)."""
import numpy as np
import pytest

from repro.core import (PhysicalPlan, gather_values, load_graph, run_host)
from repro.core.ooc import run_out_of_core
from repro.graph import PageRank, SSSP, rmat_graph
from repro.runtime import (FailureManager, WorkerFailure, latest_checkpoint,
                           load_checkpoint, repartition, save_checkpoint)

N = 240
EDGES = rmat_graph(N, 1400, seed=31)


def _final_ranks(vert_result):
    return gather_values(vert_result, N)[:, 0]


def test_checkpoint_restore_identical(tmp_path):
    pr = PageRank(N, iterations=8)
    vert = load_graph(EDGES, N, P=4, value_dims=2)
    full = run_host(vert, pr, pr.suggested_plan, max_supersteps=10,
                    checkpoint_every=3, checkpoint_dir=str(tmp_path))
    ref = _final_ranks(full.vertex)
    # restart from the superstep-3 checkpoint and finish
    path = str(tmp_path / "ckpt_000003.npz")
    v, m, gs = load_checkpoint(path)
    assert int(gs.superstep) == 3
    from repro.core.driver import default_engine_config
    import dataclasses, jax
    from repro.core import make_superstep, init_gs
    ec = default_engine_config(v, pr, pr.suggested_plan)
    # the checkpointed Msg capacity fixes bucket_cap: derive it back
    ec = dataclasses.replace(ec, bucket_cap=m.capacity // ec.n_parts)
    step = jax.jit(make_superstep(pr, pr.suggested_plan, ec))
    for _ in range(10):
        if bool(gs.halt):
            break
        v, m, gs = step(v, m, gs)
    assert np.allclose(_final_ranks(v), ref, atol=1e-6)


def test_elastic_repartition(tmp_path):
    """Recovery onto FEWER workers (blacklisted node): P=4 -> P=3."""
    pr = PageRank(N, iterations=8)
    vert = load_graph(EDGES, N, P=4, value_dims=2)
    full = run_host(vert, pr, pr.suggested_plan, max_supersteps=10,
                    checkpoint_every=3, checkpoint_dir=str(tmp_path))
    ref = _final_ranks(full.vertex)
    v, m, gs = load_checkpoint(latest_checkpoint(str(tmp_path)))
    v3, m3 = repartition(v, m, new_P=3)
    assert v3.vid.shape[0] == 3
    import jax
    from repro.core import make_superstep
    from repro.core.driver import default_engine_config
    import dataclasses
    ec = default_engine_config(v3, pr, pr.suggested_plan)
    ec = dataclasses.replace(ec, bucket_cap=max(
        ec.bucket_cap, m3.capacity // ec.n_parts + 1))
    # re-bucket restored messages to the new capacity layout
    from repro.core.driver import _regrow_msgs
    m3 = _regrow_msgs(m3, ec) if m3.capacity < ec.n_parts * ec.bucket_cap \
        else m3
    ec = dataclasses.replace(ec, bucket_cap=m3.capacity // ec.n_parts)
    step = jax.jit(make_superstep(pr, pr.suggested_plan, ec))
    for _ in range(10):
        if bool(gs.halt):
            break
        v3, m3, gs = step(v3, m3, gs)
    assert np.allclose(_final_ranks(v3), ref, atol=1e-6)


def test_failure_manager_blacklist_and_recovery(tmp_path):
    fm = FailureManager(n_workers=4)
    calls = {"n": 0}

    def run_fn(n_workers):
        calls["n"] += 1
        if calls["n"] == 1:
            raise WorkerFailure(worker=2, msg="powered off")
        assert n_workers == 3
        return "done"

    restored = {}

    def restore_fn(n_workers):
        restored["n"] = n_workers

    assert fm.run_with_recovery(run_fn, restore_fn) == "done"
    assert fm.blacklist == {2}
    assert restored["n"] == 3


def test_application_errors_forwarded():
    fm = FailureManager(n_workers=2)

    def run_fn(n):
        raise ValueError("user bug")

    with pytest.raises(ValueError):
        fm.run_with_recovery(run_fn, lambda n: None)
    assert not fm.events[0]["recoverable"]


def test_out_of_core_equivalence():
    pr = PageRank(N, iterations=6)
    vert = load_graph(EDGES, N, P=4, value_dims=2)
    ref = run_host(vert, pr, pr.suggested_plan, max_supersteps=8)
    vert2 = load_graph(EDGES, N, P=4, value_dims=2)
    ooc = run_out_of_core(vert2, pr, pr.suggested_plan,
                          budget_partitions=1, max_supersteps=8)
    assert np.allclose(_final_ranks(ref.vertex), _final_ranks(ooc.vertex),
                       atol=1e-6)


def test_ooc_delta_storage_ships_fewer_bytes():
    """LSM/delta analogue: sparse-update workloads ship only changed rows
    back to the host."""
    sp = SSSP(source=0)
    plan_full = PhysicalPlan(join="full_outer", storage="inplace")
    plan_delta = PhysicalPlan(join="full_outer", storage="delta")
    v1 = load_graph(EDGES, N, P=4, value_dims=1)
    r_full = run_out_of_core(v1, sp, plan_full, budget_partitions=2,
                             max_supersteps=20)
    v2 = load_graph(EDGES, N, P=4, value_dims=1)
    r_delta = run_out_of_core(v2, sp, plan_delta, budget_partitions=2,
                              max_supersteps=20)
    assert np.allclose(_final_ranks(r_full.vertex),
                       _final_ranks(r_delta.vertex))
    assert r_delta.stats[-1]["delta_bytes"] < \
        r_full.stats[-1]["full_bytes"] * 0.5
