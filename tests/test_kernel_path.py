"""Kernel-path dispatch + bit-for-bit parity suite.

The tentpole invariant: routing the superstep hot path through the
Pallas kernels (``kernel_impl="pallas"`` — interpret mode on CPU, the
bit-for-bit-testable emulator) produces EXACTLY the results of the jnp
reference path (``kernel_impl="ref"``), across algorithms x joins x
connectors x drivers (host loop / whole-loop jit / out-of-core,
including a disk-tier run). Not allclose — ``np.array_equal``: both
paths execute the same blocked reduction order for the sender fold, and
the gather's one-hot matmul is exact for finite floats (non-finites ride
a class channel).

Plus the dispatch layer itself (``kernels/backend.resolve`` matrix and
the ``REPRO_KERNEL_IMPL`` env override), the planner's pricing of the
kernel path, and the fused combine->pack leg's HLO evidence: the
lowered fused leg moves strictly fewer bytes because the intermediate
edge-payload relation is never re-materialized.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PhysicalPlan, gather_values, load_graph, run_host,
                        run_jit)
from repro.core.ooc import run_out_of_core
from repro.graph import SSSP, ConnectedComponents, PageRank, rmat_graph
from repro.kernels import backend as kbackend

N = 220
EDGES = rmat_graph(N, 1200, seed=7)
ALGOS = {
    "pagerank": (lambda: PageRank(N, iterations=6), 2),
    "sssp": (lambda: SSSP(source=3), 1),
    "cc": (lambda: ConnectedComponents(), 1),
}
JOINS = ("full_outer", "left_outer")
CONNECTORS = ("partitioning", "partitioning_merging")

_REF = {}   # (algo, join, connector) -> gathered values, kernel_impl="ref"


def _plan(algo, join, connector, impl):
    mk, _ = ALGOS[algo]
    return dataclasses.replace(mk().suggested_plan, join=join,
                               connector=connector, kernel_impl=impl)


def _run_host(algo, join, connector, impl):
    mk, vd = ALGOS[algo]
    vert = load_graph(EDGES, N, P=4, value_dims=vd)
    res = run_host(vert, mk(), _plan(algo, join, connector, impl),
                   max_supersteps=30)
    return gather_values(res.vertex, N)


def _ref(algo, join, connector):
    key = (algo, join, connector)
    if key not in _REF:
        _REF[key] = _run_host(algo, join, connector, "ref")
    return _REF[key]


# ---------------------------------------------------------------- drivers

@pytest.mark.parametrize("algo", list(ALGOS))
@pytest.mark.parametrize("join", JOINS)
@pytest.mark.parametrize("connector", CONNECTORS)
def test_host_parity_bit_for_bit(algo, join, connector):
    """run_host: pallas (interpret) == ref exactly, every algorithm x
    join x connector."""
    got = _run_host(algo, join, connector, "pallas")
    assert np.array_equal(got, _ref(algo, join, connector))


@pytest.mark.parametrize("algo", list(ALGOS))
def test_jit_parity_bit_for_bit(algo):
    """run_jit (whole-loop jit, kernels traced inside the while_loop):
    pallas == ref exactly."""
    mk, vd = ALGOS[algo]
    runs = {}
    for impl in ("ref", "pallas"):
        vert = load_graph(EDGES, N, P=4, value_dims=vd)
        res = run_jit(vert, mk(), mk().suggested_plan, max_supersteps=30,
                      kernel_impl=impl)
        runs[impl] = gather_values(res.vertex, N)
    assert np.array_equal(runs["pallas"], runs["ref"])


@pytest.mark.parametrize("algo", ["pagerank", "sssp"])
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_ooc_parity_bit_for_bit(algo, impl):
    """run_out_of_core under either kernel impl == the in-memory ref
    (per-super-partition gather layouts through one shared jitted step)."""
    mk, vd = ALGOS[algo]
    plan = _plan(algo, "full_outer", "partitioning", impl)
    vert = load_graph(EDGES, N, P=4, value_dims=vd)
    res = run_out_of_core(vert, mk(), plan, budget_partitions=2,
                          max_supersteps=30)
    assert np.array_equal(gather_values(res.vertex, N),
                          _ref(algo, "full_outer", "partitioning"))


def test_ooc_disk_tier_parity_bit_for_bit(tmp_path):
    """The kernel path composes with the full storage hierarchy: an OOC
    run under a DRAM budget spilling pages to disk, kernels on."""
    mk, vd = ALGOS["sssp"]
    plan = _plan("sssp", "full_outer", "partitioning", "pallas")
    vert = load_graph(EDGES, N, P=4, value_dims=vd)
    res = run_out_of_core(vert, mk(), plan, budget_partitions=2,
                          max_supersteps=30,
                          memory_budget_bytes=1 << 14,
                          disk_dir=str(tmp_path / "spill"))
    assert np.array_equal(gather_values(res.vertex, N),
                          _ref("sssp", "full_outer", "partitioning"))
    spilled = [s for s in res.stats
               if s.get("spill_read_bytes", 0) + s.get("spill_write_bytes",
                                                       0) > 0]
    assert spilled, "budget was meant to force the disk tier"


def test_driver_kernel_impl_overrides_plan():
    """run_host(kernel_impl=...) pins the dispatch over whatever the plan
    says, and the result still matches the ref exactly."""
    mk, vd = ALGOS["cc"]
    vert = load_graph(EDGES, N, P=4, value_dims=vd)
    plan = _plan("cc", "full_outer", "partitioning", "ref")
    res = run_host(vert, mk(), plan, max_supersteps=30,
                   kernel_impl="pallas")
    assert np.array_equal(gather_values(res.vertex, N),
                          _ref("cc", "full_outer", "partitioning"))


# ------------------------------------------------------- backend.resolve

def test_resolve_matrix(monkeypatch):
    monkeypatch.delenv(kbackend.ENV_VAR, raising=False)
    assert kbackend.resolve("auto", tpu=False) == "ref"
    assert kbackend.resolve("auto", tpu=True) == "pallas_tpu"
    assert kbackend.resolve("pallas", tpu=False) == "pallas"
    assert kbackend.resolve("pallas", tpu=True) == "pallas_tpu"
    assert kbackend.resolve("ref", tpu=False) == "ref"
    assert kbackend.resolve("ref", tpu=True) == "ref"
    assert kbackend.resolve("pallas_tpu", tpu=False) == "pallas_tpu"
    assert kbackend.resolve("pallas_tpu", tpu=True) == "pallas_tpu"
    with pytest.raises(ValueError):
        kbackend.resolve("bogus", tpu=False)


def test_resolve_env_override(monkeypatch):
    """$REPRO_KERNEL_IMPL overrides the knob itself — including "auto" —
    so CI can force a path without touching code or configs."""
    monkeypatch.setenv(kbackend.ENV_VAR, "pallas")
    assert kbackend.resolve("ref", tpu=False) == "pallas"
    assert kbackend.resolve("auto", tpu=False) == "pallas"
    assert kbackend.resolve("auto", tpu=True) == "pallas_tpu"
    monkeypatch.setenv(kbackend.ENV_VAR, "ref")
    assert kbackend.resolve("pallas", tpu=True) == "ref"
    monkeypatch.setenv(kbackend.ENV_VAR, "bogus")
    with pytest.raises(ValueError):
        kbackend.resolve("auto", tpu=False)


def test_env_override_end_to_end(monkeypatch):
    """A plain kernel_impl="auto" run under REPRO_KERNEL_IMPL=pallas
    takes the kernel path and still matches the ref bit-for-bit."""
    monkeypatch.setenv(kbackend.ENV_VAR, "pallas")
    mk, vd = ALGOS["sssp"]
    vert = load_graph(EDGES, N, P=4, value_dims=vd)
    res = run_host(vert, mk(), mk().suggested_plan, max_supersteps=30)
    monkeypatch.delenv(kbackend.ENV_VAR)
    assert np.array_equal(gather_values(res.vertex, N),
                          _ref("sssp", "full_outer", "partitioning"))


def test_plan_validates_kernel_impl():
    with pytest.raises(ValueError):
        PhysicalPlan(kernel_impl="vector").validate("sum")


# ----------------------------------------------------- planner pricing

def _web_stats():
    from repro.planner import GraphStats
    return GraphStats(n_vertices=130_000, n_edges=800_000, n_partitions=8,
                      vertex_capacity=16_250, edge_capacity=100_000)


def test_planner_prices_kernel_path_per_machine():
    """The cost model makes plan="auto" pick the kernels exactly where
    they win: cheaper than the jnp path on the MXU machine, dearer (the
    interpreter penalty) on the emulated one."""
    from repro.planner import (DEFAULT_MACHINE, EMULATED_MACHINE,
                               Observation, estimate)
    g = _web_stats()
    obs = Observation(frontier_density=1.0)
    base = PhysicalPlan(join="full_outer", groupby="sort",
                        connector="partitioning", sender_combine=True)
    ref = dataclasses.replace(base, kernel_impl="ref")
    pal = dataclasses.replace(base, kernel_impl="pallas")
    s = lambda p, m: estimate(p, g, obs, m).seconds(m)
    assert s(pal, DEFAULT_MACHINE) < s(ref, DEFAULT_MACHINE)
    assert s(ref, EMULATED_MACHINE) < s(pal, EMULATED_MACHINE)


def test_plan_space_kernel_dimension():
    """Default space stays the paper's 16 plans (kernel_impl inherited);
    pinning competing impls doubles it."""
    from repro.planner import plan_space
    prog = PageRank(N, iterations=6)
    assert len(list(plan_space(prog))) == 16
    both = list(plan_space(prog, kernel_impls=("ref", "pallas")))
    assert len(both) == 32
    assert {p.kernel_impl for p in both} == {"ref", "pallas"}


def test_choose_picks_kernels_only_on_mxu():
    from repro.planner import (DEFAULT_MACHINE, EMULATED_MACHINE,
                               Observation, choose)
    prog = PageRank(N, iterations=6)
    g, obs = _web_stats(), Observation(frontier_density=1.0)
    kw = dict(joins=("full_outer",), sender_combines=(True,),
              kernel_impls=("ref", "pallas"))
    plan_mxu, _ = choose(prog, g, obs, machine=DEFAULT_MACHINE, **kw)
    plan_emu, _ = choose(prog, g, obs, machine=EMULATED_MACHINE, **kw)
    assert plan_mxu.kernel_impl == "pallas"
    assert plan_emu.kernel_impl == "ref"


def test_cost_detail_ledger_populated():
    """PlanCost.detail carries the per-leg raw flops/bytes the roofline
    benchmark plots; components reconcile with the rolled-up totals."""
    from repro.planner import DEFAULT_MACHINE, Observation, estimate
    c = estimate(PhysicalPlan(kernel_impl="pallas"), _web_stats(),
                 Observation(frontier_density=1.0), DEFAULT_MACHINE)
    for leg in ("send", "sender_combine", "connector", "exchange"):
        assert leg in c.detail
    assert sum(d["flops"] for d in c.detail.values()) == pytest.approx(
        c.flops)
    assert sum(d["hbm_bytes"] for d in c.detail.values()) == pytest.approx(
        c.bytes)


# ------------------------------------------------- fused-pack HLO proof

def test_fused_pack_lowers_to_fewer_bytes_and_same_buckets():
    """The fused combine->exchange-pack leg: compacting combined
    survivors to the bucket capacity BEFORE the bucket build means the
    lowered HLO never re-materializes (or re-sorts) the full edge-payload
    relation — measured via the trip-count-aware HLO byte count, and
    the bucket outputs are bit-identical."""
    from repro.core.connector import bucket_by_owner
    from repro.core.superstep import compact_combined
    from repro.launch import hlo_cost

    P, M, D, n_parts, cap = 2, 4096, 2, 2, 32
    capc = n_parts * cap
    rng = np.random.default_rng(11)
    # post-combine shape: few survivors (one per distinct dst), dst
    # ascending per partition, everything else invalid — M >> capc
    dst = np.full((P, M), -1, np.int32)
    pay = np.zeros((P, M, D), np.float32)
    valid = np.zeros((P, M), bool)
    for p in range(P):
        rows = np.sort(rng.choice(M, 40, replace=False))
        dst[p, rows] = np.sort(rng.choice(1000, 40, replace=False))
        pay[p, rows] = rng.normal(size=(40, D)).astype(np.float32)
        valid[p, rows] = True

    def leg(d, pl, v, *, fused):
        if fused:
            d, pl, v, ovf_pack = compact_combined(d, pl, v, capc)
        else:
            ovf_pack = jnp.zeros((), jnp.int32)
        f = lambda dd, pp, vv: bucket_by_owner(dd, pp, vv, n_parts, cap,
                                               sort_by_dst=False)
        b_dst, b_pay, b_val, ovf = jax.vmap(f)(d, pl, v)
        return b_dst, b_pay, b_val, jnp.sum(ovf) + ovf_pack

    args = (jnp.asarray(dst), jnp.asarray(pay), jnp.asarray(valid))
    outs, bts = {}, {}
    for fused in (False, True):
        fn = jax.jit(functools.partial(leg, fused=fused))
        compiled = fn.lower(*args).compile()
        bts[fused] = hlo_cost.analyze(compiled.as_text()).bytes
        outs[fused] = jax.tree.map(np.asarray, fn(*args))
    for a, b in zip(outs[False], outs[True]):
        assert np.array_equal(a, b)
    assert bts[True] < bts[False], \
        f"fused leg must move fewer bytes: {bts[True]} vs {bts[False]}"
