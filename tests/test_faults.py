"""Chaos harness + recovery supervision: deterministic fault injection,
the I/O retry/backoff and degradation ladders, checksummed pages,
crash-mid-checkpoint validity, and driver-level recovery that converges
bit-for-bit with unfailed runs (paper Section 5.7)."""
import json
import os

if "XLA_FLAGS" not in os.environ:   # effective only when run standalone
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np
import pytest

from repro.core import gather_values, load_graph, run_host
from repro.core.ooc import run_out_of_core
from repro.core.sharded import run_sharded
from repro.graph import ConnectedComponents, PageRank, SSSP, rmat_graph
from repro.runtime import faults
from repro.runtime.checkpoint import (CheckpointCorruption, checkpoints,
                                      latest_checkpoint,
                                      latest_ooc_checkpoint,
                                      ooc_checkpoints, save_checkpoint,
                                      verify_ooc_checkpoint)
from repro.runtime.failure import FailureManager, StragglerMonitor, \
    WorkerFailure
from repro.storage.io_engine import ERRORS_CAP, IOEngine, RetryPolicy, \
    retry_io
from repro.storage.pager import BufferPool
from repro.storage.spillfile import (PageCorruption, SpillSlot,
                                     verify_page_file)

N = 120
EDGES = rmat_graph(N, 700, seed=3)

# near-zero backoff keeps the ladder tests fast
FAST = RetryPolicy(attempts=4, base_s=1e-4, cap_s=1e-3, jitter=0.0)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8 before jax init)")


@pytest.fixture(autouse=True)
def _disarm():
    """Every test starts and ends with the chaos harness off."""
    faults.clear()
    yield
    faults.clear()


def _vert():
    return load_graph(EDGES, N, P=4, value_dims=2)


def _vals(res):
    return gather_values(res.vertex, N)[:, 0]


# ---------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------

def test_injector_count_determinism():
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="spill.read", kind="transient",
                         after=2, times=2)]))
    outcomes = []
    for _ in range(6):
        try:
            faults.hit("spill.read", "page.npy")
            outcomes.append("ok")
        except faults.InjectedFault:
            outcomes.append("fault")
    # hits 1-2 pass (after=2), 3-4 fire (times=2), 5-6 pass again
    assert outcomes == ["ok", "ok", "fault", "fault", "ok", "ok"]
    s = faults.summary()
    assert s["specs"][0]["hits"] == 6
    assert s["specs"][0]["fired"] == 2
    faults.clear()
    faults.hit("spill.read", "page.npy")   # disarmed: no-op


def test_injector_match_and_sites():
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="spill.write", kind="permanent", times=0,
                         match="value")]))
    faults.hit("spill.write", "edge_src_0.npy")       # no match: passes
    with pytest.raises(faults.InjectedFault):
        faults.hit("spill.write", "value_1.npy")
    with pytest.raises(ValueError):
        faults.FaultSpec(site="not-a-site")
    with pytest.raises(ValueError):
        faults.FaultSpec(site="spill.read", kind="not-a-kind")


def test_worker_failure_at_superstep():
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="superstep", kind="worker", superstep=3,
                         worker=2, match="ooc")]))
    faults.superstep_tick(3, "host")      # wrong driver: passes
    faults.superstep_tick(2, "ooc")       # wrong superstep: passes
    with pytest.raises(WorkerFailure) as ei:
        faults.superstep_tick(3, "ooc")
    assert ei.value.worker == 2
    faults.superstep_tick(3, "ooc")       # times=1: consumed


def test_plan_env_roundtrip(tmp_path, monkeypatch):
    plan = faults.FaultPlan(seed=7, faults=[
        faults.FaultSpec(site="spill.read", kind="transient", times=2),
        faults.FaultSpec(site="superstep", kind="worker", superstep=5,
                         worker=1)])
    back = faults.FaultPlan.from_json(plan.to_json())
    assert back == plan
    # inline JSON
    monkeypatch.setenv(faults.ENV_PLAN, plan.to_json())
    inj = faults.install_from_env()
    assert inj is not None and inj.plan == plan
    # path to JSON
    p = tmp_path / "plan.json"
    p.write_text(plan.to_json())
    monkeypatch.setenv(faults.ENV_PLAN, str(p))
    assert faults.install_from_env().plan == plan
    monkeypatch.delenv(faults.ENV_PLAN)
    assert faults.install_from_env() is None


# ---------------------------------------------------------------------
# checksummed pages
# ---------------------------------------------------------------------

def test_page_checksum_roundtrip(tmp_path):
    slot = SpillSlot(tmp_path / "page.npy")
    arr = np.arange(64, dtype=np.float32).reshape(8, 8)
    slot.store(arr)
    assert verify_page_file(slot.path)
    assert np.array_equal(slot.load(), arr)
    # flip one payload byte: CRC must catch it
    raw = bytearray(slot.path.read_bytes())
    raw[90] ^= 0xFF
    slot.path.write_bytes(bytes(raw))
    assert not verify_page_file(slot.path)
    with pytest.raises(PageCorruption):
        slot.load()


def test_injected_write_corruption_detected(tmp_path):
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="page.corrupt", kind="corrupt", times=1)]))
    slot = SpillSlot(tmp_path / "page.npy")
    slot.store(np.ones(16, dtype=np.int32))
    with pytest.raises(PageCorruption):
        slot.load()
    # the fault was times=1: the next write is clean
    slot.store(np.ones(16, dtype=np.int32))
    assert np.array_equal(slot.load(), np.ones(16, dtype=np.int32))


# ---------------------------------------------------------------------
# retry + degradation ladders
# ---------------------------------------------------------------------

def test_retry_ladder_transient_succeeds():
    calls = {"n": 0}
    retried = []

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise OSError("transient EIO")
        return "ok"

    out = retry_io(flaky, FAST, on_retry=lambda a, e: retried.append(a))
    assert out == "ok" and calls["n"] == 3 and retried == [0, 1]


def test_retry_ladder_permanent_and_corruption():
    def dead():
        raise OSError("dead disk")

    with pytest.raises(OSError):
        retry_io(dead, FAST)

    calls = {"n": 0}

    def corrupt():
        calls["n"] += 1
        raise PageCorruption("p.npy")

    with pytest.raises(PageCorruption):
        retry_io(corrupt, FAST)
    assert calls["n"] == 1    # corruption is never retried


def test_degradation_ladder_and_healing(tmp_path):
    pool = BufferPool(None, spill=None)
    engine = IOEngine(pool, threads=1, readahead_pages=8, retry=FAST)
    try:
        assert engine.effective_readahead() == 8
        for _ in range(4):                   # health 4: throttle
            engine._note_retry(0, OSError())
        assert engine.degrade_level == 1
        assert engine.effective_readahead() == 1
        for _ in range(2):                   # health 8: sync fallback
            engine._bump_health(+2)
        assert engine.degrade_level == 2
        assert engine.effective_readahead() == 0
        for _ in range(8):                   # clean ops heal it back
            engine._bump_health(-1)
        assert engine.degrade_level == 0
        assert engine.effective_readahead() == 8
        assert engine.stats()["io_retries"] == 4
    finally:
        engine.close()


def test_error_log_bounded():
    pool = BufferPool(None, spill=None)
    engine = IOEngine(pool, threads=1)
    try:
        for k in range(ERRORS_CAP + 40):
            engine._record_error(("page", k), OSError("EIO"))
        assert len(engine.errors) <= ERRORS_CAP
        assert engine.error_count == ERRORS_CAP + 40
        assert engine.stats()["io_errors"] == ERRORS_CAP + 40
    finally:
        engine.close()


def test_transient_spill_faults_survive_ooc_run(tmp_path):
    """Transient read/write faults on the disk tier are absorbed by the
    retry ladder — the run completes without recovery and stays
    bit-for-bit with the clean run."""
    pr = PageRank(N, iterations=6)
    clean = run_out_of_core(_vert(), pr, pr.suggested_plan,
                            budget_partitions=2, max_supersteps=10,
                            disk_dir=str(tmp_path / "clean"))
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="spill.write", kind="transient", times=3),
        faults.FaultSpec(site="io.bg", kind="transient", times=2)]))
    chaotic = run_out_of_core(_vert(), pr, pr.suggested_plan,
                              budget_partitions=2, max_supersteps=10,
                              disk_dir=str(tmp_path / "chaos"),
                              memory_budget_bytes=1 << 18,
                              io_threads=1)
    assert np.array_equal(_vals(chaotic), _vals(clean))


# ---------------------------------------------------------------------
# failure manager
# ---------------------------------------------------------------------

def test_failure_manager_blacklists_repeat_offender():
    fm = FailureManager(n_workers=4, max_retries=3)
    assert fm.record(OSError("EIO"), worker=1)
    assert fm.record(OSError("EIO"), worker=1)
    assert 1 not in fm.blacklist          # two strikes: benefit of doubt
    assert fm.record(PageCorruption("p.npy"), worker=1)
    assert 1 in fm.blacklist              # third recoverable failure
    assert fm.healthy_workers() == 3
    # a WorkerFailure blacklists immediately
    assert fm.record(WorkerFailure(2, "power off"))
    assert 2 in fm.blacklist
    # application errors are not recoverable and never blacklist
    assert not fm.record(ValueError("bug"), worker=3)
    assert 3 not in fm.blacklist


def test_straggler_monitor_and_stats_wiring():
    mon = StragglerMonitor(threshold=2.0)
    for i in range(5):
        assert mon.observe(i, 0.1) is None
    flag = mon.observe(5, 0.5)
    assert flag and flag["action"] == "flag-straggler"

    from repro.planner.stats import StatsCollector
    coll = StatsCollector(n_partitions=4, vertex_capacity=32, msg_dims=1)
    for i in range(6):
        rec = coll.record(i, active=10, messages=5, wall_s=0.01)
        assert "straggler" not in rec.extra
    slow = coll.record(6, active=10, messages=5, wall_s=0.5)
    assert slow.extra["straggler"]["superstep"] == 6
    # jit-compile steps are excluded from the straggler baseline
    comp = coll.record(7, active=10, messages=5, wall_s=9.0,
                       recompiled=True)
    assert "straggler" not in comp.extra


# ---------------------------------------------------------------------
# checkpoint validity: COMMIT manifests, crash-mid-checkpoint
# ---------------------------------------------------------------------

def test_crash_mid_npz_checkpoint(tmp_path):
    """The fault injector kills the writer between payload publish and
    the COMMIT manifest; recovery must restore the PREVIOUS committed
    snapshot, never the newer partial."""
    pr = PageRank(N, iterations=6)
    clean = run_host(_vert(), pr, pr.suggested_plan, max_supersteps=10)
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="checkpoint.commit", kind="permanent",
                         times=1, match="ckpt_000004")]))
    res = run_host(_vert(), pr, pr.suggested_plan, max_supersteps=10,
                   checkpoint_every=2, checkpoint_dir=str(tmp_path),
                   recover=True)
    # restore landed on ckpt_000002 — the ckpt_000004 payload existed at
    # restore time but carried no manifest (the replay later rewrites it)
    assert res.recovery and res.recovery[0]["restored_from"] \
        == str(tmp_path / "ckpt_000002.npz")
    assert np.allclose(_vals(res), _vals(clean), atol=1e-6)


def test_partial_npz_never_selected(tmp_path):
    v = _vert()
    pr = PageRank(N, iterations=6)
    res = run_host(v, pr, pr.suggested_plan, max_supersteps=6,
                   checkpoint_every=2, checkpoint_dir=str(tmp_path))
    assert res.supersteps >= 4
    good = latest_checkpoint(str(tmp_path))
    # a later payload without a manifest must never win, even though the
    # (untrusted) LATEST hint points at it
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="checkpoint.commit", kind="permanent")]))
    from repro.runtime.checkpoint import load_checkpoint
    gv, gm, ggs = load_checkpoint(good)
    with pytest.raises(faults.InjectedFault):
        save_checkpoint(str(tmp_path), 99, gv, gm, ggs)
    faults.clear()
    assert (tmp_path / "ckpt_000099.npz").exists()
    assert latest_checkpoint(str(tmp_path)) == good
    assert all("000099" not in c for c in checkpoints(str(tmp_path)))


def test_corrupt_npz_fails_over_to_previous(tmp_path):
    pr = PageRank(N, iterations=6)
    run_host(_vert(), pr, pr.suggested_plan, max_supersteps=6,
             checkpoint_every=2, checkpoint_dir=str(tmp_path))
    newest = latest_checkpoint(str(tmp_path))
    raw = bytearray((tmp_path / os.path.basename(newest)).read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    (tmp_path / os.path.basename(newest)).write_bytes(bytes(raw))
    # verify=True rejects the damaged snapshot outright
    assert latest_checkpoint(str(tmp_path), verify=True) != newest
    from repro.runtime.checkpoint import load_checkpoint
    with pytest.raises(CheckpointCorruption):
        load_checkpoint(newest)


def test_crash_mid_ooc_checkpoint(tmp_path):
    """Same crash window for the OOC (directory) checkpoint writer: the
    partial snapshot stays visible on disk without a manifest, selection
    skips it, and a resume lands on the previous valid snapshot."""
    pr = PageRank(N, iterations=6)
    clean = run_out_of_core(_vert(), pr, pr.suggested_plan,
                            budget_partitions=2, max_supersteps=10,
                            disk_dir=str(tmp_path / "clean"))
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="checkpoint.commit", kind="permanent",
                         after=1, times=1)]))
    ck = tmp_path / "ckpt"
    with pytest.raises(faults.InjectedFault):
        run_out_of_core(_vert(), pr, pr.suggested_plan,
                        budget_partitions=2, max_supersteps=10,
                        disk_dir=str(tmp_path / "chaos"),
                        checkpoint_every=2, checkpoint_dir=str(ck))
    # the writer died mid-checkpoint at superstep 4: the partial dir is
    # visible, manifest-less, and never selected
    assert (ck / "ooc_000004").is_dir()
    assert not (ck / "ooc_000004" / "COMMIT.json").exists()
    assert str(ck / "ooc_000004") not in ooc_checkpoints(str(ck))
    assert latest_ooc_checkpoint(str(ck)) == str(ck / "ooc_000002")
    # a resume pointed at the checkpoint PARENT resolves to the valid
    # snapshot and finishes bit-for-bit (vert=None: shapes come from it)
    res = run_out_of_core(None, pr, pr.suggested_plan,
                          budget_partitions=2, max_supersteps=10,
                          disk_dir=str(tmp_path / "resume"),
                          resume_from=str(ck))
    assert np.array_equal(_vals(res), _vals(clean))


def test_verify_ooc_checkpoint_deep(tmp_path):
    pr = PageRank(N, iterations=6)
    ck = tmp_path / "ckpt"
    run_out_of_core(_vert(), pr, pr.suggested_plan, budget_partitions=2,
                    max_supersteps=6, disk_dir=str(tmp_path / "spill"),
                    checkpoint_every=2, checkpoint_dir=str(ck))
    snaps = ooc_checkpoints(str(ck))
    assert len(snaps) >= 2
    assert verify_ooc_checkpoint(snaps[-1]) == []
    # damage one page payload inside the newest snapshot
    import pathlib
    pages = sorted(pathlib.Path(snaps[-1]).glob("*.npy"))
    raw = bytearray(pages[0].read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    # break the hard link first: the live spill file must stay clean
    pages[0].unlink()
    pages[0].write_bytes(bytes(raw))
    assert verify_ooc_checkpoint(snaps[-1]) != []
    # deep selection fails over to the previous valid snapshot
    assert latest_ooc_checkpoint(str(ck), deep=True) == snaps[-2]


# ---------------------------------------------------------------------
# chaos parity: recovery converges bit-for-bit with unfailed runs
# ---------------------------------------------------------------------

_CHAOS_ALGOS = {
    "pagerank": lambda: PageRank(N, iterations=8),
    "sssp": lambda: SSSP(source=0),
    "cc": lambda: ConnectedComponents(),
}


@pytest.mark.parametrize("algo", sorted(_CHAOS_ALGOS))
def test_ooc_recovery_parity(tmp_path, algo):
    """Seeded chaos plan — transient disk reads, one permanent page
    corruption, a WorkerFailure at superstep 5 — against
    ``run_out_of_core(recover=True)``: completes bit-for-bit identical
    to the unfailed run, restoring from a committed checkpoint."""
    prog = _CHAOS_ALGOS[algo]()
    clean = run_out_of_core(_vert(), prog, prog.suggested_plan,
                            budget_partitions=2, max_supersteps=12,
                            disk_dir=str(tmp_path / "clean"))
    # the corruption hits the gen-4 inbox page exported into checkpoint
    # ooc_000004; worker 1 then dies at superstep 4, so recovery must
    # reject the newest (corrupt) snapshot, restore ooc_000003 — whose
    # page reads run through the transient spill.read faults — and
    # replay to a bit-for-bit identical result
    faults.install(faults.FaultPlan(seed=42, faults=[
        faults.FaultSpec(site="spill.read", kind="transient", times=2),
        faults.FaultSpec(site="page.corrupt", kind="corrupt", times=1,
                         match="inbox_dst_4"),
        faults.FaultSpec(site="superstep", kind="worker", superstep=4,
                         worker=1, match="ooc", times=1)]))
    res = run_out_of_core(_vert(), prog, prog.suggested_plan,
                          budget_partitions=2, max_supersteps=12,
                          disk_dir=str(tmp_path / "chaos"),
                          checkpoint_every=1,
                          checkpoint_dir=str(tmp_path / "ckpt"),
                          recover=True)
    summ = faults.summary()
    assert summ["specs"][0]["fired"] == 2       # transients retried away
    assert summ["specs"][1]["fired"] == 1       # corruption landed
    assert summ["specs"][2]["fired"] == 1       # worker failed once
    assert len(res.recovery) == 1
    assert res.recovery[0]["restored_from"] \
        == str(tmp_path / "ckpt" / "ooc_000003")
    assert np.array_equal(_vals(res), _vals(clean))


def test_ooc_recovery_from_live_page_corruption(tmp_path):
    """A corrupt LIVE page raises typed PageCorruption on fault-in under
    budget pressure; the supervisor restores and the run converges."""
    pr = PageRank(N, iterations=8)
    clean = run_out_of_core(_vert(), pr, pr.suggested_plan,
                            budget_partitions=2, max_supersteps=12,
                            disk_dir=str(tmp_path / "clean"))
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="page.corrupt", kind="corrupt", times=1,
                         match="value", after=4)]))
    res = run_out_of_core(_vert(), pr, pr.suggested_plan,
                          budget_partitions=2, max_supersteps=12,
                          disk_dir=str(tmp_path / "chaos"),
                          memory_budget_bytes=1 << 17,
                          checkpoint_every=2,
                          checkpoint_dir=str(tmp_path / "ckpt"),
                          recover=True)
    assert np.array_equal(_vals(res), _vals(clean))


def test_host_recovery_elastic(tmp_path):
    """WorkerFailure blacklists a worker; the host driver re-partitions
    the latest checkpoint onto the survivors (P=4 -> P=3) and
    converges."""
    pr = PageRank(N, iterations=8)
    clean = run_host(_vert(), pr, pr.suggested_plan, max_supersteps=12)
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="superstep", kind="worker", superstep=5,
                         worker=2, match="host", times=1)]))
    res = run_host(_vert(), pr, pr.suggested_plan, max_supersteps=12,
                   checkpoint_every=2, checkpoint_dir=str(tmp_path),
                   recover=True)
    assert len(res.recovery) == 1
    assert res.recovery[0]["blacklist"] == [2]
    assert res.vertex.num_partitions == 3
    assert np.allclose(_vals(res), _vals(clean), atol=1e-6)


def test_supervisor_forwards_application_errors():
    pr = PageRank(N, iterations=4)

    def boom(i, rec):
        if i == 2:
            raise ValueError("application bug")

    with pytest.raises(ValueError):
        run_out_of_core(_vert(), pr, pr.suggested_plan,
                        budget_partitions=2, max_supersteps=8,
                        recover=True, on_superstep=boom)


# ---------------------------------------------------------------------
# sharded driver recovery (multi-device: runs in the CI chaos job under
# XLA_FLAGS=--xla_force_host_platform_device_count=8)
# ---------------------------------------------------------------------

def _vert8():
    return load_graph(EDGES, N, P=8, value_dims=2)


@multi_device
def test_sharded_recovery_parity(tmp_path):
    """WorkerFailure on the mesh: recovery blacklists the device-worker,
    restores the latest valid npz checkpoint, re-meshes onto the largest
    divisor of P that fits the 7 survivors (P stays 8, so per-partition
    results are device-count invariant) and replays bit-for-bit."""
    pr = PageRank(N, iterations=8)
    clean = run_sharded(_vert8(), pr, pr.suggested_plan,
                        max_supersteps=12)
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="superstep", kind="worker", superstep=3,
                         worker=5, match="sharded", times=1)]))
    res = run_sharded(_vert8(), pr, pr.suggested_plan, max_supersteps=12,
                      checkpoint_every=2, checkpoint_dir=str(tmp_path),
                      recover=True)
    assert len(res.recovery) == 1
    assert res.recovery[0]["blacklist"] == [5]
    assert res.recovery[0]["restored_from"] \
        == str(tmp_path / "ckpt_000002.npz")
    assert np.array_equal(_vals(res), _vals(clean))


@multi_device
def test_sharded_exchange_fault_restarts(tmp_path):
    """A transient exchange-transport fault before the first checkpoint
    is recoverable but leaves nothing to restore: the supervisor
    restarts from the initial relations and still converges
    bit-for-bit."""
    pr = PageRank(N, iterations=6)
    clean = run_sharded(_vert8(), pr, pr.suggested_plan,
                        max_supersteps=10)
    faults.install(faults.FaultPlan(faults=[
        faults.FaultSpec(site="sharded.exchange", kind="transient",
                         times=1)]))
    res = run_sharded(_vert8(), pr, pr.suggested_plan, max_supersteps=10,
                      checkpoint_every=4, checkpoint_dir=str(tmp_path),
                      recover=True)
    assert len(res.recovery) == 1
    assert res.recovery[0]["restored_from"] is None
    assert np.array_equal(_vals(res), _vals(clean))
