"""Barrier-free superstep pipeline + background page-I/O engine suite.

The PR-5 executor removes the two global stalls PR 3/4 left per
superstep: the inbox-rebuild/GS-fold barrier between supersteps
(per-destination readiness: rebuild and mutation-apply roll forward one
destination at a time, overlapped with the next superstep's compute)
and synchronous page faults/write-backs on the dispatcher/collector
thread (the ``storage/io_engine`` worker). Both are pure scheduling
changes, so the bar is the same as every other executor mode:
BIT-FOR-BIT parity with the synchronous loop — including mutations, the
disk tier, mid-pipeline regrows spanning the rolling frontier, and
checkpoint/resume — plus fault-injection coverage for the engine
(failed/delayed reads surface cleanly, dirty pages drain on shutdown,
eviction never blocks on in-flight I/O) and the controller-state /
re-calibration / per-superstep-counter satellites.
"""
import dataclasses
import json
import threading
import time

import numpy as np
import pytest

from repro.core import (EngineConfig, PhysicalPlan, gather_values,
                        load_graph, run_host)
from repro.core.ooc import run_out_of_core
from repro.graph import SSSP, ConnectedComponents, PageRank, chain_graph, \
    rmat_graph
from repro.storage import BufferPool, IOEngine, SpillDir, TieredStore
from repro.storage.spillfile import SpillSlot

N = 220
EDGES = rmat_graph(N, 1200, seed=7)
ALGOS = {
    "pagerank": (lambda: PageRank(N, iterations=6), 2),
    "sssp": (lambda: SSSP(source=3), 1),
    "cc": (lambda: ConnectedComponents(), 1),
}
_BUDGET = 16 * 1024
_SYNC_REF = {}


def _sync_ref(algo: str):
    """The reference: the fully synchronous loop (stream=False), at the
    same super-partitioning as the pipelined runs — the float aggregate
    folds per super-partition, so the counts must match for
    bit-equality."""
    if algo not in _SYNC_REF:
        mk, vd = ALGOS[algo]
        vert = load_graph(EDGES, N, P=4, value_dims=vd)
        res = run_out_of_core(vert, mk(), mk().suggested_plan,
                              budget_partitions=1, max_supersteps=30,
                              stream=False)
        _SYNC_REF[algo] = (gather_values(res.vertex, N), res.supersteps,
                           np.asarray(res.gs.aggregate))
    return _SYNC_REF[algo]


# ------------------------------------------------- bit-for-bit parity

@pytest.mark.parametrize("algo", list(ALGOS))
def test_barrier_free_matches_sync_bit_for_bit(algo):
    """barrier-free == barrier == synchronous, exactly — values,
    superstep count and the order-sensitive float aggregate."""
    vals, steps, agg = _sync_ref(algo)
    mk, vd = ALGOS[algo]
    for bf in (False, True):
        vert = load_graph(EDGES, N, P=4, value_dims=vd)
        res = run_out_of_core(vert, mk(), mk().suggested_plan,
                              budget_partitions=1, max_supersteps=30,
                              stream=True, barrier_free=bf,
                              prefetch_depth=3)
        assert np.array_equal(gather_values(res.vertex, N), vals), bf
        assert res.supersteps == steps
        assert np.array_equal(np.asarray(res.gs.aggregate), agg)
    recs = [s for s in res.stats if "wall_s" in s]
    assert recs and all(s["barrier_free"] for s in recs)
    assert all(s["readiness_stall_s"] >= 0.0 for s in recs)
    assert all(s["super_partitions"] == 4 for s in recs)


@pytest.mark.parametrize("algo", list(ALGOS))
def test_barrier_free_disk_tier_with_io_engine_parity(algo, tmp_path):
    """The full stack at once: barrier-free + spilling buffer cache +
    background I/O engine (readahead + dirty drain) — still bit-for-bit
    with the synchronous DRAM loop."""
    vals, steps, _ = _sync_ref(algo)
    mk, vd = ALGOS[algo]
    vert = load_graph(EDGES, N, P=4, value_dims=vd)
    res = run_out_of_core(vert, mk(), mk().suggested_plan,
                          budget_partitions=2, max_supersteps=30,
                          stream=True, barrier_free=True,
                          memory_budget_bytes=_BUDGET, disk_dir=tmp_path,
                          eviction="mru", io_threads=2,
                          readahead_pages=16)
    assert np.array_equal(gather_values(res.vertex, N), vals)
    assert res.supersteps == steps
    recs = [s for s in res.stats if "wall_s" in s]
    assert recs and all(s["spill"] for s in recs)
    assert all(s["io_queue_depth"] >= 0 for s in recs)


def test_barrier_free_mutations_parity():
    """Cross-super-partition inserts under the rolling frontier: the
    per-destination mutation apply (deferred into prepare) must match
    run_host exactly — including the final superstep's mutations, which
    the loop-exit path must land before the gather."""
    from tests.test_storage import CrossInsert, _cross_insert_ref
    ref = _cross_insert_ref(N, 3)
    for bf in (False, True):
        vert = load_graph(EDGES, N, P=4, value_dims=1)
        prog = CrossInsert(N, 3)
        res = run_out_of_core(vert, prog, prog.suggested_plan,
                              budget_partitions=2, max_supersteps=5,
                              stream=True, barrier_free=bf)
        assert np.array_equal(gather_values(res.vertex, N), ref), bf


def test_barrier_free_mutations_applied_at_max_supersteps_cutoff():
    """Stop the run on the exact superstep that PROPOSES inserts: the
    rolling frontier defers their application to the next superstep's
    prepare, which never comes — the exit path must apply them anyway,
    mirroring run_host (whose in-step apply includes them)."""
    from tests.test_storage import CrossInsert
    prog = CrossInsert(N, 3)
    ref = run_host(load_graph(EDGES, N, P=4, value_dims=1), prog,
                   prog.suggested_plan, max_supersteps=1)
    res = run_out_of_core(load_graph(EDGES, N, P=4, value_dims=1),
                          CrossInsert(N, 3), prog.suggested_plan,
                          budget_partitions=2, max_supersteps=1,
                          stream=True, barrier_free=True)
    assert np.array_equal(gather_values(res.vertex, N),
                          gather_values(ref.vertex, N))


def test_regrow_while_rolling_frontier_spans_supersteps():
    """A bucket overflow landing while the rolling frontier has later
    destinations still unprepared (window < n_sp, so chunks of the
    in-flight generation are built lazily while earlier destinations
    compute — destination state of two adjacent generations coexists in
    the store): the deferred regrow must unwind, pad the committed
    generation-g+1 blocks, redo, and stay bit-for-bit."""
    prog = SSSP(source=3)
    ec = EngineConfig(n_parts=4, bucket_cap=2, frontier_cap=0)
    outs = {}
    for bf in (False, True):
        vert = load_graph(EDGES, N, P=4, value_dims=1)
        res = run_out_of_core(vert, SSSP(source=3), prog.suggested_plan,
                              budget_partitions=1, max_supersteps=30,
                              ec=ec, stream=True, barrier_free=bf,
                              prefetch_depth=2)
        regrows = [s for s in res.stats if s.get("event") == "regrow"]
        assert regrows and regrows[-1]["bucket_cap"] > 2
        outs[bf] = gather_values(res.vertex, N)
    assert np.array_equal(outs[True], outs[False])
    assert np.array_equal(outs[True], _sync_ref("sssp")[0])


def test_checkpoint_resume_under_barrier_free(tmp_path):
    """Checkpoints synchronize the rolling frontier (the saved inbox
    generation is complete, mutations applied); resuming lands on the
    identical final state."""
    prog = SSSP(source=3)
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    full = run_out_of_core(vert, prog, prog.suggested_plan,
                           budget_partitions=2, max_supersteps=30,
                           stream=True, barrier_free=True,
                           checkpoint_every=2,
                           checkpoint_dir=str(tmp_path))
    ck = tmp_path / "ooc_000002"
    assert (ck / "meta.json").exists()
    res = run_out_of_core(None, SSSP(source=3), prog.suggested_plan,
                          budget_partitions=2, max_supersteps=30,
                          stream=True, barrier_free=True,
                          resume_from=str(ck))
    assert res.supersteps == full.supersteps
    assert np.array_equal(gather_values(res.vertex, N),
                          gather_values(full.vertex, N))


# --------------------------------------- controller state & recalibrate

def test_checkpoint_persists_controller_hysteresis_state(tmp_path):
    """The OOC checkpoint meta carries the AdaptiveController's
    window/streak/cooldown state, and resume restores it — a resume
    right before a pending switch must not re-pay the patience window."""
    prog = SSSP(source=3)
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    run_out_of_core(vert, prog, "auto", budget_partitions=2,
                    max_supersteps=6, checkpoint_every=2,
                    checkpoint_dir=str(tmp_path))
    meta = json.loads((tmp_path / "ooc_000002" / "meta.json").read_text())
    assert meta["controller"] is not None
    assert {"want", "streak", "last_switch", "last_recal"} <= \
        set(meta["controller"])


def test_controller_state_roundtrip_mid_patience():
    """state_dict/load_state reproduce a half-served patience window:
    the restored controller switches after ONE more preferring
    superstep, not a full fresh window."""
    from repro.planner import AdaptiveConfig, GraphStats
    from repro.planner.adaptive import AdaptiveController
    from repro.planner.stats import SuperstepStats
    g = GraphStats(n_vertices=100_000, n_edges=800_000, n_partitions=8,
                   vertex_capacity=16_250, edge_capacity=100_000)
    prog = SSSP(source=0)
    cfg = AdaptiveConfig(patience=2, cooldown=0, min_superstep=0)
    full = PhysicalPlan(join="full_outer")
    rec = lambda i: SuperstepStats(superstep=i, active=50,
                                   frontier_density=50 / 100_000)
    c1 = AdaptiveController(prog, g, full, cfg)
    assert c1.observe(rec(3)) is None          # streak 1 of 2
    state = c1.state_dict()
    assert state["want"] is not None and state["streak"] == 1
    c2 = AdaptiveController(prog, g, full, cfg)
    c2.load_state(state)
    switched = c2.observe(rec(4))              # streak 2 -> switch
    assert switched is not None and switched.join == "left_outer"
    # a fresh controller at the same superstep would still be waiting
    c3 = AdaptiveController(prog, g, full, cfg)
    assert c3.observe(rec(4)) is None


def test_maybe_recalibrate_amortizes_and_requires_shape_change(
        monkeypatch):
    """Re-calibration fires only when (calibrate on, recalibrate_every
    set, shapes changed, N supersteps since the last fit) all hold —
    and updates the controller's machine in place."""
    import repro.planner.adaptive as adaptive_mod
    from repro.planner import AdaptiveConfig, GraphStats
    from repro.planner.adaptive import AdaptiveController
    from repro.planner.cost import DEFAULT_MACHINE
    calls = []

    def fake_calibrate(program, g, machine, refresh=False):
        calls.append(refresh)
        return dataclasses.replace(machine, k_compute=42.0)

    monkeypatch.setattr("repro.planner.cost.calibrate_machine",
                        fake_calibrate)
    g = GraphStats(n_vertices=100, n_edges=400, n_partitions=4,
                   vertex_capacity=32, edge_capacity=128)
    prog = SSSP(source=0)
    cfg = AdaptiveConfig(calibrate=True, recalibrate_every=3)
    c = AdaptiveController(prog, g, PhysicalPlan(), cfg,
                           machine=DEFAULT_MACHINE)
    assert c.maybe_recalibrate(prog, 1) is None      # no shape change
    c.note_shape_change()
    out = c.maybe_recalibrate(prog, 1)
    assert out is not None and out["k_compute"] == 42.0
    assert calls == [True] and c.machine.k_compute == 42.0
    c.note_shape_change()
    assert c.maybe_recalibrate(prog, 2) is None      # within the window
    assert c.maybe_recalibrate(prog, 4) is not None  # window elapsed
    assert len(calls) == 2
    # recalibrate_every=0 (default) never refits
    c0 = AdaptiveController(prog, g, PhysicalPlan(),
                            AdaptiveConfig(calibrate=True))
    c0.note_shape_change()
    assert c0.maybe_recalibrate(prog, 50) is None


# -------------------------------------------- per-superstep counters

def test_pager_counters_reset_per_superstep(tmp_path):
    """The statistics stream carries INTERVAL pager counters: each
    record reflects only its own superstep's paging (they sum to the
    pool's cumulative totals), so the planner observes current — not
    cumulative — behavior."""
    prog = PageRank(N, iterations=6)
    vert = load_graph(EDGES, N, P=4, value_dims=2)
    res = run_out_of_core(vert, prog, prog.suggested_plan,
                          budget_partitions=2, max_supersteps=10,
                          memory_budget_bytes=_BUDGET,
                          disk_dir=tmp_path, io_threads=0)
    recs = [s for s in res.stats if "spill_read_bytes" in s]
    assert len(recs) >= 3
    # steady-state supersteps page similar amounts: a cumulative counter
    # would grow monotonically instead
    steady = [s["spill_read_bytes"] for s in recs[1:]]
    assert max(steady) < sum(steady), "per-superstep, not cumulative"
    assert all(0.0 <= s["cache_hit_rate"] <= 1.0 for s in recs)


def test_take_interval_resets_and_sums_to_cumulative(tmp_path):
    pool = BufferPool(2 * 4096, policy="lru", spill=SpillDir(tmp_path))
    a = np.zeros((1024,), np.float32)   # 4 KiB pages
    for i in range(3):
        pool.put(i, a + i)
    pool.get(0)
    i1 = pool.take_interval()
    assert i1["evictions"] >= 1 and i1["misses"] >= 1
    i2 = pool.take_interval()
    assert i2["misses"] == 0 and i2["spill_read_bytes"] == 0
    pool.get(1)
    i3 = pool.take_interval()
    total = pool.stats()
    assert i1["misses"] + i2["misses"] + i3["misses"] == total["misses"]


# ------------------------------------------------- I/O engine unit tests

def _engine_pool(tmp_path, budget_pages=2, threads=1, **kw):
    pool = BufferPool(budget_pages * 4096, policy="lru",
                      spill=SpillDir(tmp_path))
    engine = IOEngine(pool, threads=threads, **kw)
    pool.attach_engine(engine)
    return pool, engine


def _page(i):
    return np.full((1024,), i, np.float32)   # 4 KiB


def test_engine_readahead_turns_fault_into_hit(tmp_path):
    pool, engine = _engine_pool(tmp_path, readahead_pages=8)
    try:
        for i in range(3):
            pool.put(i, _page(i))
        assert not pool.page(0).resident     # evicted by budget
        engine.clean_ahead(limit=8)
        engine.prefetch([0])
        engine.drain()
        st0 = pool.stats()
        got = pool.get(0)                    # must be a DRAM hit now
        assert np.array_equal(got, _page(0))
        assert pool.stats()["hits"] == st0["hits"] + 1
        assert pool.stats()["misses"] == st0["misses"]
        assert engine.stats()["io_reads"] >= 1
    finally:
        engine.close()


def test_engine_drains_dirty_pages_on_shutdown(tmp_path):
    """Dirty pages whose write-backs were handed to the engine are on
    disk when close() returns — nothing is lost at shutdown."""
    pool, engine = _engine_pool(tmp_path, budget_pages=4)
    try:
        for i in range(4):
            pool.put(i, _page(i))            # all dirty, all resident
        scheduled = engine.clean_ahead(limit=4)
        assert scheduled > 0                 # budget is exactly full
    finally:
        engine.close()
    for i in range(4):
        page = pool.page(i)
        if not page.dirty:
            assert page.slot is not None and page.slot.exists()
            assert np.array_equal(page.slot.load(), _page(i))
    assert engine.stats()["io_writes"] >= 1


def test_engine_failed_read_surfaces_cleanly(tmp_path, monkeypatch):
    """A failed background read must not hang or kill the run: the
    engine records the error and the foreground fault retries
    synchronously, surfacing the real exception to the caller."""
    pool, engine = _engine_pool(tmp_path)
    try:
        for i in range(3):
            pool.put(i, _page(i))
        pool.flush()
        assert not pool.page(0).resident
        orig = SpillSlot.load

        def boom(self):
            raise OSError("injected read failure")

        monkeypatch.setattr(SpillSlot, "load", boom)
        engine.prefetch([0])
        engine.drain()
        assert 0 in engine.errors
        assert isinstance(engine.errors[0], OSError)
        with pytest.raises(OSError, match="injected"):
            pool.get(0)                      # sync retry surfaces it
        monkeypatch.setattr(SpillSlot, "load", orig)
        assert np.array_equal(pool.get(0), _page(0))   # and recovers
    finally:
        engine.close()


def test_foreground_get_waits_for_inflight_background_fault(tmp_path,
                                                            monkeypatch):
    """A DELAYED background read: the foreground get blocks until the
    in-flight engine fault lands instead of duplicating the disk read,
    then returns the faulted bytes."""
    pool, engine = _engine_pool(tmp_path)
    try:
        for i in range(3):
            pool.put(i, _page(i))
        pool.flush()
        assert not pool.page(0).resident
        gate = threading.Event()
        orig = SpillSlot.load

        def slow(self):
            gate.wait(timeout=10.0)
            return orig(self)

        monkeypatch.setattr(SpillSlot, "load", slow)
        engine.prefetch([0])
        time.sleep(0.05)                     # engine now blocked in load
        monkeypatch.setattr(SpillSlot, "load", orig)
        got = {}

        def fg():
            got["v"] = pool.get(0)

        t = threading.Thread(target=fg)
        t.start()
        time.sleep(0.05)
        gate.set()                           # release the delayed read
        t.join(timeout=10.0)
        assert not t.is_alive()
        assert np.array_equal(got["v"], _page(0))
        # exactly ONE disk read happened (the engine's — counted as the
        # miss); the waiting foreground get was served from DRAM
        assert pool.stats()["misses"] == 1
        assert pool.stats()["hits"] >= 1
    finally:
        gate.set()
        engine.close()


def test_eviction_skips_pages_with_inflight_io(tmp_path, monkeypatch):
    """Pin-aware scheduling: a page mid-transfer is never an eviction
    victim — room is made from other pages and eviction never blocks on
    the in-flight I/O."""
    pool, engine = _engine_pool(tmp_path, budget_pages=3)
    try:
        for i in range(3):
            pool.put(i, _page(i))
        pool.flush()                         # all clean, all resident
        gate = threading.Event()
        orig = SpillSlot.store

        def slow_store(self, arr):
            gate.wait(timeout=10.0)
            return orig(self, arr)

        pool.get(0)[...] = 7.0
        pool.mark_dirty(0)
        monkeypatch.setattr(SpillSlot, "store", slow_store)
        engine.clean_ahead(limit=1)          # write of page 0 in flight
        time.sleep(0.05)
        monkeypatch.setattr(SpillSlot, "store", orig)
        pool.put(3, _page(3))                # needs an eviction NOW
        assert pool.page(0).resident         # io-busy page was skipped
        assert pool.page(3).resident
        gate.set()
        engine.drain()
    finally:
        gate.set()
        engine.close()


def test_tiered_store_readahead_noop_without_engine(tmp_path):
    store = TieredStore(n_sp=2, disk_dir=tmp_path, io_threads=0)
    store.register("a", np.zeros((4, 8), np.float32))
    assert store.readahead([("a", 0)]) == 0
    assert "io_reads" not in store.stats()
    store.close()
    store2 = TieredStore(n_sp=2, budget_bytes=64 * 1024,
                         disk_dir=tmp_path, io_threads=1)
    store2.register("a", np.zeros((4, 8), np.float32))
    assert "io_reads" in store2.stats()
    iv = store2.take_interval()
    assert "io_queue_depth_peak" in iv
    store2.close()
