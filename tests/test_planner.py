"""The adaptive cost-based plan optimizer: cost-model rankings, plan-space
pruning, message-layout migration, and end-to-end adaptive runs."""
import numpy as np
import pytest

from repro.core import (PhysicalPlan, VertexProgram, gather_values,
                        load_graph, run_host, run_jit)
from repro.graph import SSSP, PageRank
from repro.graph.generators import grid_graph
from repro.planner import (AdaptiveConfig, GraphStats, Observation,
                           StatsCollector, choose, estimate, migrate_msgs,
                           plan_space, rank)

WEB = GraphStats(n_vertices=100_000, n_edges=800_000, n_partitions=8,
                 vertex_capacity=16_250, edge_capacity=100_000,
                 value_dims=1, msg_dims=1)


def _join_cost(join, density):
    plan = PhysicalPlan(join=join)
    return estimate(plan, WEB, Observation(frontier_density=density)) \
        .seconds()


def test_cost_ranks_left_outer_below_full_once_sparse():
    """The paper's Figure 14 regime: full-outer wins message-dense,
    left-outer wins once the frontier collapses."""
    assert _join_cost("full_outer", 1.0) <= _join_cost("left_outer", 1.0)
    assert _join_cost("left_outer", 0.01) < _join_cost("full_outer", 0.01)
    # full-outer's cost is density-independent (it always scans all slots);
    # left-outer's falls with the frontier
    assert _join_cost("left_outer", 0.01) < _join_cost("left_outer", 1.0)


def test_choose_switches_join_with_density():
    sssp = SSSP(source=0)
    dense, _ = choose(sssp, WEB, Observation(frontier_density=1.0))
    sparse, _ = choose(sssp, WEB, Observation(frontier_density=0.01))
    assert dense.join == "full_outer"
    assert sparse.join == "left_outer"


class _CustomCombine(VertexProgram):
    combine_op = "custom"

    def combine(self, a, b):
        return a + b


def test_optimizer_rejects_invalid_combos():
    """scatter group-by + custom combine is pruned from the space."""
    prog = _CustomCombine()
    plans = list(plan_space(prog))
    assert plans and all(p.groupby == "sort" for p in plans)
    plan, _ = choose(prog, WEB, Observation())
    plan.validate(prog.combine_op)  # must not raise
    # restricting the space to the invalid combo is an error, not a pick
    with pytest.raises(ValueError):
        choose(prog, WEB, Observation(), groupbys=("scatter",))


def test_rank_is_sorted_and_covers_space():
    pr = PageRank(100_000)
    ranked = rank(pr, WEB, Observation(frontier_density=1.0))
    assert len(ranked) == 16   # 2 joins x 2 group-bys x 2 conns x 2 sc
    secs = [c.seconds() for _, c in ranked]
    assert secs == sorted(secs)


def test_storage_dimension_defaults_inherited_and_ooc_doubles_space():
    """In-memory spaces inherit the base storage (write-back never paid,
    so varying it only makes ties); storages=STORAGES doubles the space."""
    from repro.core import STORAGES
    pr = PageRank(100_000)
    assert len(list(plan_space(pr))) == 16
    both = list(plan_space(pr, storages=STORAGES))
    assert len(both) == 32
    assert {p.storage for p in both} == {"inplace", "delta"}


def test_storage_cost_follows_measured_change_density():
    """The storage_writeback term prices delta by the measured
    delta/full byte ratio: sparse updates favor delta, dense inplace —
    and without ooc the policies tie (no write-back crosses the link)."""
    inplace = PhysicalPlan(storage="inplace")
    delta = PhysicalPlan(storage="delta")
    sparse = Observation(ooc=True, change_density=0.01)
    dense = Observation(ooc=True, change_density=1.0)
    assert estimate(delta, WEB, sparse).seconds() < \
        estimate(inplace, WEB, sparse).seconds()
    assert estimate(inplace, WEB, dense).seconds() < \
        estimate(delta, WEB, dense).seconds()
    in_mem = Observation(change_density=0.01)
    assert estimate(delta, WEB, in_mem).seconds() == \
        estimate(inplace, WEB, in_mem).seconds()
    # the write-back term lives on the device<->host link
    assert estimate(inplace, WEB, sparse).host_bytes > 0
    assert estimate(inplace, WEB, in_mem).host_bytes == 0


def test_streaming_observation_prices_with_overlap():
    """Under the pipelined OOC executor the host link overlaps compute:
    the model prices the superstep as a CRITICAL PATH — max(device,
    host) plus the serial inter-superstep readiness leg (the inbox
    rebuild nothing overlaps) — instead of the plain sum, so streaming
    cost is never above synchronous cost and is strictly below it
    whenever both sides are non-trivial."""
    plan = PhysicalPlan()
    sync = estimate(plan, WEB, Observation(ooc=True))
    strm = estimate(plan, WEB, Observation(ooc=True, streaming=True))
    assert not sync.overlap_host and strm.overlap_host
    # identical traffic, different composition rule
    assert strm.host_bytes == sync.host_bytes
    assert strm.bytes == sync.bytes
    assert strm.serial_seconds == sync.serial_seconds > 0
    assert strm.seconds() < sync.seconds()
    dev, hst = strm.device_seconds(), strm.host_seconds()
    assert strm.seconds() == pytest.approx(
        max(dev, hst) + strm.serial_seconds, rel=0.01)
    # in-memory observations are untouched by the streaming flag
    mem = estimate(plan, WEB, Observation(streaming=True))
    assert not mem.overlap_host and mem.host_bytes == 0
    assert mem.serial_seconds == 0


def test_barrier_free_shrinks_the_serial_readiness_leg():
    """barrier_free keeps only the first destination's share of the
    inbox rebuild on the serial path (1/super_partitions); the barrier
    executor pays all of it — so the model prefers the barrier-free
    schedule and scales its advantage with the super-partition count."""
    plan = PhysicalPlan()
    bar = estimate(plan, WEB, Observation(ooc=True, streaming=True,
                                          super_partitions=4))
    bf4 = estimate(plan, WEB, Observation(ooc=True, streaming=True,
                                          barrier_free=True,
                                          super_partitions=4))
    bf8 = estimate(plan, WEB, Observation(ooc=True, streaming=True,
                                          barrier_free=True,
                                          super_partitions=8))
    assert bf4.serial_seconds == pytest.approx(bar.serial_seconds / 4)
    assert bf8.serial_seconds < bf4.serial_seconds < bar.serial_seconds
    assert bf4.seconds() < bar.seconds()
    assert "inbox_rebuild" in bar.terms


def test_ooc_stream_io_prices_the_super_partition_traffic():
    """OOC observations charge the host link for the vertex/edge block
    and message-bucket round trip, not just the value write-back."""
    plan = PhysicalPlan()
    ooc = estimate(plan, WEB, Observation(ooc=True))
    assert "stream_io" in ooc.terms and ooc.terms["stream_io"] > 0
    assert ooc.host_bytes > estimate(
        plan, WEB, Observation()).host_bytes == 0


def test_calibrate_machine_refits_constants_from_hlo():
    """One-shot startup calibration: the fitted constants come back
    finite, inside their clamp ranges, cached per backend, and the
    calibrated machine still ranks plans (sanity: left-outer wins sparse
    frontiers)."""
    from repro.planner import (DEFAULT_MACHINE, calibrate_machine, choose)
    from repro.planner.cost import _CALIBRATED
    small = GraphStats(n_vertices=192, n_edges=960, n_partitions=4,
                       vertex_capacity=64, edge_capacity=256,
                       value_dims=1, msg_dims=1)
    prog = SSSP(source=0)
    _CALIBRATED.clear()
    m = calibrate_machine(prog, small, DEFAULT_MACHINE)
    assert 0.5 <= m.k_compute <= 128.0
    assert 1.0 <= m.k_scatter <= 64.0
    assert 0.02 <= m.sort_pass_frac <= 4.0
    # cached: a second call must not refit (and must agree)
    m2 = calibrate_machine(prog, small, DEFAULT_MACHINE)
    assert (m2.k_compute, m2.k_scatter, m2.sort_pass_frac) == \
        (m.k_compute, m.k_scatter, m.sort_pass_frac)
    assert len(_CALIBRATED) == 1
    sparse, _ = choose(prog, WEB, Observation(frontier_density=0.01),
                       machine=m)
    assert sparse.join == "left_outer"


def test_run_host_auto_with_calibration_matches_static():
    """AdaptiveConfig(calibrate=True) wires the one-shot calibration into
    _resolve_plan; the run must still be exact."""
    side = 12
    edges = grid_graph(side)
    n = side * side
    prog = SSSP(source=0)
    static = run_host(load_graph(edges, n, P=4, value_dims=1), prog,
                      prog.suggested_plan, max_supersteps=60)
    auto = run_host(load_graph(edges, n, P=4, value_dims=1), prog, "auto",
                    max_supersteps=60,
                    auto_config=AdaptiveConfig(calibrate=True))
    assert np.array_equal(gather_values(auto.vertex, n),
                          gather_values(static.vertex, n))


def test_choose_switches_storage_with_change_density():
    from repro.core import STORAGES
    sssp = SSSP(source=0)
    sparse, _ = choose(sssp, WEB,
                       Observation(ooc=True, change_density=0.01,
                                   frontier_density=0.05),
                       storages=STORAGES)
    dense, _ = choose(PageRank(100_000), WEB,
                      Observation(ooc=True, change_density=1.0,
                                  frontier_density=1.0),
                      storages=STORAGES)
    assert sparse.storage == "delta"
    assert dense.storage == "inplace"


def test_controller_reads_change_density_from_stats_extra():
    """The OOC driver annotates records with ooc/change_density; the
    controller must surface them into the Observation it plans with.
    Planned on the EMULATED machine (host link = memcpy), like the real
    emulated-transport OOC driver: on a PCIe-class host link the
    stream_io term correctly makes synchronous OOC transfer-bound, which
    mutes per-plan differences below the switch margin."""
    from repro.core import STORAGES
    from repro.planner import EMULATED_MACHINE, AdaptiveController
    sssp = SSSP(source=0)
    plan, _ = choose(sssp, WEB, Observation(frontier_density=1.0, ooc=True),
                     machine=EMULATED_MACHINE, storages=STORAGES)
    ctl = AdaptiveController(sssp, WEB, plan,
                             AdaptiveConfig(patience=1, cooldown=0),
                             machine=EMULATED_MACHINE,
                             space_kw={"storages": STORAGES})
    coll = StatsCollector(n_partitions=WEB.n_partitions,
                          vertex_capacity=WEB.vertex_capacity,
                          msg_dims=WEB.msg_dims)
    total = WEB.n_partitions * WEB.vertex_capacity
    rec = coll.record(2, active=total // 100, messages=10, wall_s=0.0,
                      ooc=True, change_density=0.01)
    switched = ctl.observe(rec)
    assert switched is not None
    assert switched.storage == "delta"


def test_migrate_msgs_sorts_runs_for_merging_receiver():
    import jax.numpy as jnp

    from repro.core.relations import MsgRel
    rng = np.random.default_rng(0)
    P, n_parts, C, D = 2, 4, 8, 1
    dst = rng.integers(0, 100, (P, n_parts * C)).astype(np.int32)
    valid = rng.random((P, n_parts * C)) > 0.3
    pay = dst[..., None].astype(np.float32)   # payload tracks its dst
    msg = MsgRel(dst=jnp.asarray(np.where(valid, dst, -1)),
                 payload=jnp.asarray(np.where(valid[..., None], pay, 0.0)),
                 valid=jnp.asarray(valid))
    old = PhysicalPlan(connector="partitioning", sender_combine=False)
    new = PhysicalPlan(connector="partitioning_merging")
    out = migrate_msgs(msg, old, new, n_parts)
    od = np.asarray(out.dst).reshape(P, n_parts, C)
    ov = np.asarray(out.valid).reshape(P, n_parts, C)
    op = np.asarray(out.payload).reshape(P, n_parts, C, D)
    for p in range(P):
        for r in range(n_parts):
            d, v = od[p, r], ov[p, r]
            assert (np.diff(d[v]) >= 0).all()        # runs dst-ascending
            assert (op[p, r][v, 0] == d[v]).all()    # payload follows dst
    # same multiset of live messages
    assert sorted(np.asarray(msg.dst)[np.asarray(msg.valid)]) == \
        sorted(od[ov])
    # no-op when the stream is already dst-sorted (sender combine on)
    sorted_old = PhysicalPlan(connector="partitioning", sender_combine=True)
    same = migrate_msgs(msg, sorted_old, new, n_parts)
    assert same is msg


def test_stats_collector_record_and_events():
    coll = StatsCollector(n_partitions=4, vertex_capacity=100, msg_dims=2)
    rec = coll.record(1, active=40, messages=10, wall_s=0.5)
    assert rec.frontier_density == pytest.approx(0.1)
    assert rec.bytes_exchanged == 10 * (4 + 8 + 1)
    coll.event(1, "plan-switch", join="left_outer")
    assert len(coll.supersteps()) == 1 and len(coll.records) == 2
    d = coll.records[-1].as_dict()
    assert d == {"superstep": 1, "event": "plan-switch",
                 "join": "left_outer"}


def test_adaptive_sssp_matches_static_and_switches():
    """Acceptance: plan="auto" SSSP equals the best static plan
    vertex-for-vertex and performs >=1 mid-run plan adaptation."""
    side = 40
    edges = grid_graph(side)
    n = side * side
    prog = SSSP(source=0)
    static = run_host(load_graph(edges, n, P=4, value_dims=1), prog,
                      prog.suggested_plan, max_supersteps=100)
    auto = run_host(load_graph(edges, n, P=4, value_dims=1), prog,
                    "auto", max_supersteps=100)
    d_static = gather_values(static.vertex, n)[:, 0]
    d_auto = gather_values(auto.vertex, n)[:, 0]
    assert np.array_equal(d_static, d_auto)
    switches = [s for s in auto.stats if s.get("event") == "plan-switch"]
    assert len(switches) >= 1
    # the high-diameter lattice collapses to a sparse frontier: the
    # adaptation must land on the paper's Figure 9 SSSP hint
    assert auto.plan.join == "left_outer"
    assert auto.supersteps == static.supersteps


def test_run_jit_auto_resolves_statically():
    side = 16
    edges = grid_graph(side)
    n = side * side
    prog = SSSP(source=0)
    auto = run_jit(load_graph(edges, n, P=4, value_dims=1), prog, "auto",
                   max_supersteps=40)
    static = run_host(load_graph(edges, n, P=4, value_dims=1), prog,
                      prog.suggested_plan, max_supersteps=40)
    assert np.array_equal(gather_values(auto.vertex, n),
                          gather_values(static.vertex, n))
    assert auto.plan is not None   # resolved to a concrete plan


def test_run_host_rejects_unknown_plan_string():
    side = 8
    edges = grid_graph(side)
    vert = load_graph(edges, side * side, P=2, value_dims=1)
    with pytest.raises(ValueError):
        run_host(vert, SSSP(source=0), "fastest")


def test_adaptive_controller_hysteresis():
    """No thrash: a one-superstep density dip must not trigger a switch
    with patience=2; a sustained dip must."""
    from repro.planner import AdaptiveController
    sssp = SSSP(source=0)
    plan, _ = choose(sssp, WEB, Observation(frontier_density=1.0))
    ctl = AdaptiveController(sssp, WEB, plan,
                             AdaptiveConfig(patience=2, cooldown=1))
    coll = StatsCollector(n_partitions=WEB.n_partitions,
                          vertex_capacity=WEB.vertex_capacity,
                          msg_dims=WEB.msg_dims)
    total = WEB.n_partitions * WEB.vertex_capacity
    blip = coll.record(1, active=total // 100, messages=10, wall_s=0.0)
    assert ctl.observe(blip) is None           # first sparse sighting
    dense = coll.record(2, active=total, messages=total, wall_s=0.0)
    assert ctl.observe(dense) is None          # streak reset
    s3 = coll.record(3, active=total // 100, messages=10, wall_s=0.0)
    assert ctl.observe(s3) is None
    s4 = coll.record(4, active=total // 100, messages=10, wall_s=0.0)
    switched = ctl.observe(s4)                 # sustained -> switch
    assert switched is not None and switched.join == "left_outer"
    assert ctl.switches and ctl.plan == switched
