"""Observability layer suite (span tracing, metrics, Chrome export).

Covers the ISSUE-6 acceptance criteria: concurrent span recording from
multiple threads while an export is in flight, trace-event JSON schema
validation (positive and negative), counter/gauge/histogram semantics,
the overhead guard for disabled tracing (the hot-path instrumentation
must allocate nothing when no tracer is active), the measured
readiness-stall EWMA -> ``Observation.serial_scale`` -> ``PlanCost``
closure, adaptive readahead pacing, and an end-to-end traced disk-tier
run whose timeline must show the dispatcher/collector main thread plus
both I/O-engine workers.
"""
import dataclasses
import json
import threading

import pytest

from repro.core import PhysicalPlan, load_graph
from repro.core.ooc import run_out_of_core
from repro.graph import PageRank, rmat_graph
from repro.obs import trace
from repro.obs.export import (chrome_trace, validate_chrome_trace,
                              write_chrome_trace)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               percentile)
from repro.obs.progress import fmt_plan, progress_line
from repro.planner import GraphStats, estimate
from repro.planner.adaptive import AdaptiveController
from repro.planner.stats import StatsCollector, SuperstepStats
from repro.storage.io_engine import IOEngine


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    """Every test starts and ends with tracing disabled — a tracer
    leaked across tests would defeat the overhead guard."""
    trace.stop()
    yield
    trace.stop()


# ------------------------------------------------------ overhead guard

def test_disabled_tracing_allocates_nothing():
    """With no active tracer every span() call returns the SAME cached
    no-op singleton (no per-call allocation on the hot path) and no
    event is buffered anywhere."""
    assert not trace.enabled()
    s1 = trace.span("a", "compute")
    s2 = trace.span("b", "dispatch")
    assert s1 is s2                       # the cached _NULL singleton
    assert trace.annotate("c") is s1
    with s1:
        pass                              # and it is a working no-op CM
    # the fire-and-forget paths are plain early returns
    assert trace.complete("x", "commit", 0.0, 1.0) is None
    assert trace.instant("y", "replan") is None
    assert trace.counter("z", 3) is None
    assert trace.get() is None


def test_stop_detaches_and_disables():
    t = trace.start()
    with trace.span("work", "compute"):
        pass
    assert trace.stop() is t
    assert not trace.enabled()
    assert trace.span("late", "compute") is trace.span("later", "commit")
    assert t.n_events() == 1              # the detached buffer survives


# ------------------------------------------- recording + export schema

def test_span_events_round_trip_to_chrome_json(tmp_path):
    tr = trace.start()
    with trace.span("outer", "commit", q=2):
        with trace.span("inner", "fault"):
            pass
    trace.instant("mark", "replan", superstep=3)
    trace.counter("depth", 5)
    tracer = trace.stop()
    assert tracer is tr
    obj = chrome_trace(tracer)
    summary = validate_chrome_trace(obj)
    assert summary["spans"] == 2
    assert summary["span_threads"] == 1
    assert set(summary["categories"]) == {"commit", "fault"}
    by_name = {e["name"]: e for e in obj["traceEvents"]}
    assert by_name["outer"]["ph"] == "X"
    assert by_name["outer"]["args"] == {"q": 2}
    assert by_name["inner"]["dur"] <= by_name["outer"]["dur"]
    assert by_name["mark"]["ph"] == "i"
    assert by_name["depth"]["ph"] == "C"
    assert by_name["depth"]["args"]["value"] == 5
    assert all(e.get("ts", 0) >= 0 for e in obj["traceEvents"])
    # file writer emits loadable JSON and the CLI validator accepts it
    p = tmp_path / "trace.json"
    trace.start()
    with trace.span("w", "compute"):
        pass
    write_chrome_trace(str(p))
    reloaded = json.loads(p.read_text())
    assert validate_chrome_trace(reloaded)["spans"] == 1
    from repro.obs.export import main as export_main
    assert export_main([str(p), "--min-threads", "1"]) == 0


def test_explicit_time_complete_spans():
    trace.start()
    trace.complete("stall", "dispatch", 10.0, 10.25, q=1)
    trace.complete("inverted", "commit", 5.0, 4.0)  # clamped, not negative
    tracer = trace.stop()
    events = [ev for _, _, evs in tracer.drain() for ev in evs]
    spans = {e[1]: e for e in events if e[0] == "X"}
    assert spans["stall"][3] == 10.0
    assert spans["stall"][4] == pytest.approx(0.25)
    assert spans["inverted"][4] == 0.0
    validate_chrome_trace(chrome_trace(tracer))


def test_concurrent_recording_while_exporting():
    """N worker threads record spans while the main thread repeatedly
    exports; nothing is lost and every thread gets its own track."""
    n_threads, per_thread = 4, 200
    trace.start()
    # keep all workers alive until everyone recorded: OS thread idents
    # are reused after exit, which would merge tracks in the export
    gate = threading.Barrier(n_threads + 1)

    def worker(k):
        gate.wait()
        for _ in range(per_thread):
            with trace.span(f"w{k}", "readahead"):
                pass
        gate.wait()

    threads = [threading.Thread(target=worker, args=(k,), daemon=True)
               for k in range(n_threads)]
    for th in threads:
        th.start()
    gate.wait()
    # export concurrently with recording — must never raise (the first
    # snapshots may race ahead of any span, hence min_threads=0)
    for _ in range(20):
        validate_chrome_trace(chrome_trace(trace.get()), min_threads=0)
    gate.wait()
    for th in threads:
        th.join()
    tracer = trace.stop()
    obj = chrome_trace(tracer)
    summary = validate_chrome_trace(obj, min_threads=n_threads)
    assert summary["spans"] == n_threads * per_thread
    assert summary["span_threads"] == n_threads


def test_schema_validation_rejects_malformed_traces():
    with pytest.raises(ValueError, match="top level"):
        validate_chrome_trace([])
    with pytest.raises(ValueError, match="must be a list"):
        validate_chrome_trace({"traceEvents": {}})
    ok = {"ph": "X", "name": "s", "cat": "compute", "pid": 1, "tid": 1,
          "ts": 0.0, "dur": 1.0}
    with pytest.raises(ValueError, match="unknown phase"):
        validate_chrome_trace({"traceEvents": [{**ok, "ph": "Z"}]})
    bad = dict(ok)
    del bad["tid"]
    with pytest.raises(ValueError, match="missing name/pid/tid"):
        validate_chrome_trace({"traceEvents": [bad]})
    with pytest.raises(ValueError, match="unknown category"):
        validate_chrome_trace({"traceEvents": [{**ok, "cat": "nonsense"}]})
    with pytest.raises(ValueError, match="bad ts"):
        validate_chrome_trace({"traceEvents": [{**ok, "ts": -1.0}]})
    with pytest.raises(ValueError, match="bad dur"):
        validate_chrome_trace({"traceEvents": [{**ok, "dur": None}]})
    with pytest.raises(ValueError, match="need >= 2"):
        validate_chrome_trace({"traceEvents": [ok]}, min_threads=2)
    # and the valid event passes
    assert validate_chrome_trace({"traceEvents": [ok]})["spans"] == 1


def test_export_cli_lists_every_violation(tmp_path, capsys):
    """The --validate CLI collects ALL schema violations in one run and
    exits nonzero — CI logs show every problem at once, not just the
    first raise."""
    from repro.obs.export import main as export_main, trace_violations
    ok = {"ph": "X", "name": "s", "cat": "compute", "pid": 1, "tid": 1,
          "ts": 0.0, "dur": 1.0}
    broken = {"traceEvents": [
        {**ok, "ph": "Z"},                      # unknown phase
        {k: v for k, v in ok.items() if k != "tid"},  # missing tid
        {**ok, "cat": "nonsense"},              # unknown category
        {**ok, "ts": -1.0},                     # bad ts
        {**ok, "dur": None},                    # bad dur
    ]}
    errs, summary = trace_violations(broken)
    assert len(errs) == 5
    # same scan order as the raise-first validator: the first collected
    # violation IS the one validate_chrome_trace raises
    with pytest.raises(ValueError, match="unknown phase"):
        validate_chrome_trace(broken)
    assert "unknown phase" in errs[0]
    assert summary["events"] == 5
    p = tmp_path / "broken.json"
    p.write_text(json.dumps(broken))
    assert export_main([str(p)]) == 1
    out = capsys.readouterr().out
    assert "INVALID" in out and "5 violation(s)" in out
    for needle in ("unknown phase", "missing name/pid/tid",
                   "unknown category", "bad ts", "bad dur"):
        assert needle in out


# -------------------------------------------------------------- metrics

def test_counter_interval_is_a_delta():
    c = Counter()
    c.inc(3)
    assert c.interval() == 3
    assert c.interval() == 0              # nothing new since the mark
    c.inc(2)
    assert c.snapshot() == 5              # snapshot stays cumulative
    assert c.interval() == 2


def test_gauge_reports_last_level():
    g = Gauge()
    g.set(7)
    assert g.interval() == 7.0
    assert g.snapshot() == 7.0
    assert g.interval() == 7.0            # interval does not reset a level


def test_histogram_percentiles_and_reset():
    h = Histogram()
    for v in range(1, 11):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 10
    assert snap["mean"] == pytest.approx(5.5)
    assert snap["p50"] in (5.0, 6.0)
    assert snap["p90"] in (9.0, 10.0)
    assert snap["max"] == 10.0
    first = h.interval()                  # same numbers, then resets
    assert first == snap
    assert h.interval()["count"] == 0
    # bounded reservoir: overflow still counts, percentiles stay sane
    small = Histogram(cap=8)
    for v in range(100):
        small.observe(v)
    s = small.interval()
    assert s["count"] == 100 and s["max"] == 99.0


def test_percentile_nearest_rank():
    assert percentile([], 0.5) == 0.0
    assert percentile([4.0], 0.9) == 4.0
    assert percentile([1.0, 2.0, 3.0], 0.0) == 1.0
    assert percentile([1.0, 2.0, 3.0], 1.0) == 3.0


def test_registry_get_or_create_and_interval_merge():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.histogram("h") is reg.histogram("h")
    reg.counter("a").inc(4)
    reg.gauge("g").set(2)
    reg.histogram("h").observe(9)
    view = reg.interval()
    assert view["a"] == 4 and view["g"] == 2.0
    assert view["h"]["count"] == 1 and view["h"]["max"] == 9.0
    assert reg.interval()["a"] == 0       # counters/hists reset per call
    # snapshot is the non-destructive cumulative view
    reg.counter("a").inc(1)
    assert reg.snapshot()["a"] == 5
    assert reg.snapshot()["a"] == 5
    assert MetricsRegistry().interval() == {}


def test_stats_collector_merges_registry_interval():
    reg = MetricsRegistry()
    sc = StatsCollector(n_partitions=4, vertex_capacity=16, msg_dims=1,
                        n_vertices=40, metrics=reg)
    reg.counter("io.reads").inc(5)
    rec = sc.record(0, active=10, messages=3, wall_s=0.01)
    assert rec.extra["metrics"]["io.reads"] == 5
    rec2 = sc.record(1, active=10, messages=3, wall_s=0.01)
    assert rec2.extra["metrics"]["io.reads"] == 0   # per-superstep delta
    assert rec.as_dict()["metrics"]["io.reads"] == 5


# -------------------------- satellite 1: measured stall -> plan pricing

_G = GraphStats(n_vertices=100_000, n_edges=800_000, n_partitions=8,
                vertex_capacity=16_250, edge_capacity=100_000,
                value_dims=2, msg_dims=1)


def _stall_rec(stall_s, *, superstep=5, recompiled=False):
    return SuperstepStats(
        superstep=superstep, active=100_000, messages=400_000,
        frontier_density=1.0, wall_s=0.01, recompiled=recompiled,
        extra={"ooc": True, "streaming": True, "barrier_free": True,
               "super_partitions": 4, "readiness_stall_s": stall_s})


def test_measured_stall_scales_the_serial_plan_leg():
    """Observation -> PlanCost closure: the EWMA'd measured stall shifts
    every candidate's serial inbox-rebuild price by the measured/analytic
    ratio (the ISSUE-6 'planner's serial-leg price demonstrably shifts'
    criterion)."""
    plan = PhysicalPlan(join="full_outer")
    ctrl = AdaptiveController(PageRank(_G.n_vertices, iterations=5),
                              _G, plan)
    # analytic serial leg of the current plan, no measurement yet
    base_obs = ctrl._make_observation(_stall_rec(0.0))
    assert base_obs.serial_scale == 1.0 and base_obs.stall_ewma_s < 0.0
    base = estimate(plan, _G, base_obs, ctrl.machine)
    assert base.serial_seconds > 0.0
    # observe a stall 3x the analytic estimate
    rec = _stall_rec(3.0 * base.serial_seconds)
    ctrl._update_stall_ewma(rec)
    assert ctrl._stall_ewma == pytest.approx(3.0 * base.serial_seconds)
    obs = ctrl._make_observation(rec)
    assert obs.serial_scale == pytest.approx(3.0, rel=1e-6)
    assert obs.stall_ewma_s == pytest.approx(ctrl._stall_ewma)
    scaled = estimate(plan, _G, obs, ctrl.machine)
    assert scaled.serial_seconds == pytest.approx(3.0 * base.serial_seconds)
    assert scaled.terms["inbox_rebuild"] == pytest.approx(
        3.0 * base.terms["inbox_rebuild"])
    # the scale is plan-INDEPENDENT: a 4-way barrier-free candidate keeps
    # its 1/4 analytic advantage under the measured multiplier
    bf1 = dataclasses.replace(obs, barrier_free=False, super_partitions=1)
    assert estimate(plan, _G, bf1, ctrl.machine).serial_seconds == \
        pytest.approx(4.0 * scaled.serial_seconds)


def test_stall_ewma_smooths_and_skips_recompiles():
    ctrl = AdaptiveController(PageRank(_G.n_vertices, iterations=5),
                              _G, PhysicalPlan(join="full_outer"))
    ctrl._update_stall_ewma(_stall_rec(1.0))
    assert ctrl._stall_ewma == pytest.approx(1.0)
    # recompile supersteps are poisoned by jit time -> skipped
    ctrl._update_stall_ewma(_stall_rec(50.0, recompiled=True))
    assert ctrl._stall_ewma == pytest.approx(1.0)
    # in-memory records (no stall key) are skipped too
    ctrl._update_stall_ewma(SuperstepStats(superstep=6, wall_s=0.01))
    assert ctrl._stall_ewma == pytest.approx(1.0)
    ctrl._update_stall_ewma(_stall_rec(2.0))
    a = ctrl.config.stall_alpha
    assert ctrl._stall_ewma == pytest.approx(a * 2.0 + (1 - a) * 1.0)
    # the calibration multiplier is clamped against outliers
    ctrl._stall_ewma = 1e9
    obs = ctrl._make_observation(_stall_rec(1e9))
    assert obs.serial_scale == 8.0
    ctrl._stall_ewma = 1e-12
    obs = ctrl._make_observation(_stall_rec(1e-12))
    assert obs.serial_scale == 0.125
    # and it round-trips through the checkpointed controller state
    ctrl._stall_ewma = 0.5
    state = ctrl.state_dict()
    ctrl2 = AdaptiveController(PageRank(_G.n_vertices, iterations=5),
                               _G, PhysicalPlan(join="full_outer"))
    ctrl2.load_state(state)
    assert ctrl2._stall_ewma == pytest.approx(0.5)


# ------------------------- satellite 1b: adaptive readahead pacing

class _DummyPool:
    def wants_prefetch(self, key):
        return False

    def dirty_eviction_candidates(self, limit):
        return []


def test_autopace_matches_faults_to_the_compute_window():
    eng = IOEngine(_DummyPool(), threads=1, readahead_pages=8)
    try:
        assert eng.readahead_pages == 8   # starts at the ceiling
        # 4 faults in 40ms -> 10ms/fault; a 50ms compute window hides 5
        with eng._mu:
            eng._int_reads, eng._int_read_s = 4, 0.040
        assert eng.autopace(0.050) == 5
        # deep window -> clamped at the configured ceiling
        with eng._mu:
            eng._int_reads, eng._int_read_s = 4, 0.040
        assert eng.autopace(10.0) == 8
        # compute window shorter than one fault -> floor of 1
        with eng._mu:
            eng._int_reads, eng._int_read_s = 4, 0.040
        assert eng.autopace(0.001) == 1
        # no faults observed this superstep -> depth unchanged
        assert eng.autopace(1.0) == 1
        # the sample is consumed: a second call sees no data
        with eng._mu:
            eng._int_reads, eng._int_read_s = 2, 0.002
        eng.autopace(0.010)
        assert eng.autopace(10.0) == eng.readahead_pages
    finally:
        eng.close()


# ------------------------------------------------------- progress lines

def test_progress_line_formats_the_record():
    rec = {"superstep": 7, "active": 12_400, "frontier_density": 0.19,
           "messages": 48_200, "wall_s": 0.031, "cache_hit_rate": 0.97,
           "readiness_stall_s": 0.0021, "readahead_depth": 4}
    line = progress_line(rec, PhysicalPlan(join="left_outer"))
    assert "superstep   7" in line
    assert "active 12.4k (19.0%)" in line
    assert "msgs 48.2k" in line and "wall 0.031s" in line
    assert "hit 0.97" in line and "stall 2.1ms" in line
    assert "ra 4" in line
    assert "plan left_outer/" in line
    assert "recompile" not in line
    # omitted fields simply drop out; events/recompiles are flagged
    assert "hit" not in progress_line({"superstep": 0, "active": 5,
                                       "wall_s": 0.1})
    assert "[recompile]" in progress_line({"superstep": 0, "active": 5,
                                           "wall_s": 0.1,
                                           "recompiled": True})
    assert "[plan-switch]" in progress_line({"superstep": 3,
                                             "event": "plan-switch"})
    assert fmt_plan(None) == ""


def test_progress_line_shows_sharded_exchange_extras():
    """The PR 8 sharded extras render SI-formatted when present and
    drop out otherwise."""
    rec = {"superstep": 2, "active": 220, "messages": 1200,
           "wall_s": 0.01, "exchange_stall_s": 0.0042,
           "exchange_bytes": 1_300_000}
    line = progress_line(rec)
    assert "xstall 4.2ms" in line
    assert "xbytes 1.3M" in line
    bare = progress_line({"superstep": 2, "active": 220, "wall_s": 0.01})
    assert "xstall" not in bare and "xbytes" not in bare


# --------------------------------------------- end-to-end traced run

def test_traced_disk_tier_run_shows_all_pipeline_threads(tmp_path):
    """The acceptance criterion: a barrier-free disk-tier run with
    tracing on yields a valid Chrome trace with spans from the
    dispatcher/collector main thread and BOTH io-engine workers, the
    readiness stall visible as a span, and queue-depth percentiles +
    registry metrics in the per-superstep stats."""
    n = 220
    edges = rmat_graph(n, 1200, seed=7)
    prog = PageRank(n, iterations=6)
    vert = load_graph(edges, n, P=4, value_dims=2)
    progress = []
    trace.start()
    try:
        res = run_out_of_core(
            vert, prog, prog.suggested_plan, budget_partitions=1,
            max_supersteps=8, stream=True, barrier_free=True,
            memory_budget_bytes=16 * 1024, disk_dir=str(tmp_path / "sp"),
            eviction="mru", io_threads=2,
            on_superstep=lambda i, rec: progress.append((i, rec)))
    finally:
        tracer = trace.stop()
    obj = chrome_trace(tracer)
    summary = validate_chrome_trace(obj, min_threads=3)
    assert summary["spans"] > 0
    assert any("pregelix-io" in nm for nm in summary["thread_names"])
    names = {e["name"] for e in obj["traceEvents"] if e["ph"] == "X"}
    assert {"dispatch", "commit", "collect_wait", "prepare", "fold",
            "superstep", "readiness_stall"} <= names
    assert "fault_bg" in names or "page_fault" in names
    cats = set(summary["categories"])
    assert {"dispatch", "compute", "collect", "commit"} <= cats
    # counter tracks for the Perfetto area charts
    counters = {e["name"] for e in obj["traceEvents"] if e["ph"] == "C"}
    assert {"active", "messages", "io_queue_depth"} <= counters
    # satellite 2: real within-superstep queue-depth percentiles
    recs = [s for s in res.stats if "wall_s" in s]
    assert recs
    for s in recs:
        assert s["io_queue_depth_p90"] >= s["io_queue_depth_p50"] >= 0
        assert s["io_queue_depth_max"] >= s["io_queue_depth_p90"]
        assert 1 <= s["readahead_depth"] <= 8
        assert s["metrics"]["io.queue_depth"]["count"] >= 0
    assert any(s["metrics"]["io.queue_depth"]["count"] > 0 for s in recs)
    # the on_superstep callback saw every superstep record, in order,
    # and the records render as progress lines
    assert [i for i, _ in progress] == [s["superstep"] for s in recs]
    for i, rec in progress:
        assert f"superstep {i:>3}" in progress_line(rec, res.plan)


def test_tracing_overhead_free_run_records_nothing():
    """A run WITHOUT trace.start() must leave the module disabled and
    buffer zero events (the instrumentation is permanently in the hot
    path, so this is the regression guard for its cost)."""
    n = 120
    edges = rmat_graph(n, 600, seed=3)
    prog = PageRank(n, iterations=4)
    vert = load_graph(edges, n, P=4, value_dims=2)
    assert not trace.enabled()
    res = run_out_of_core(vert, prog, prog.suggested_plan,
                          budget_partitions=2, max_supersteps=6)
    assert res.supersteps > 0
    assert trace.get() is None            # nothing got started implicitly
    with pytest.raises(ValueError):
        chrome_trace()                    # and there is nothing to export
