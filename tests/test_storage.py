"""Disk-tier storage subsystem suite.

Holds the new HBM ↔ DRAM ↔ disk hierarchy to the same standard as the
rest of the OOC path: the buffer cache (``storage/pager``) must provably
respect its DRAM byte budget, disk-tier runs must match the DRAM-only
path BIT-FOR-BIT (PageRank / SSSP / CC × eviction policy × streaming
on/off), regrows must work under memory pressure, the host mutation
inbox must route inserts across super-partitions exactly like the
in-memory exchange, and spill-file checkpoints must resume to identical
results.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ComputeOut, EngineConfig, PhysicalPlan,
                        VertexProgram, gather_values, load_graph,
                        run_host)
from repro.core.ooc import run_out_of_core
from repro.graph import SSSP, ConnectedComponents, PageRank, PathMerge, \
    chain_graph, rmat_graph
from repro.planner.cost import GraphStats, Observation, estimate
from repro.storage import BufferPool, SpillDir, TieredStore

N = 220
EDGES = rmat_graph(N, 1200, seed=7)
ALGOS = {
    "pagerank": (lambda: PageRank(N, iterations=6), 2),
    "sssp": (lambda: SSSP(source=3), 1),
    "cc": (lambda: ConnectedComponents(), 1),
}
_DRAM_REF = {}   # algo -> gathered values of the DRAM-only OOC run


# a DRAM budget well under the ~18 KiB test working set (relations +
# inbox generations): every spilling test below must actually page
_BUDGET = 16 * 1024


def _dram_ref(algo: str) -> np.ndarray:
    if algo not in _DRAM_REF:
        mk, vd = ALGOS[algo]
        prog = mk()
        vert = load_graph(EDGES, N, P=4, value_dims=vd)
        res = run_out_of_core(vert, prog, prog.suggested_plan,
                              budget_partitions=2, max_supersteps=30)
        _DRAM_REF[algo] = gather_values(res.vertex, N)
    return _DRAM_REF[algo]


# ---------------------------------------------------------------- pager

def _pg(i, kb=4):
    return np.full((kb * 256,), i, np.float32)   # kb KiB per page


def test_pool_budget_evicts_and_faults_back(tmp_path):
    pool = BufferPool(2 * _pg(0).nbytes, policy="lru",
                      spill=SpillDir(tmp_path))
    for i in range(3):
        pool.put(i, _pg(i))
    st = pool.stats()
    assert st["evictions"] >= 1
    assert st["resident_bytes"] <= pool.budget
    assert st["peak_resident_bytes"] <= pool.budget
    # evicted page faults back in, bit-for-bit
    assert np.array_equal(pool.get(0), _pg(0))
    assert pool.stats()["misses"] >= 1
    assert pool.stats()["spill_read_bytes"] > 0


def test_pool_lru_evicts_cold_mru_evicts_hot(tmp_path):
    for policy, victim in (("lru", 0), ("mru", 1)):
        pool = BufferPool(2 * _pg(0).nbytes, policy=policy,
                          spill=SpillDir(tmp_path / policy))
        pool.put(0, _pg(0))
        pool.put(1, _pg(1))
        pool.get(0), pool.get(1)       # recency order: 0 older than 1
        pool.put(2, _pg(2))
        assert not pool.page(victim).resident, policy
        assert pool.page(1 - victim).resident, policy


def test_mru_survives_cyclic_scan_lru_floods(tmp_path):
    """The superstep access pattern: cyclic sequential scan over a
    working set larger than the budget. LRU's hit rate collapses to 0;
    MRU retains a stable prefix and keeps hitting."""
    hits = {}
    for policy in ("lru", "mru"):
        pool = BufferPool(2 * _pg(0).nbytes, policy=policy,
                          spill=SpillDir(tmp_path / policy))
        for i in range(4):
            pool.put(i, _pg(i), dirty=True)
        pool.hits = pool.misses = 0
        for _ in range(3):
            for i in range(4):
                pool.get(i)
        hits[policy] = pool.hits
    assert hits["lru"] == 0
    assert hits["mru"] > 0


def test_pool_pinned_pages_never_evicted(tmp_path):
    pool = BufferPool(2 * _pg(0).nbytes, policy="lru",
                      spill=SpillDir(tmp_path))
    pool.put(0, _pg(0))
    pool.put(1, _pg(1))
    pool.pin(0)
    pool.put(2, _pg(2))          # must evict 1, not pinned 0
    assert pool.page(0).resident
    pool.pin(1)                  # faults 1 back, evicting 2
    with pytest.raises(RuntimeError, match="pinned working set"):
        pool.pin(2)              # both budgeted slots are pinned
    pool.unpin(0)
    pool.unpin(1)


def test_pool_budget_requires_spill_dir():
    with pytest.raises(ValueError, match="spill"):
        BufferPool(1024, policy="lru", spill=None)
    with pytest.raises(ValueError, match="policy"):
        BufferPool(None, policy="fifo")


def test_dirty_writeback_roundtrip_and_replacement_keeps_pins(tmp_path):
    pool = BufferPool(None, policy="lru", spill=SpillDir(tmp_path))
    pool.put("a", _pg(1))
    pool.pin("a")
    pool.put("a", _pg(2))        # full replacement under a pin
    pool.unpin("a")              # must not raise: pins survive put()
    pool.flush()
    pool.page("a").data = None   # simulate eviction
    assert np.array_equal(pool.get("a"), _pg(2))


def test_spillslot_hardlink_export_is_immutable(tmp_path):
    """Atomic page write-back (tmp + os.replace) makes hard-linked
    checkpoint exports safe: rewriting the page must not change the
    exported file."""
    sd = SpillDir(tmp_path / "run")
    slot = sd.slot_for(("page", 0))
    slot.store(_pg(1))
    out = tmp_path / "ckpt.npy"
    slot.export_to(out, allow_link=True)
    slot.store(_pg(9))           # atomic replace breaks the link
    assert np.array_equal(np.load(out), _pg(1))
    assert np.array_equal(slot.load(), _pg(9))


def test_tiered_store_roundtrip_under_pressure(tmp_path):
    rng = np.random.default_rng(0)
    arrs = {k: rng.random((8, 64)).astype(np.float32) for k in "abc"}
    store = TieredStore(n_sp=4, budget_bytes=3000, disk_dir=tmp_path,
                        policy="mru")
    for k, a in arrs.items():
        store.register(k, a)
    # full-chunk write + row-level delta write
    store.write("a", 1, np.ones((2, 64), np.float32))
    arrs["a"][2:4] = np.ones((2, 64))
    mask = np.zeros((2,), bool)
    mask[0] = True
    store.write_rows("b", 0, mask, np.full((1, 64), 7, np.float32))
    arrs["b"][0] = 7
    for k in arrs:
        assert np.array_equal(store.gather(k), arrs[k]), k
    assert store.stats()["spill_write_bytes"] > 0
    assert store.stats()["peak_resident_bytes"] <= 3000
    store.close()


# -------------------------------------------- disk-vs-DRAM parity suite

@pytest.mark.parametrize("algo", list(ALGOS))
@pytest.mark.parametrize("policy", ["lru", "mru"])
@pytest.mark.parametrize("streaming", [False, True])
def test_disk_tier_parity_bit_for_bit(algo, policy, streaming, tmp_path):
    """The disk tier only moves bytes: spilling through the buffer cache
    under a budget that forces page-outs must reproduce the DRAM-only
    run exactly, for every eviction policy and both executors."""
    mk, vd = ALGOS[algo]
    prog = mk()
    vert = load_graph(EDGES, N, P=4, value_dims=vd)
    budget = _BUDGET
    res = run_out_of_core(vert, prog, prog.suggested_plan,
                          budget_partitions=2, max_supersteps=30,
                          stream=streaming, memory_budget_bytes=budget,
                          disk_dir=tmp_path, eviction=policy)
    assert np.array_equal(gather_values(res.vertex, N), _dram_ref(algo))
    recs = [s for s in res.stats if "wall_s" in s]
    assert recs and all(s["spill"] for s in recs)
    # the budget actually bit: pages spilled and faulted back
    assert sum(s["spill_write_bytes"] for s in recs) > 0
    assert all(0.0 <= s["cache_hit_rate"] <= 1.0 for s in recs)


def test_pager_respects_memory_budget():
    """The acceptance bar: the pager's peak resident bytes never exceed
    memory_budget_bytes, asserted across every superstep of a spilling
    run."""
    import tempfile
    prog = PageRank(N, iterations=6)
    vert = load_graph(EDGES, N, P=4, value_dims=2)
    budget = _BUDGET
    with tempfile.TemporaryDirectory() as td:
        res = run_out_of_core(vert, prog, prog.suggested_plan,
                              budget_partitions=2, max_supersteps=10,
                              memory_budget_bytes=budget, disk_dir=td)
    recs = [s for s in res.stats if "pager_peak_bytes" in s]
    assert recs
    assert all(s["pager_peak_bytes"] <= budget for s in recs)
    assert any(s["spill_read_bytes"] > 0 for s in recs)


def test_regrow_with_spill_mid_run(tmp_path):
    """A bucket overflow while the store is spilling: the deferred
    regrow must end-pad the already-collected out pages THROUGH the
    pager and still match the in-memory reference exactly."""
    prog = SSSP(source=3)
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    budget = _BUDGET
    ec = EngineConfig(n_parts=4, bucket_cap=2,
                      frontier_cap=vert.capacity + 8)
    res = run_out_of_core(vert, prog, prog.suggested_plan,
                          budget_partitions=2, max_supersteps=30, ec=ec,
                          memory_budget_bytes=budget, disk_dir=tmp_path,
                          eviction="mru")
    regrows = [s for s in res.stats if s.get("event") == "regrow"]
    assert regrows and regrows[-1]["bucket_cap"] > 2
    ref = run_host(load_graph(EDGES, N, P=4, value_dims=1), prog,
                   prog.suggested_plan, max_supersteps=30)
    assert np.array_equal(gather_values(res.vertex, N),
                          gather_values(ref.vertex, N))
    assert any(s.get("spill_write_bytes", 0) > 0 for s in res.stats)


# ------------------------------------------------- host mutation inbox

class CrossInsert(VertexProgram):
    """Every vertex proposes, at superstep 0, an insert targeting
    (vid + shift) mod n — under hash partitioning always a DIFFERENT
    partition, and (for shift >= budget) frequently a different
    SUPER-partition. Values are small integers, so the resolve sum is
    float-exact and parity can be bit-for-bit."""

    value_dims = 1
    msg_dims = 1
    agg_dims = 1
    combine_op = "sum"
    mutates = True
    suggested_plan = PhysicalPlan(join="full_outer", groupby="scatter")

    def __init__(self, n: int, shift: int = 1):
        self.n = n
        self.shift = shift

    def init_value(self, vid, out_degree, gs):
        return jnp.where(vid >= 0, vid, 0).astype(jnp.float32)[..., None]

    def compute(self, vid, value, msg, has_msg, active, gs):
        first = gs.superstep == 0
        tgt = jnp.where(first & (vid >= 0),
                        (vid + self.shift) % self.n, -1)
        done = gs.superstep >= 1
        return ComputeOut(
            value=value,
            halt=jnp.broadcast_to(done | ~first, vid.shape),
            send_gate=jnp.zeros(vid.shape, bool),
            aggregate=jnp.zeros(vid.shape + (1,)),
            insert_vid=tgt,
            insert_value=jnp.where(vid >= 0, vid, 0)
            .astype(jnp.float32)[..., None] + 1000.0)

    def send(self, src_vid, src_value, edge_val, dst_vid, gs):
        return jnp.zeros_like(src_value[..., 0:1])


def _cross_insert_ref(n, shift):
    prog = CrossInsert(n, shift)
    vert = load_graph(EDGES, n, P=4, value_dims=1)
    res = run_host(vert, prog, prog.suggested_plan, max_supersteps=5)
    return gather_values(res.vertex, n)


@pytest.mark.parametrize("streaming", [False, True])
def test_mutation_inbox_spans_super_partitions(streaming):
    """Inserts proposed in one super-partition must land in another:
    the host mutation inbox must reproduce the in-memory exchange +
    resolve exactly (the seed's in-device route only spanned the
    resident super-partition)."""
    n, shift = N, 3
    ref = _cross_insert_ref(n, shift)
    # sanity: the insert really overwrote values cross-partition
    assert not np.array_equal(ref[:, 0], np.arange(n, dtype=np.float32))
    prog = CrossInsert(n, shift)
    vert = load_graph(EDGES, n, P=4, value_dims=1)
    res = run_out_of_core(vert, prog, prog.suggested_plan,
                          budget_partitions=2, max_supersteps=5,
                          stream=streaming)
    assert np.array_equal(gather_values(res.vertex, n), ref)
    recs = [s for s in res.stats if "mutation_rate" in s]
    assert recs and recs[0]["mutation_rate"] > 0


def test_mutation_inbox_spills_through_pager(tmp_path):
    n, shift = N, 3
    ref = _cross_insert_ref(n, shift)
    prog = CrossInsert(n, shift)
    vert = load_graph(EDGES, n, P=4, value_dims=1)
    budget = _BUDGET
    res = run_out_of_core(vert, prog, prog.suggested_plan,
                          budget_partitions=2, max_supersteps=5,
                          memory_budget_bytes=budget, disk_dir=tmp_path)
    assert np.array_equal(gather_values(res.vertex, n), ref)


class Lazarus(VertexProgram):
    """Deletes every odd vertex at superstep 0, then messages the dead:
    Pregel semantics re-CREATE a vertex that receives a message
    (superstep.resurrect), deriving its vid from the slot address —
    which out-of-core needs the block's GLOBAL partition offset for
    (under hash partitioning with P=2 every odd vid lives in partition
    1, i.e. entirely inside the second super-partition)."""

    value_dims = 1
    msg_dims = 1
    agg_dims = 1
    combine_op = "sum"
    mutates = True
    suggested_plan = PhysicalPlan(join="full_outer", groupby="scatter")

    def compute(self, vid, value, msg, has_msg, active, gs):
        first = gs.superstep == 0
        second = gs.superstep == 1
        new_val = jnp.where(has_msg, msg[..., 0], value[..., 0])
        return ComputeOut(
            value=new_val[..., None],
            halt=jnp.broadcast_to(gs.superstep >= 2, vid.shape),
            send_gate=second & (vid % 2 == 0) & (vid >= 0),
            aggregate=jnp.zeros(vid.shape + (1,)),
            delete_self=first & (vid % 2 == 1))

    def send(self, src_vid, src_value, edge_val, dst_vid, gs):
        return (src_vid + 100.0)[..., None]


@pytest.mark.parametrize("streaming", [False, True])
def test_resurrect_in_later_super_partition_gets_global_vid(streaming):
    """A message to a deleted vid in super-partition 1 must re-create it
    with the GLOBAL vid (slot * P + global_partition), identical to the
    in-memory run — the resident block's partitions are not 0..sp-1."""
    n = 16
    edges = chain_graph(n)
    prog = Lazarus()
    ref = run_host(load_graph(edges, n, P=2, value_dims=1), prog,
                   prog.suggested_plan, max_supersteps=6)
    res = run_out_of_core(load_graph(edges, n, P=2, value_dims=1), prog,
                          prog.suggested_plan, budget_partitions=1,
                          max_supersteps=6, stream=streaming)
    assert np.array_equal(np.asarray(res.vertex.vid),
                          np.asarray(ref.vertex.vid))
    assert np.array_equal(gather_values(res.vertex, n),
                          gather_values(ref.vertex, n))
    # the resurrected odd vertices carry their sender's tag: i -> i+1
    vals = gather_values(res.vertex, n)[:, 0]
    assert vals[3] == 2 + 100 and vals[7] == 6 + 100


def test_delete_only_mutations_match_in_memory():
    """PathMerge (delete + resolve, no inserts) out-of-core vs
    run_host: deletions are partition-local and must stay exact."""
    n = 32
    edges = chain_graph(n)
    pm = PathMerge(rounds=10)
    ref = run_host(load_graph(edges, n, P=2, value_dims=2), pm,
                   pm.suggested_plan, max_supersteps=12)
    res = run_out_of_core(load_graph(edges, n, P=2, value_dims=2), pm,
                          pm.suggested_plan, budget_partitions=1,
                          max_supersteps=12)
    assert np.array_equal(gather_values(res.vertex, n),
                          gather_values(ref.vertex, n))
    assert np.array_equal(np.asarray(res.vertex.vid),
                          np.asarray(ref.vertex.vid))


# ------------------------------------------------- spill checkpoints

@pytest.mark.parametrize("disk", [False, True])
def test_ooc_checkpoint_resume_matches_uninterrupted(disk, tmp_path):
    """Checkpoint at a superstep boundary (file-level page export) and
    resume directly from the spill directory — no VertexRel needed —
    landing on the same final state bit-for-bit."""
    prog = SSSP(source=3)
    plan = prog.suggested_plan
    kw = {}
    if disk:
        kw = dict(disk_dir=str(tmp_path / "spill1"))
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    full = run_out_of_core(vert, prog, plan, budget_partitions=2,
                           max_supersteps=30,
                           checkpoint_every=2,
                           checkpoint_dir=str(tmp_path / "ckpt"), **kw)
    assert full.supersteps > 2
    ck = tmp_path / "ckpt" / "ooc_000002"
    assert (ck / "meta.json").exists()
    assert (ck / "vid_0.npy").exists() and (ck / "inbox_dst_1.npy").exists()
    kw2 = {}
    if disk:
        kw2 = dict(disk_dir=str(tmp_path / "spill2"))
    res = run_out_of_core(None, prog, plan, budget_partitions=2,
                          max_supersteps=30, resume_from=str(ck), **kw2)
    assert res.supersteps == full.supersteps
    assert np.array_equal(gather_values(res.vertex, N),
                          gather_values(full.vertex, N))


def test_resume_from_latest_marker(tmp_path):
    prog = ConnectedComponents()
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    full = run_out_of_core(vert, prog, prog.suggested_plan,
                           budget_partitions=2, max_supersteps=30,
                           checkpoint_every=1,
                           checkpoint_dir=str(tmp_path))
    # LATEST_OOC resolves to the final checkpoint: resuming is a no-op
    # (the job halted) and returns the converged state
    res = run_out_of_core(None, prog, prog.suggested_plan,
                          budget_partitions=2, max_supersteps=30,
                          resume_from=str(tmp_path))
    assert res.supersteps == full.supersteps
    assert np.array_equal(gather_values(res.vertex, N),
                          gather_values(full.vertex, N))


def test_resume_with_auto_plan_restores_checkpointed_plan(tmp_path):
    """The checkpoint records the plan IN EFFECT (it produced the saved
    inbox's run layout); a plan='auto' resume must restart from it —
    not re-choose blind over a foreign inbox — and still converge to
    the same answer (min-combine: exact regardless of later switches)."""
    import json
    prog = SSSP(source=3)
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    full = run_out_of_core(vert, prog, "auto", budget_partitions=2,
                           max_supersteps=30, checkpoint_every=2,
                           checkpoint_dir=str(tmp_path))
    ck = tmp_path / "ooc_000002"
    meta = json.loads((ck / "meta.json").read_text())
    assert meta["plan"] is not None and "connector" in meta["plan"]
    res = run_out_of_core(None, prog, "auto", budget_partitions=2,
                          max_supersteps=30, resume_from=str(ck))
    assert np.array_equal(gather_values(res.vertex, N),
                          gather_values(full.vertex, N))


def test_resume_budget_partition_mismatch_raises(tmp_path):
    prog = SSSP(source=3)
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    run_out_of_core(vert, prog, prog.suggested_plan,
                    budget_partitions=2, max_supersteps=4,
                    checkpoint_every=2, checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="super-partition"):
        run_out_of_core(None, prog, prog.suggested_plan,
                        budget_partitions=1, max_supersteps=10,
                        resume_from=str(tmp_path))


# --------------------------------------------- planner: new cost axes

_G = GraphStats(n_vertices=4096, n_edges=40960, n_partitions=8,
                vertex_capacity=680, edge_capacity=6200)


def test_combinability_drives_sender_combine_ranking():
    """High measured combinability (many messages per distinct dst) must
    improve sender-combine plans RELATIVE to uncombined ones — the
    signal the adaptive controller now conditions the sender_combine
    replan dimension on."""
    sc = PhysicalPlan(sender_combine=True)
    nosc = PhysicalPlan(sender_combine=False)
    msgs = _G.n_edges

    def ratio(comb):
        obs = Observation(frontier_density=1.0, messages=msgs, ooc=True,
                          combinability=comb)
        return (estimate(sc, _G, obs).seconds() /
                estimate(nosc, _G, obs).seconds())

    assert ratio(16.0) < ratio(1.0)


def test_mutation_rate_prices_host_inbox_traffic():
    prog_plan = PhysicalPlan()
    base = Observation(frontier_density=1.0, messages=100, ooc=True)
    mut = dataclasses.replace(base, mutation_rate=0.5)
    c0 = estimate(prog_plan, _G, base)
    c1 = estimate(prog_plan, _G, mut)
    assert "mutation_io" in c1.terms and "mutation_io" not in c0.terms
    assert c1.seconds() > c0.seconds()


def test_disk_axis_prices_spilling_and_storage_policy():
    """Spilling adds a disk term scaled by the miss rate, and a
    low-change-density delta plan writes fewer disk bytes than inplace —
    what lets plan='auto' choose the storage policy per run on the disk
    tier."""
    plan_in = PhysicalPlan(storage="inplace")
    plan_dl = PhysicalPlan(storage="delta")
    dram = Observation(frontier_density=1.0, messages=100, ooc=True)
    spill = dataclasses.replace(dram, spilling=True, hit_rate=0.3,
                                change_density=0.05)
    assert estimate(plan_in, _G, dram).disk_bytes == 0
    c_in = estimate(plan_in, _G, spill)
    c_dl = estimate(plan_dl, _G, spill)
    assert c_in.disk_bytes > 0 and "disk_io" in c_in.terms
    assert c_dl.disk_bytes < c_in.disk_bytes
    # a worse hit rate means more disk seconds
    worse = dataclasses.replace(spill, hit_rate=0.0)
    assert estimate(plan_in, _G, worse).disk_seconds() > \
        c_in.disk_seconds()
