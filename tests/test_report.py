"""Plan-audit ledger + memory-pressure accounting suite (ISSUE 9).

Covers the acceptance criteria: a disk-tier OOC run with the ledgers on
produces a schema-valid ``pregelix-run-report/v1`` document whose every
superstep row joins per-term predicted against measured leg seconds with
a FINITE drift score, carries HBM/DRAM/SSD occupancy with the DRAM peak
under ``memory_budget_bytes``, and whose every replan decision is paired
with the candidate price table it was made from; ``compare()`` on two
runs of the same workload returns zero regressions; and the
disabled-path guard proves the audit hooks cost nothing when off
(mirroring the ``_NULL`` tracer guard in test_obs.py).
"""
import json
import math

import pytest

from repro.core import PhysicalPlan, load_graph
from repro.core.ooc import run_out_of_core
from repro.graph import PageRank, rmat_graph
from repro.obs import explain, memwatch, report
from repro.obs.explain import TERM_LEG, drift
from repro.obs.report import (build_report, compare, to_markdown,
                              validate_report, write_report)
from repro.planner import GraphStats
from repro.planner.adaptive import AdaptiveConfig, AdaptiveController
from repro.planner.stats import SuperstepStats


@pytest.fixture(autouse=True)
def _no_leaked_ledgers():
    """Every test starts and ends with both ledgers disabled — a leak
    across tests would defeat the disabled-path overhead guard."""
    explain.stop()
    memwatch.stop()
    yield
    explain.stop()
    memwatch.stop()


N = 220
EDGES = rmat_graph(N, 1200, seed=7)
BUDGET = 16 * 1024


def _disk_tier_run(tmp_path, tag):
    """One small disk-tier OOC run with both ledgers recording; returns
    the assembled report document."""
    prog = PageRank(N, iterations=6)
    vert = load_graph(EDGES, N, P=4, value_dims=2)
    explain.start()
    memwatch.start()
    try:
        res = run_out_of_core(
            vert, prog, "auto", budget_partitions=1, max_supersteps=8,
            stream=True, barrier_free=True,
            memory_budget_bytes=BUDGET,
            disk_dir=str(tmp_path / f"spill-{tag}"),
            eviction="mru", io_threads=2)
    finally:
        led = explain.stop()
        mw = memwatch.stop()
    return build_report(stats=res.stats, explain=led, memwatch=mw,
                        meta={"tag": tag, "algo": "pagerank"})


# ------------------------------------------------- disabled-path guard

def test_disabled_audit_records_nothing():
    """Without start() every module hook is a plain early return — no
    ledger, no rows, no samples (the audit calls sit permanently in the
    driver hot path, so this is the regression guard for their cost)."""
    assert not explain.enabled() and not memwatch.enabled()
    assert explain.get() is None and memwatch.get() is None
    prog = PageRank(N, iterations=4)
    # the fire-and-forget module surface is all Nones while off
    assert explain.attach(prog, plan=PhysicalPlan()) is None
    assert explain.superstep(SuperstepStats(superstep=0)) is None
    assert explain.decision(0, "replan") is None
    assert memwatch.configure(budget_bytes=1) is None
    assert memwatch.sample(0) is None
    # and a real run leaves both modules untouched
    vert = load_graph(EDGES, N, P=4, value_dims=2)
    res = run_out_of_core(vert, prog, prog.suggested_plan,
                          budget_partitions=2, max_supersteps=6)
    assert res.supersteps > 0
    assert explain.get() is None and memwatch.get() is None


def test_stop_detaches_the_ledgers():
    led = explain.start()
    mw = memwatch.start()
    assert explain.enabled() and memwatch.enabled()
    assert explain.stop() is led and memwatch.stop() is mw
    assert not explain.enabled() and not memwatch.enabled()


# -------------------------------------- acceptance: disk-tier OOC run

def test_disk_tier_report_meets_acceptance(tmp_path):
    rep = _disk_tier_run(tmp_path, "accept")
    # schema-valid, with zero violations listed
    assert validate_report(rep) == []
    rows = rep["supersteps"]
    assert rows
    for r in rows:
        # (a) per-term predicted vs measured with a finite drift score
        a = r["audit"]
        assert "error" not in a
        assert math.isfinite(a["drift_score"])
        assert a["predicted"]
        for term, d in a["predicted"].items():
            assert d["leg"] == TERM_LEG.get(term, "device")
            assert math.isfinite(d["seconds"])
        # the disk-tier pipeline measures at least device + serial +
        # host_io legs; every joined leg has both sides and finite drift
        assert {"device", "host_io", "serial"} <= set(a["legs"])
        for leg in a["legs"].values():
            assert math.isfinite(leg["drift"])
            assert leg["measured_s"] >= 0.0
            assert leg["drift"] == pytest.approx(
                drift(leg["predicted_s"], leg["measured_s"]))
        # (b) all three tiers sampled; DRAM peak within the hard budget
        m = r["memory"]
        assert m["hbm"]["total_bytes"] > 0
        assert m["dram"]["budget_bytes"] == BUDGET
        assert 0 <= m["dram"]["peak_resident_bytes"] <= BUDGET
        assert m["dram"]["occupancy"] == pytest.approx(
            m["dram"]["resident_bytes"] / BUDGET)
        assert m["ssd"]["spill_bytes"] >= 0
    # paging actually happened (the 16 KiB budget forces the disk tier)
    assert rep["memory_peaks"]["ssd_spill_bytes"] > 0
    assert 0 < rep["memory_peaks"]["dram_occupancy"] <= 1.0 + 1e-9
    # (c) every replan decision carries its candidate price table
    for d in rep["decisions"]:
        assert d["kind"] in ("replan", "recalibrate")
        if d["kind"] == "replan":
            assert d["candidates"]
            for c in d["candidates"]:
                assert c["plan"] and math.isfinite(c["seconds"])
    s = rep["summary"]
    assert s["supersteps"] == len(rows)
    assert math.isfinite(s["mean_drift"]) and math.isfinite(s["max_drift"])
    assert s["replans"] == sum(1 for d in rep["decisions"]
                               if d["kind"] == "replan")
    # the markdown digest renders without blowing up on any row
    md = to_markdown(rep)
    assert "Run report" in md and "supersteps" in md


def test_same_workload_compares_clean(tmp_path):
    """compare() across two runs of the SAME workload: zero
    regressions despite scheduler/cache noise."""
    a = _disk_tier_run(tmp_path, "a")
    b = _disk_tier_run(tmp_path, "b")
    diff = compare(a, b)
    assert diff["ok"] and diff["regressions"] == []
    assert diff["base"]["supersteps"] == diff["other"]["supersteps"]
    # and the flip side: a doctored report with much worse drift and a
    # fuller DRAM tier is flagged on both axes
    worse = json.loads(json.dumps(b))
    worse["summary"]["mean_drift"] = a["summary"]["mean_drift"] + 2.0
    worse["memory_peaks"]["dram_occupancy"] = min(
        a["memory_peaks"]["dram_occupancy"] + 0.5, 2.0)
    diff = compare(a, worse)
    assert not diff["ok"]
    assert {r["kind"] for r in diff["regressions"]} == \
        {"drift", "occupancy"}


# ----------------------------------------- decision log (replan audit)

_G = GraphStats(n_vertices=100_000, n_edges=800_000, n_partitions=8,
                vertex_capacity=16_250, edge_capacity=100_000,
                value_dims=2, msg_dims=1)


def test_replan_decision_carries_the_losing_candidates():
    """A controller switch while the ledger is on logs the full ranked
    candidate table the decision was made from — the 'why did auto pick
    this plan' record."""
    from repro.planner import Observation, choose
    prog = PageRank(_G.n_vertices, iterations=5)
    dense, _ = choose(prog, _G, Observation(frontier_density=1.0))
    explain.start()
    ctrl = AdaptiveController(
        prog, _G, dense,
        config=AdaptiveConfig(margin=0.05, patience=1, cooldown=0,
                              min_superstep=0))
    rec = SuperstepStats(superstep=3, active=100, messages=800,
                         frontier_density=0.001, wall_s=0.01)
    new = ctrl.observe(rec)
    led = explain.stop()
    assert new is not None and new != dense
    (d,) = led.decisions
    assert d["kind"] == "replan" and d["superstep"] == 3
    assert d["from"] != d["to"]
    assert math.isfinite(d["current_s"])
    # cheapest-first, and the winner leads the table
    secs = [c["seconds"] for c in d["candidates"]]
    assert secs == sorted(secs)
    assert d["candidates"][0]["plan"] == d["to"]
    # the decision log survives the report round trip
    rep = build_report(stats=[rec.as_dict()], explain=led)
    assert validate_report(rep) == []
    assert rep["summary"]["replans"] == 1


def test_decision_validation_rejects_bad_entries():
    base = {"schema": report.SCHEMA, "meta": {},
            "supersteps": [{"superstep": 0, "wall_s": 0.1}],
            "summary": {}}
    ok = dict(base, decisions=[
        {"superstep": 1, "kind": "replan",
         "candidates": [{"plan": "a/b", "seconds": 0.5}]},
        {"superstep": 2, "kind": "recalibrate", "k_compute": 1.0}])
    assert validate_report(ok) == []
    # unknown kind, replan without candidates, candidate without price:
    # ALL collected in one pass
    bad = dict(base, decisions=[
        {"superstep": 1, "kind": "mystery"},
        {"superstep": 2, "kind": "replan"},
        {"superstep": 3, "kind": "replan",
         "candidates": [{"plan": "a/b"}]}])
    errs = validate_report(bad)
    assert len(errs) == 3
    assert any("unknown kind" in e for e in errs)
    assert any("candidate price table" in e for e in errs)
    assert any("bad candidate" in e for e in errs)


# -------------------------------------------------- validator + CLI

def test_validator_collects_every_violation():
    assert validate_report([]) == ["top level must be a dict"]
    errs = validate_report({"schema": "nope", "meta": None,
                            "supersteps": [], "decisions": None,
                            "summary": None})
    assert len(errs) == 5                 # one per broken section
    # a budget-busting DRAM peak and a NaN drift are both caught
    doc = {"schema": report.SCHEMA,
           "meta": {"memory_budget_bytes": 100},
           "supersteps": [
               {"superstep": 0, "wall_s": 0.1,
                "audit": {"drift_score": float("nan"), "legs": {},
                          "predicted": {"send": {"seconds": 1.0}}},
                "memory": {"dram": {"resident_bytes": 50,
                                    "dirty_bytes": 0, "pinned_bytes": 0,
                                    "peak_resident_bytes": 150}}}],
           "decisions": [], "summary": {}}
    errs = validate_report(doc)
    assert any("drift_score" in e for e in errs)
    assert any("exceeds budget" in e for e in errs)


def test_report_cli_validate_and_compare(tmp_path, capsys):
    good = tmp_path / "good.json"
    bad = tmp_path / "bad.json"
    gdoc = {"schema": report.SCHEMA, "meta": {},
            "supersteps": [{"superstep": 0, "wall_s": 0.1}],
            "decisions": [], "summary": {"mean_drift": 0.5}}
    good.write_text(json.dumps(gdoc))
    bad.write_text(json.dumps({"schema": "wrong", "meta": {},
                               "supersteps": [], "decisions": [],
                               "summary": {}}))
    assert report.main(["--validate", str(good)]) == 0
    assert "OK" in capsys.readouterr().out
    # nonzero exit + the FULL violation list on one run
    assert report.main(["--validate", str(good), str(bad)]) == 1
    out = capsys.readouterr().out
    assert "INVALID" in out and "schema must be" in out
    assert "supersteps must be a non-empty list" in out
    assert report.main(["--validate", str(tmp_path / "missing.json")]) == 1
    assert "unreadable" in capsys.readouterr().out
    # compare: clean pair exits 0 either way; regression gates only
    # under --strict
    worse = tmp_path / "worse.json"
    wdoc = json.loads(json.dumps(gdoc))
    wdoc["summary"]["mean_drift"] = 9.0
    worse.write_text(json.dumps(wdoc))
    assert report.main(["--compare", str(good), str(good)]) == 0
    assert report.main(["--compare", str(good), str(worse)]) == 0
    assert report.main(["--compare", str(good), str(worse),
                        "--strict"]) == 1
    assert "mean drift rose" in capsys.readouterr().out


# --------------------------------------------- ledger unit semantics

def test_drift_is_finite_and_symmetric():
    assert drift(1.0, 1.0) == 0.0
    assert drift(1.0, 2.0) == pytest.approx(math.log(2), abs=1e-5)
    assert drift(2.0, 1.0) == pytest.approx(drift(1.0, 2.0), abs=1e-5)
    assert math.isfinite(drift(0.0, 0.0))
    assert math.isfinite(drift(0.0, 1e9))


def test_memwatch_budget_gauge_and_peaks():
    class _Store:
        def occupancy(self):
            return {"resident_bytes": 60, "dirty_bytes": 10,
                    "pinned_bytes": 4, "peak_resident_bytes": 80,
                    "budget_bytes": 100, "spill_bytes": 7,
                    "spill_read_bytes": 3, "spill_write_bytes": 9}
    mw = memwatch.start()
    s = memwatch.sample(0, store=_Store())
    assert s["dram"]["occupancy"] == pytest.approx(0.6)
    assert s["dram"]["headroom_bytes"] == 40
    assert s["ssd"]["spill_bytes"] == 7
    # sharded: per-worker stores SUM (budgets too)
    s2 = memwatch.sample(1, stores=[_Store(), _Store()])
    assert s2["dram"]["resident_bytes"] == 120
    assert s2["dram"]["budget_bytes"] == 200
    assert memwatch.stop() is mw
    assert mw.peaks["dram_resident_bytes"] == 120
    assert mw.peaks["ssd_spill_bytes"] == 14
    assert mw.peaks["dram_occupancy"] == pytest.approx(0.6)


def test_explain_attach_requires_context():
    led = explain.start()
    prog = PageRank(N, iterations=4)
    # no plan / no graph context -> decision-log-only ledger
    assert explain.attach(prog) is None
    assert explain.attach(prog, plan=PhysicalPlan()) is None
    assert led.superstep(SuperstepStats(superstep=0)) is None
    # with a vertex relation the shadow auditor prices rows
    vert = load_graph(EDGES, N, P=4, value_dims=2)
    assert explain.attach(prog, vert=vert, plan=PhysicalPlan()) is led
    row = led.superstep(SuperstepStats(
        superstep=0, active=N, messages=1200, frontier_density=1.0,
        wall_s=0.01))
    assert row is not None and math.isfinite(row["drift_score"])
    assert row["legs"]["device"]["measured_s"] == pytest.approx(0.01)
    # event records never become audit rows
    assert led.superstep(SuperstepStats(superstep=1,
                                        event="plan-switch")) is None
    explain.stop()


def test_write_report_emits_json_and_markdown(tmp_path):
    doc = {"schema": report.SCHEMA, "meta": {"algo": "pagerank"},
           "supersteps": [{"superstep": 0, "wall_s": 0.1}],
           "decisions": [], "summary": {"supersteps": 1, "wall_s": 0.1,
                                        "mean_drift": None,
                                        "replans": 0,
                                        "recalibrations": 0}}
    p = tmp_path / "rep.json"
    m = tmp_path / "rep.md"
    write_report(str(p), doc, markdown=str(m))
    assert json.loads(p.read_text())["schema"] == report.SCHEMA
    assert "Run report" in m.read_text()
