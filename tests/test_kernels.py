"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.csr_spmv import ops as spmv_ops
from repro.kernels.csr_spmv.ref import edge_gather_ref
from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm import ops as gmm_ops
from repro.kernels.moe_gmm.ref import grouped_matmul_ref
from repro.kernels.segment_combine.ref import segment_combine_ref
from repro.kernels.segment_combine.segment_combine import \
    segment_combine_pallas

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("M,D,op", [
    (128, 1, "sum"), (256, 4, "sum"), (512, 8, "min"), (1024, 2, "max"),
    (96, 3, "sum"), (513, 2, "min"),
])
def test_segment_combine(M, D, op):
    seg = np.sort(RNG.integers(0, max(M // 3, 1), M)).astype(np.int32)
    pay = RNG.normal(size=(M, D)).astype(np.float32)
    val = RNG.random(M) > 0.1
    order = np.argsort(~val, kind="stable")
    seg, pay, val = seg[order], pay[order], val[order]
    f1, l1 = segment_combine_ref(
        jnp.asarray(np.where(val, seg, np.iinfo(np.int32).max)),
        jnp.asarray(pay), jnp.asarray(val), op)
    f2, l2 = segment_combine_pallas(jnp.asarray(seg), jnp.asarray(pay),
                                    jnp.asarray(val), op, block_m=128,
                                    interpret=True)
    assert (np.asarray(l1) == np.asarray(l2)).all()
    np.testing.assert_allclose(np.asarray(f1)[np.asarray(l1)],
                               np.asarray(f2)[np.asarray(l2)], atol=1e-5)


@pytest.mark.parametrize("B,Sq,Sk,hd,causal,dtype", [
    (2, 128, 128, 64, True, np.float32),
    (1, 256, 256, 128, True, np.float32),
    (2, 128, 128, 64, False, np.float32),
    (1, 128, 384, 64, True, np.float32),   # decode-suffix layout
    (1, 128, 128, 64, True, jnp.bfloat16),
])
def test_flash_attention(B, Sq, Sk, hd, causal, dtype):
    q = RNG.normal(size=(B, Sq, hd)).astype(np.float32)
    k = RNG.normal(size=(B, Sk, hd)).astype(np.float32)
    v = RNG.normal(size=(B, Sk, hd)).astype(np.float32)
    qj = jnp.asarray(q).astype(dtype)
    kj = jnp.asarray(k).astype(dtype)
    vj = jnp.asarray(v).astype(dtype)
    o1 = attention_ref(qj, kj, vj, causal=causal)
    o2 = flash_attention_pallas(qj, kj, vj, causal=causal, block_q=128,
                                block_k=128, interpret=True)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=atol)


@pytest.mark.parametrize("T,d,f,E,bm", [
    (300, 64, 128, 4, 64), (1024, 128, 256, 8, 128), (50, 32, 64, 8, 16),
    (17, 16, 32, 3, 8),
])
def test_moe_gmm(T, d, f, E, bm):
    sizes = RNG.multinomial(T, np.ones(E) / E).astype(np.int32)
    x = RNG.normal(size=(T, d)).astype(np.float32)
    w = (RNG.normal(size=(E, d, f)) / np.sqrt(d)).astype(np.float32)
    o1 = grouped_matmul_ref(jnp.asarray(x), jnp.asarray(w),
                            jnp.asarray(sizes))
    o2 = gmm_ops.grouped_matmul(jnp.asarray(x), jnp.asarray(w),
                                jnp.asarray(sizes), impl="pallas",
                                block_m=bm)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


@pytest.mark.parametrize("N,E,V", [(500, 3000, 2), (1000, 8000, 4),
                                   (128, 100, 1), (64, 64, 8)])
def test_csr_spmv(N, E, V):
    src = RNG.integers(0, N, E).astype(np.int32)
    src[RNG.random(E) < 0.05] = -1
    ev = RNG.normal(size=E).astype(np.float32)
    vals = RNG.normal(size=(N, V)).astype(np.float32)
    layout = spmv_ops.plan_layout(src, N, block_m=128, block_r=64)
    o1 = edge_gather_ref(jnp.asarray(vals), jnp.asarray(src),
                         jnp.asarray(ev))
    o2 = spmv_ops.edge_gather(jnp.asarray(vals), jnp.asarray(src),
                              jnp.asarray(ev), layout=layout,
                              impl="pallas", block_m=128, block_r=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
