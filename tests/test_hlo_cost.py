"""The trip-count-aware HLO analyzer vs XLA's own cost_analysis."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_cost


def test_matches_xla_on_plain_matmul():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    c = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
    got = hlo_cost.analyze(c.as_text())
    xla = hlo_cost.normalize_cost_analysis(c.cost_analysis())
    assert got.flops == xla["flops"]


def test_scan_trip_count_multiplies():
    def scanned(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    c = jax.jit(scanned).lower(x, w).compile()
    got = hlo_cost.analyze(c.as_text())
    assert got.flops == 8 * 2 * 128 ** 3
    # XLA itself undercounts (counts the body once) — the analyzer's
    # reason to exist
    xla = hlo_cost.normalize_cost_analysis(c.cost_analysis())
    assert xla["flops"] < got.flops


def test_scanned_equals_unrolled():
    def scanned(x, w):
        y, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return y

    def unrolled(x, w):
        for i in range(6):
            x = x @ w[i]
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    cs = jax.jit(scanned).lower(x, w).compile()
    cu = jax.jit(unrolled).lower(x, w).compile()
    fs = hlo_cost.analyze(cs.as_text()).flops
    fu = hlo_cost.analyze(cu.as_text()).flops
    assert fs == fu


def test_tiny_transformer_close_to_6nd():
    from repro.configs import get_config
    from repro.models import make_train_step, init_params
    from repro.optim import adamw_init
    cfg = get_config("stablelm-12b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    tok = jnp.zeros((B, S), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    comp = jax.jit(make_train_step(cfg)).lower(
        params, adamw_init(params), batch).compile()
    flops = hlo_cost.analyze(comp.as_text()).flops
    model = 6 * cfg.param_count() * B * S
    # remat + attention put the ratio in (1, 3)
    assert 0.8 < flops / model < 3.0
