"""Physical plan flexibility: every join x group-by x connector combination
computes the same answer (paper Section 5.3)."""
import numpy as np
import pytest

from repro.core import PhysicalPlan, gather_values, load_graph, run_host
from repro.graph import SSSP, rmat_graph

N = 200
EDGES = rmat_graph(N, 1000, seed=21)


def _run(plan):
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    res = run_host(vert, SSSP(source=2), plan, max_supersteps=40)
    d = gather_values(res.vertex, N)[:, 0]
    return np.where(d > 1e37, 1e9, d)


REF = None


@pytest.mark.parametrize("join", ["full_outer", "left_outer"])
@pytest.mark.parametrize("groupby", ["scatter", "sort"])
@pytest.mark.parametrize("connector",
                         ["partitioning", "partitioning_merging"])
def test_plan_equivalence(join, groupby, connector):
    global REF
    plan = PhysicalPlan(join=join, groupby=groupby, connector=connector,
                        sender_combine=True)
    d = _run(plan)
    if REF is None:
        REF = d
    assert np.allclose(REF, d)


def test_sender_combine_equivalence():
    a = _run(PhysicalPlan(sender_combine=True))
    b = _run(PhysicalPlan(sender_combine=False))
    assert np.allclose(a, b)


def test_scatter_groupby_rejects_custom_combine():
    with pytest.raises(ValueError):
        PhysicalPlan(groupby="scatter").validate("custom")


def test_range_partition_equivalence():
    """Beyond-paper range partitioning computes identical results."""
    import dataclasses
    from repro.core import load_graph as lg
    plan_h = PhysicalPlan(partition="hash")
    plan_r = PhysicalPlan(partition="range")
    v1 = lg(EDGES, N, P=4, value_dims=1, partition="hash")
    v2 = lg(EDGES, N, P=4, value_dims=1, partition="range")
    from repro.graph import SSSP as S2
    r1 = run_host(v1, S2(source=2), plan_h, max_supersteps=40)
    r2 = run_host(v2, S2(source=2), plan_r, max_supersteps=40)
    d1 = gather_values(r1.vertex, N)[:, 0]
    d2 = gather_values(r2.vertex, N)[:, 0]
    assert np.allclose(d1, d2)
