"""Out-of-core parity + resilience suite.

The paper's headline claim is that ONE set of plans runs in-memory and
out-of-core. We hold it to the strongest possible standard: for
PageRank / SSSP / CC, ``run_out_of_core`` must match ``run_host``
BIT-FOR-BIT under every connector x storage combination (the
run-structured inbox delivers the exact same receiver layout the
in-memory exchange does, so even float accumulation order agrees), and
capacity overflows (bucket or frontier) must regrow-and-redo instead of
raising.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (EngineConfig, PhysicalPlan, gather_values,
                        load_graph, run_host)
from repro.core.ooc import (_pad_run_width, _round_run_width,
                            _sort_inbox_runs, run_out_of_core)
from repro.graph import SSSP, ConnectedComponents, PageRank, rmat_graph
from repro.graph.generators import grid_graph

N = 220
EDGES = rmat_graph(N, 1200, seed=7)
ALGOS = {
    "pagerank": (lambda: PageRank(N, iterations=6), 2),
    "sssp": (lambda: SSSP(source=3), 1),
    "cc": (lambda: ConnectedComponents(), 1),
}
_HOST_REF = {}   # (algo, connector) -> gathered values of run_host


def _host_ref(algo: str, connector: str) -> np.ndarray:
    if (algo, connector) not in _HOST_REF:
        mk, vd = ALGOS[algo]
        prog = mk()
        plan = dataclasses.replace(prog.suggested_plan, connector=connector)
        vert = load_graph(EDGES, N, P=4, value_dims=vd)
        res = run_host(vert, prog, plan, max_supersteps=30)
        _HOST_REF[(algo, connector)] = gather_values(res.vertex, N)
    return _HOST_REF[(algo, connector)]


@pytest.mark.parametrize("algo", list(ALGOS))
@pytest.mark.parametrize("connector",
                         ["partitioning", "partitioning_merging"])
@pytest.mark.parametrize("storage", ["inplace", "delta"])
def test_ooc_parity_bit_for_bit(algo, connector, storage):
    """run_out_of_core == run_host exactly, every connector x storage."""
    mk, vd = ALGOS[algo]
    prog = mk()
    plan = dataclasses.replace(prog.suggested_plan, connector=connector,
                               storage=storage)
    vert = load_graph(EDGES, N, P=4, value_dims=vd)
    res = run_out_of_core(vert, prog, plan, budget_partitions=2,
                          max_supersteps=30)
    assert np.array_equal(gather_values(res.vertex, N),
                          _host_ref(algo, connector))


def test_bucket_overflow_regrows_instead_of_raising():
    """A bucket_cap far too small for superstep 0's all-active sends must
    regrow-and-redo the super-partition, not lose work or raise (the seed
    raised RuntimeError('OOC bucket overflow; raise bucket_cap'))."""
    prog = SSSP(source=3)
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    ec = EngineConfig(n_parts=4, bucket_cap=2,
                      frontier_cap=vert.capacity + 8)
    res = run_out_of_core(vert, prog, prog.suggested_plan,
                          budget_partitions=2, max_supersteps=30, ec=ec)
    regrows = [s for s in res.stats if s.get("event") == "regrow"]
    assert regrows, "expected at least one regrow event"
    assert regrows[-1]["bucket_cap"] > 2
    assert np.array_equal(gather_values(res.vertex, N),
                          _host_ref("sssp", "partitioning"))


def test_frontier_overflow_regrows_instead_of_raising():
    """Left-outer with a tiny frontier capacity: superstep 0 activates all
    vertices, overflowing the frontier compaction — the regrow path must
    double it until the superstep fits, making adaptive refits safe."""
    prog = SSSP(source=3)
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    plan = dataclasses.replace(prog.suggested_plan, join="left_outer")
    ec = EngineConfig(n_parts=4, bucket_cap=64, frontier_cap=4)
    res = run_out_of_core(vert, prog, plan, budget_partitions=2,
                          max_supersteps=30, ec=ec)
    regrows = [s for s in res.stats if s.get("event") == "regrow"]
    assert regrows, "expected at least one regrow event"
    assert regrows[-1]["frontier_cap"] > 4
    assert np.array_equal(gather_values(res.vertex, N),
                          _host_ref("sssp", "partitioning"))


def test_ooc_auto_searches_full_space_and_switches_storage():
    """plan='auto' out-of-core: matches the static reference exactly, and
    on the high-diameter lattice (frontier collapses, few values change
    per superstep) re-plans mid-run onto storage='delta' — the scenario
    the seed's _OOC_PLAN_SPACE fence made unreachable. Run synchronously:
    the storage dimension is priced additively only when host transfers
    do NOT overlap compute (under streaming the planner's max(step,
    transfer) correctly collapses write-back savings that hide behind
    compute — see test_streaming_observation_prices_with_overlap)."""
    side = 40
    n = side * side
    edges = grid_graph(side)
    prog = SSSP(source=0)
    static = run_host(load_graph(edges, n, P=4, value_dims=1), prog,
                      prog.suggested_plan, max_supersteps=100)
    auto = run_out_of_core(load_graph(edges, n, P=4, value_dims=1), prog,
                           "auto", budget_partitions=2, max_supersteps=100,
                           stream=False)
    assert np.array_equal(gather_values(auto.vertex, n),
                          gather_values(static.vertex, n))
    switches = [s for s in auto.stats if s.get("event") == "plan-switch"]
    assert len(switches) >= 1
    assert auto.plan.storage == "delta"
    assert auto.plan.join == "left_outer"
    # the OOC statistics stream carries the measured write-back signal
    recs = [s for s in auto.stats if "change_density" in s]
    assert recs and all(0.0 <= s["change_density"] <= 1.0 for s in recs)
    assert all(s["ooc"] for s in recs)
    assert not any(s["streaming"] for s in recs)


def test_ooc_runs_merging_connector_with_auto_space():
    """The merging connector is a legal auto-space member in OOC now:
    pin the space to it and both storages — the run must still match."""
    prog = PageRank(N, iterations=6)
    vert = load_graph(EDGES, N, P=4, value_dims=2)
    res = run_out_of_core(
        vert, prog, "auto", budget_partitions=2, max_supersteps=10,
        auto_space={"connectors": ("partitioning_merging",),
                    "storages": ("inplace", "delta")})
    assert res.plan.connector == "partitioning_merging"
    assert np.array_equal(gather_values(res.vertex, N),
                          _host_ref("pagerank", "partitioning_merging"))


def test_frontier_cap_default_zero_still_regrows():
    """A caller-supplied EngineConfig with frontier_cap=0 (the 'Np/2'
    dataclass default) must not wedge the regrow doubling at 0: SSSP
    superstep 0 activates every vertex, overflowing Np/2, and the run
    must recover and terminate."""
    prog = SSSP(source=3)
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    plan = dataclasses.replace(prog.suggested_plan, join="left_outer")
    ec = EngineConfig(n_parts=4, bucket_cap=64)   # frontier_cap = 0
    res = run_out_of_core(vert, prog, plan, budget_partitions=2,
                          max_supersteps=30, ec=ec)
    assert any(s.get("event") == "regrow" for s in res.stats)
    assert np.array_equal(gather_values(res.vertex, N),
                          _host_ref("sssp", "partitioning"))


def test_switch_to_merging_sorts_unsorted_inbox_runs(monkeypatch):
    """A mid-run switch from (partitioning, sender_combine=False) onto
    the merging connector must dst-sort the in-flight host runs (the OOC
    analogue of migrate_msgs) — forced here via the controller."""
    from repro.planner.adaptive import AdaptiveController
    prog = PageRank(N, iterations=6)

    def force_merging(self, rec, *, bucket_cap=0):
        if rec.superstep == 2:
            self.plan = dataclasses.replace(
                self.plan, connector="partitioning_merging")
            return self.plan
        return None

    monkeypatch.setattr(AdaptiveController, "observe", force_merging)
    vert = load_graph(EDGES, N, P=4, value_dims=2)
    res = run_out_of_core(
        vert, prog, "auto", budget_partitions=2, max_supersteps=10,
        auto_space={"connectors": ("partitioning",),
                    "sender_combines": (False,),
                    "storages": ("inplace",)})
    assert res.plan.connector == "partitioning_merging"
    assert any(s.get("event") == "plan-switch" for s in res.stats)
    # PageRank's ranks must come out right despite the layout change
    ref = _host_ref("pagerank", "partitioning")
    got = gather_values(res.vertex, N)
    assert np.allclose(got, ref, atol=1e-6)


def test_sort_inbox_runs_orders_and_preserves_messages():
    rng = np.random.default_rng(3)
    P, C, D = 3, 8, 2
    dst = rng.integers(0, 50, (P, P, C)).astype(np.int32)
    val = rng.random((P, P, C)) > 0.4
    # prefix-compact the valid mask the way real buckets arrive
    val = np.sort(val, axis=2)[:, :, ::-1]
    dst = np.where(val, dst, -1)
    pay = np.repeat(dst[..., None], D, axis=-1).astype(np.float32)
    d2, p2, v2 = _sort_inbox_runs((dst, pay, val))
    for q in range(P):
        for p in range(P):
            live = d2[q, p][v2[q, p]]
            assert (np.diff(live) >= 0).all()          # dst ascending
            assert (p2[q, p][v2[q, p], 0] == live).all()  # payload follows
            # valid entries stay a prefix
            k = v2[q, p].sum()
            assert v2[q, p][:k].all() and not v2[q, p][k:].any()
    assert sorted(dst[val]) == sorted(d2[v2])          # same multiset


@pytest.mark.parametrize("algo", list(ALGOS))
def test_streaming_matches_synchronous_bit_for_bit(algo):
    """The pipelined executor (prefetch + async collect + deferred
    commit) must be bit-for-bit identical to the synchronous loop —
    including the float aggregate, which is folded in super-partition
    order at the superstep barrier regardless of completion order."""
    mk, vd = ALGOS[algo]
    runs = {}
    for streaming in (False, True):
        prog = mk()
        vert = load_graph(EDGES, N, P=4, value_dims=vd)
        runs[streaming] = run_out_of_core(
            vert, prog, prog.suggested_plan, budget_partitions=1,
            max_supersteps=30, stream=streaming, prefetch_depth=3)
    a, b = runs[False], runs[True]
    assert np.array_equal(gather_values(a.vertex, N),
                          gather_values(b.vertex, N))
    assert a.supersteps == b.supersteps
    assert np.array_equal(np.asarray(a.gs.aggregate),
                          np.asarray(b.gs.aggregate))
    # and both match the in-memory reference exactly
    assert np.array_equal(gather_values(b.vertex, N),
                          _host_ref(algo, "partitioning"))
    # the streamed run annotates the transfer/compute wall-time split
    recs = [s for s in b.stats if "wall_s" in s]
    assert recs and all(s["streaming"] for s in recs)
    for f in ("dispatch_s", "collect_wait_s", "commit_s"):
        assert all(s[f] >= 0.0 for s in recs)
    assert not any(s["streaming"] for s in a.stats if "wall_s" in s)


def test_streaming_overflow_mid_pipeline_regrows():
    """An overflow that lands while later super-partitions are already in
    flight must unwind the prefetch, regrow and redo — committing only
    clean results — and still match the synchronous run bit-for-bit."""
    prog = SSSP(source=3)
    ec = EngineConfig(n_parts=4, bucket_cap=2,
                      frontier_cap=0)   # bucket AND frontier stress
    results = {}
    for streaming in (False, True):
        vert = load_graph(EDGES, N, P=4, value_dims=1)
        res = run_out_of_core(vert, prog, prog.suggested_plan,
                              budget_partitions=1, max_supersteps=30,
                              ec=ec, stream=streaming, prefetch_depth=4)
        regrows = [s for s in res.stats if s.get("event") == "regrow"]
        assert regrows, "expected a mid-pipeline regrow"
        assert regrows[-1]["bucket_cap"] > 2
        results[streaming] = res
    assert np.array_equal(gather_values(results[True].vertex, N),
                          gather_values(results[False].vertex, N))
    assert np.array_equal(gather_values(results[True].vertex, N),
                          _host_ref("sssp", "partitioning"))


def test_overflow_attributed_to_source_leaves_buckets_alone():
    """Per-source overflow counters: a frontier overflow must regrow the
    frontier capacity WITHOUT doubling the bucket tensors — the
    device-memory hot spot on the budgeted OOC path."""
    prog = SSSP(source=3)
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    plan = dataclasses.replace(prog.suggested_plan, join="left_outer")
    ec = EngineConfig(n_parts=4, bucket_cap=64, frontier_cap=4)
    res = run_out_of_core(vert, prog, plan, budget_partitions=2,
                          max_supersteps=30, ec=ec)
    regrows = [s for s in res.stats if s.get("event") == "regrow"]
    assert regrows
    assert regrows[-1]["frontier_cap"] > 4
    assert all(r["bucket_cap"] == 64 for r in regrows), \
        "frontier overflow must not drag bucket capacity"
    assert np.array_equal(gather_values(res.vertex, N),
                          _host_ref("sssp", "partitioning"))


def test_host_driver_overflow_attribution():
    """run_host's regrow likewise doubles only the overflowed source."""
    from repro.core import run_host as _run_host
    prog = SSSP(source=3)
    vert = load_graph(EDGES, N, P=4, value_dims=1)
    plan = dataclasses.replace(prog.suggested_plan, join="left_outer")
    ec = EngineConfig(n_parts=4, bucket_cap=64, frontier_cap=4)
    res = _run_host(vert, prog, plan, max_supersteps=30, ec=ec)
    regrows = [s for s in res.stats if s.get("event") == "regrow"]
    assert regrows
    assert regrows[-1]["frontier_cap"] > 4
    assert all(r["bucket_cap"] == 64 for r in regrows)
    assert np.array_equal(gather_values(res.vertex, N),
                          _host_ref("sssp", "partitioning"))


def test_sort_inbox_runs_is_stable_within_equal_dsts():
    """The run sort must be STABLE: messages sharing a dst keep their
    arrival order (combine-order determinism for non-commutative custom
    folds), and invalid slots stay an end-aligned suffix."""
    P, C, D = 2, 6, 1
    dst = np.array([[[5, 5, 3, 5, -1, -1]] * P] * P, np.int32)
    val = dst >= 0
    # payload tags arrival order within the duplicate dst=5 group
    pay = np.arange(P * P * C, dtype=np.float32).reshape(P, P, C, 1)
    d2, p2, v2 = _sort_inbox_runs((dst, pay, val))
    for q in range(P):
        for p in range(P):
            assert (d2[q, p][v2[q, p]] == [3, 5, 5, 5]).all()
            five = p2[q, p][d2[q, p] == 5, 0]
            assert (np.diff(five) > 0).all(), \
                "equal-dst messages must keep arrival order"
            k = v2[q, p].sum()
            assert v2[q, p][:k].all() and not v2[q, p][k:].any()


def test_round_run_width_pow2_clamped():
    assert _round_run_width(0, 64) == 1
    assert _round_run_width(1, 64) == 1
    assert _round_run_width(3, 64) == 4
    assert _round_run_width(33, 64) == 64
    assert _round_run_width(200, 64) == 64   # clamped to bucket_cap


def test_pad_run_width_preserves_prefix_layout():
    d = np.array([[[5, -1]]], np.int32)
    p = np.ones((1, 1, 2, 1), np.float32)
    v = np.array([[[True, False]]])
    d2, p2, v2 = _pad_run_width((d, p, v), 4)
    assert d2.shape == (1, 1, 4) and p2.shape == (1, 1, 4, 1)
    assert (d2[0, 0] == [5, -1, -1, -1]).all()
    assert (v2[0, 0] == [True, False, False, False]).all()
    same = _pad_run_width((d, p, v), 2)
    assert same[0] is d
