"""End-to-end behaviour tests: Pregelix algorithms vs exact oracles."""
import heapq

import numpy as np
import pytest

from repro.core import (PhysicalPlan, gather_values, load_graph, run_host,
                        run_jit)
from repro.graph import (BFS, SSSP, ConnectedComponents, PageRank,
                         Reachability, rmat_graph, uniform_graph)

N = 300


def _edges():
    return rmat_graph(N, 1800, seed=11)


def _dijkstra(edges, n, src):
    adj = {}
    for s, d in edges:
        adj.setdefault(int(s), []).append(int(d))
    dist = [float("inf")] * n
    dist[src] = 0
    h = [(0.0, src)]
    while h:
        dd, u = heapq.heappop(h)
        if dd > dist[u]:
            continue
        for v in adj.get(u, []):
            if dd + 1 < dist[v]:
                dist[v] = dd + 1
                heapq.heappush(h, (dd + 1, v))
    return np.array(dist)


def _union_find_cc(edges, n):
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for s, d in edges:
        a, b = find(int(s)), find(int(d))
        if a != b:
            parent[a] = b
    return np.array([find(i) for i in range(n)])


def test_sssp_matches_dijkstra():
    edges = _edges()
    oracle = _dijkstra(edges, N, 5)
    vert = load_graph(edges, N, P=4, value_dims=1)
    res = run_host(vert, SSSP(source=5), SSSP(5).suggested_plan,
                   max_supersteps=40)
    d = gather_values(res.vertex, N)[:, 0]
    d = np.where(d > 1e37, np.inf, d)
    assert np.allclose(np.nan_to_num(oracle, posinf=1e9),
                       np.nan_to_num(d, posinf=1e9))


def test_cc_matches_union_find():
    edges = uniform_graph(200, 400, seed=12, undirected=True)
    oracle = _union_find_cc(edges, 200)
    vert = load_graph(edges, 200, P=4, value_dims=1)
    cc = ConnectedComponents()
    res = run_host(vert, cc, cc.suggested_plan, max_supersteps=60)
    labels = gather_values(res.vertex, 200)[:, 0].astype(int)
    # same partition <=> same label
    for comp in set(oracle):
        members = np.where(oracle == comp)[0]
        assert len(set(labels[members])) == 1
    assert len(set(labels)) == len(set(oracle))


def test_pagerank_mass_and_convergence():
    edges = _edges()
    vert = load_graph(edges, N, P=4, value_dims=2)
    pr = PageRank(N, iterations=10)
    res = run_jit(vert, pr, pr.suggested_plan, max_supersteps=15)
    ranks = gather_values(res.vertex, N)[:, 0]
    assert (ranks >= 0).all()
    # total mass bounded by 1 (dangling leakage only reduces it)
    assert 0.1 < ranks.sum() <= 1.0 + 1e-4
    assert res.supersteps == 10


def test_pagerank_against_numpy_power_iteration():
    edges = _edges()
    n = N
    A = np.zeros((n, n), np.float64)
    for s, d in edges:
        A[int(d), int(s)] += 1.0
    deg = np.maximum(A.sum(axis=0), 1.0)
    M = A / deg
    r = np.full(n, 1.0 / n)
    for _ in range(9):
        r = 0.15 / n + 0.85 * (M @ r)
    vert = load_graph(edges, n, P=2, value_dims=2)
    pr = PageRank(n, iterations=10)
    res = run_jit(vert, pr, pr.suggested_plan, max_supersteps=12)
    ranks = gather_values(res.vertex, n)[:, 0]
    has_out = np.asarray(deg > 1.0) | (A.sum(axis=0) > 0)
    assert np.allclose(ranks, r, atol=5e-5)


def test_bfs_and_reachability_agree():
    edges = _edges()
    vert = load_graph(edges, N, P=4, value_dims=1)
    res_b = run_host(vert, BFS(source=3), BFS(3).suggested_plan,
                     max_supersteps=40)
    lv = gather_values(res_b.vertex, N)[:, 0]
    vert2 = load_graph(edges, N, P=4, value_dims=1)
    rc = Reachability(source=3)
    res_r = run_host(vert2, rc, rc.suggested_plan, max_supersteps=40)
    reach = gather_values(res_r.vertex, N)[:, 0] > 0
    assert ((lv < 1e37) == reach).all()


def test_jit_and_host_drivers_agree():
    edges = _edges()
    vert = load_graph(edges, N, P=2, value_dims=1)
    r1 = run_jit(vert, SSSP(source=0), PhysicalPlan(), max_supersteps=30)
    vert2 = load_graph(edges, N, P=2, value_dims=1)
    r2 = run_host(vert2, SSSP(source=0), PhysicalPlan(), max_supersteps=30)
    assert np.allclose(gather_values(r1.vertex, N),
                       gather_values(r2.vertex, N))


def test_weighted_sssp_matches_dijkstra():
    """Weighted edges exercise edge_val through send (paper Fig 9 uses
    weighted SSSP)."""
    rng = np.random.default_rng(17)
    edges = _edges()
    w = rng.uniform(0.5, 3.0, len(edges)).astype(np.float32)
    adj = {}
    for (s, d), wt in zip(edges, w):
        adj.setdefault(int(s), []).append((int(d), float(wt)))
    dist = [float("inf")] * N
    dist[4] = 0.0
    h = [(0.0, 4)]
    while h:
        dd, u = heapq.heappop(h)
        if dd > dist[u]:
            continue
        for v, wt in adj.get(u, []):
            if dd + wt < dist[v]:
                dist[v] = dd + wt
                heapq.heappush(h, (dd + wt, v))
    from repro.core import load_graph as lg
    vert = lg(edges, N, P=4, value_dims=1, edge_values=w)
    res = run_host(vert, SSSP(source=4), SSSP(4).suggested_plan,
                   max_supersteps=60)
    d = gather_values(res.vertex, N)[:, 0]
    d = np.where(d > 1e37, np.inf, d)
    assert np.allclose(np.nan_to_num(np.array(dist), posinf=1e9),
                       np.nan_to_num(d, posinf=1e9), atol=1e-4)


def test_kcore_matches_peeling_oracle():
    from repro.graph import uniform_graph
    from repro.graph.algorithms import KCore
    n, k = 150, 3
    edges = uniform_graph(n, 420, seed=23, undirected=True)
    # numpy peeling oracle
    deg = np.bincount(edges[:, 0], minlength=n).astype(float)
    alive = np.ones(n, bool)
    changed = True
    adj = {}
    for s, d in edges:
        adj.setdefault(int(s), []).append(int(d))
    while changed:
        changed = False
        for v in range(n):
            if alive[v] and sum(alive[u] for u in adj.get(v, [])) < k:
                alive[v] = False
                changed = True
    vert = load_graph(edges, n, P=4, value_dims=2)
    prog = KCore(k)
    res = run_host(vert, prog, prog.suggested_plan, max_supersteps=60)
    got = gather_values(res.vertex, n)[:, 1] > 0
    assert (got == alive).all()
