"""Property-based tests (hypothesis) on the system's invariants."""
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.connector import bucket_by_owner
from repro.core.groupby import (compact, scatter_combine_dense,
                                sort_combine_dense)

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.integers(2, 64), st.integers(1, 200), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_bucket_routing_is_a_partition(P, K, seed):
    """Every valid message lands in exactly one bucket, owner = dst % P,
    and payloads survive the trip (permutation invariance)."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, 1000, K).astype(np.int32)
    valid = rng.random(K) > 0.2
    pay = rng.normal(size=(K, 2)).astype(np.float32)
    cap = K + 8
    b_dst, b_pay, b_val, ovf = bucket_by_owner(
        jnp.asarray(dst), jnp.asarray(pay), jnp.asarray(valid), P, cap,
        sort_by_dst=False)
    assert int(ovf) == 0
    got = []
    bd, bp, bv = np.asarray(b_dst), np.asarray(b_pay), np.asarray(b_val)
    for q in range(P):
        ok = bv[q]
        assert (bd[q][ok] % P == q).all()
        got += [(int(d), tuple(np.round(p, 5)))
                for d, p in zip(bd[q][ok], bp[q][ok])]
    want = [(int(d), tuple(np.round(p, 5)))
            for d, p, v in zip(dst, pay, valid) if v]
    assert sorted(got) == sorted(want)


@given(st.integers(1, 400), st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_compact_preserves_true_indices(n, cap, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(n) > 0.5
    idx, cnt, ovf = compact(jnp.asarray(mask), cap)
    idx = np.asarray(idx)
    true_idx = np.where(mask)[0]
    keep = min(len(true_idx), cap)
    assert int(cnt) == keep
    assert int(ovf) == max(len(true_idx) - cap, 0)
    assert (idx[:keep] == true_idx[:keep]).all()
    assert (idx[keep:] == -1).all()


@given(st.integers(1, 100), st.integers(4, 64),
       st.sampled_from(["sum", "min", "max"]),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_groupby_strategies_agree(M, Np, op, seed):
    """scatter (hash) and sort group-bys compute identical dense combines
    — the paper's plan-equivalence invariant."""
    rng = np.random.default_rng(seed)
    slot = rng.integers(0, Np, M).astype(np.int32)
    pay = rng.normal(size=(M, 3)).astype(np.float32)
    valid = rng.random(M) > 0.3
    d1, h1 = scatter_combine_dense(jnp.asarray(slot), jnp.asarray(pay),
                                   jnp.asarray(valid), Np, op)
    from repro.core.groupby import MONOIDS
    fn, ident = MONOIDS[op]
    d2, h2 = sort_combine_dense(jnp.asarray(slot), jnp.asarray(pay),
                                jnp.asarray(valid), Np, fn,
                                jnp.full((3,), ident, jnp.float32))
    assert (np.asarray(h1) == np.asarray(h2)).all()
    has = np.asarray(h1)
    np.testing.assert_allclose(np.asarray(d1)[has], np.asarray(d2)[has],
                               atol=1e-5)


@given(st.integers(10, 200), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_segment_combine_kernel_matches_numpy(M, seed):
    """Kernel vs a direct numpy oracle (independent of the jnp ref)."""
    from repro.kernels.segment_combine.segment_combine import \
        segment_combine_pallas
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, max(M // 4, 1), M)).astype(np.int32)
    pay = rng.normal(size=(M, 2)).astype(np.float32)
    valid = np.ones(M, bool)
    f, last = segment_combine_pallas(jnp.asarray(seg), jnp.asarray(pay),
                                     jnp.asarray(valid), "sum",
                                     block_m=64, interpret=True)
    f, last = np.asarray(f), np.asarray(last)
    for s in np.unique(seg):
        rows = seg == s
        want = pay[rows].sum(axis=0)
        got = f[last & rows]
        assert got.shape == (1, 2)
        np.testing.assert_allclose(got[0], want, atol=1e-4)
