"""Property-based tests (hypothesis) on the system's invariants."""
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.connector import bucket_by_owner
from repro.core.groupby import (compact, scatter_combine_dense,
                                sort_combine_dense)

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.integers(2, 64), st.integers(1, 200), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_bucket_routing_is_a_partition(P, K, seed):
    """Every valid message lands in exactly one bucket, owner = dst % P,
    and payloads survive the trip (permutation invariance)."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, 1000, K).astype(np.int32)
    valid = rng.random(K) > 0.2
    pay = rng.normal(size=(K, 2)).astype(np.float32)
    cap = K + 8
    b_dst, b_pay, b_val, ovf = bucket_by_owner(
        jnp.asarray(dst), jnp.asarray(pay), jnp.asarray(valid), P, cap,
        sort_by_dst=False)
    assert int(ovf) == 0
    got = []
    bd, bp, bv = np.asarray(b_dst), np.asarray(b_pay), np.asarray(b_val)
    for q in range(P):
        ok = bv[q]
        assert (bd[q][ok] % P == q).all()
        got += [(int(d), tuple(np.round(p, 5)))
                for d, p in zip(bd[q][ok], bp[q][ok])]
    want = [(int(d), tuple(np.round(p, 5)))
            for d, p, v in zip(dst, pay, valid) if v]
    assert sorted(got) == sorted(want)


@given(st.integers(1, 400), st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_compact_preserves_true_indices(n, cap, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(n) > 0.5
    idx, cnt, ovf = compact(jnp.asarray(mask), cap)
    idx = np.asarray(idx)
    true_idx = np.where(mask)[0]
    keep = min(len(true_idx), cap)
    assert int(cnt) == keep
    assert int(ovf) == max(len(true_idx) - cap, 0)
    assert (idx[:keep] == true_idx[:keep]).all()
    assert (idx[keep:] == -1).all()


@given(st.integers(1, 100), st.integers(4, 64),
       st.sampled_from(["sum", "min", "max"]),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_groupby_strategies_agree(M, Np, op, seed):
    """scatter (hash) and sort group-bys compute identical dense combines
    — the paper's plan-equivalence invariant."""
    rng = np.random.default_rng(seed)
    slot = rng.integers(0, Np, M).astype(np.int32)
    pay = rng.normal(size=(M, 3)).astype(np.float32)
    valid = rng.random(M) > 0.3
    d1, h1 = scatter_combine_dense(jnp.asarray(slot), jnp.asarray(pay),
                                   jnp.asarray(valid), Np, op)
    from repro.core.groupby import MONOIDS
    fn, ident = MONOIDS[op]
    d2, h2 = sort_combine_dense(jnp.asarray(slot), jnp.asarray(pay),
                                jnp.asarray(valid), Np, fn,
                                jnp.full((3,), ident, jnp.float32))
    assert (np.asarray(h1) == np.asarray(h2)).all()
    has = np.asarray(h1)
    np.testing.assert_allclose(np.asarray(d1)[has], np.asarray(d2)[has],
                               atol=1e-5)


INT32_MAX = np.iinfo(np.int32).max


@given(st.integers(1, 300), st.integers(1, 3),
       st.sampled_from(["sum", "min", "max"]),
       st.sampled_from([32, 64, 128]),
       st.sampled_from([0.0, 0.3, 0.8, 1.0]),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_segment_fold_blocked_ref_vs_pallas_bitwise(M, D, op, bm, p_valid,
                                                    seed):
    """The engine's two fold paths (jnp blocked ref vs Pallas interpret)
    are BIT-FOR-BIT identical — including degenerate inputs: all-invalid
    streams (p_valid=0), int32-max sentinel keys, M not divisible by
    block_m (ragged final tile), and D=1 payloads."""
    from repro.kernels.segment_combine.ref import segment_combine_blocked
    from repro.kernels.segment_combine.segment_combine import \
        segment_combine_pallas
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, max(M // 3, 1), M)).astype(np.int32)
    valid = rng.random(M) < p_valid
    # invalid rows carry the engine's sentinel key (sender_combine keys
    # invalid lanes int32.max before the sort)
    seg = np.where(valid, seg, INT32_MAX).astype(np.int32)
    pay = rng.normal(size=(M, D)).astype(np.float32)
    args = (jnp.asarray(seg), jnp.asarray(pay), jnp.asarray(valid), op)
    f_r, l_r = segment_combine_blocked(*args, block_m=bm)
    f_p, l_p = segment_combine_pallas(*args, block_m=bm, interpret=True)
    assert np.array_equal(np.asarray(l_r), np.asarray(l_p))
    assert np.array_equal(np.asarray(f_r), np.asarray(f_p))
    # oracle: every marked row closes a maximal contiguous run of its key
    # and carries that run's fold over its valid rows
    f, last = np.asarray(f_p), np.asarray(l_p)
    red = {"sum": np.sum, "min": np.min, "max": np.max}[op]
    bounds = [0] + [i + 1 for i in range(M - 1) if seg[i] != seg[i + 1]] \
        + [M]
    n_marked = 0
    for a, b in zip(bounds[:-1], bounds[1:]):
        if valid[b - 1]:
            n_marked += 1
            assert last[b - 1]
            want = red(pay[a:b][valid[a:b]], axis=0)
            np.testing.assert_allclose(f[b - 1], want, atol=1e-4)
    assert int(last.sum()) == n_marked


def test_segment_fold_empty_and_all_sentinel():
    """Deterministic degenerate corners: an empty (all-invalid) stream and
    a stream of nothing but sentinel keys produce no marked rows, on both
    impls, bit-for-bit."""
    from repro.kernels.segment_combine.ref import segment_combine_blocked
    from repro.kernels.segment_combine.segment_combine import \
        segment_combine_pallas
    for M, D in [(1, 1), (7, 2), (64, 1)]:
        seg = jnp.full((M,), INT32_MAX, jnp.int32)
        pay = jnp.ones((M, D), jnp.float32)
        valid = jnp.zeros((M,), bool)
        f_r, l_r = segment_combine_blocked(seg, pay, valid, "sum",
                                           block_m=32)
        f_p, l_p = segment_combine_pallas(seg, pay, valid, "sum",
                                          block_m=32, interpret=True)
        assert not np.asarray(l_r).any() and not np.asarray(l_p).any()
        assert np.array_equal(np.asarray(f_r), np.asarray(f_p))


@given(st.sampled_from([(1, 40, 96), (2, 30, 64), (2, 257, 100)]),
       st.integers(1, 3),
       st.sampled_from([0.0, 0.2, 1.0]),
       st.booleans(),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_edge_gather_engine_matches_take_oracle(shape, V, p_inv, nonfinite,
                                                seed):
    """The engine's kernel gather (one-hot MXU matmul + class-channel
    non-finite reconstruction) reproduces take_along_axis EXACTLY on
    valid lanes — inf/-inf/nan included — and reads 0.0 on invalid lanes;
    degenerate inputs: all-invalid edge blocks (p_inv=1) and edge counts
    not divisible by the kernel block."""
    from repro.kernels import backend as kbackend
    P, Np, Ep = shape
    rng = np.random.default_rng(seed)
    src = rng.integers(0, Np, (P, Ep)).astype(np.int32)
    src = np.where(rng.random((P, Ep)) < p_inv, -1, src).astype(np.int32)
    vals = rng.normal(size=(P, Np, V)).astype(np.float32)
    if nonfinite:
        for bad in (np.inf, -np.inf, np.nan):
            mask = rng.random((P, Np, V)) < 0.05
            vals = np.where(mask, bad, vals).astype(np.float32)
    layout = kbackend.plan_edge_layout(src, Np)
    got = np.asarray(kbackend.edge_gather_values(
        jnp.asarray(vals), jnp.asarray(src), layout, impl_r="pallas"))
    want = np.take_along_axis(vals, np.maximum(src, 0)[:, :, None], axis=1)
    ok = src >= 0
    np.testing.assert_array_equal(got[ok], want[ok])
    assert (got[~ok] == 0.0).all()


@given(st.integers(10, 200), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_segment_combine_kernel_matches_numpy(M, seed):
    """Kernel vs a direct numpy oracle (independent of the jnp ref)."""
    from repro.kernels.segment_combine.segment_combine import \
        segment_combine_pallas
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, max(M // 4, 1), M)).astype(np.int32)
    pay = rng.normal(size=(M, 2)).astype(np.float32)
    valid = np.ones(M, bool)
    f, last = segment_combine_pallas(jnp.asarray(seg), jnp.asarray(pay),
                                     jnp.asarray(valid), "sum",
                                     block_m=64, interpret=True)
    f, last = np.asarray(f), np.asarray(last)
    for s in np.unique(seg):
        rows = seg == s
        want = pay[rows].sum(axis=0)
        got = f[last & rows]
        assert got.shape == (1, 2)
        np.testing.assert_allclose(got[0], want, atol=1e-4)
