"""Per-architecture smoke tests: REDUCED same-family configs, one forward
+ one train step on CPU, asserting output shapes and no NaNs (the full
configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import (forward_train, init_params, make_decode_step,
                          make_prefill_step, make_train_step)
from repro.optim import adamw_init


def _batch(cfg, B=2, S=32, rng=None):
    rng = rng or jax.random.PRNGKey(0)
    tok = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    if cfg.frontend == "audio":
        batch = {"frames": jnp.ones((B, S, cfg.d_model), jnp.float32),
                 "labels": tok}
    elif cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.ones((B, cfg.frontend_len, cfg.d_model),
                                         jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h, aux = forward_train(params, batch, cfg, remat=False)
    assert h.shape == (2, 32, cfg.d_model)
    assert not bool(jnp.isnan(h).any())
    ts = make_train_step(cfg)
    params2, opt2, metrics = jax.jit(ts)(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed (exact comparison: warmup LR updates are tiny)
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not (np.asarray(l0, np.float32)
                == np.asarray(l1, np.float32)).all()


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if not get_config(a).is_encoder])
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg)
    ps = make_prefill_step(cfg)
    tok, caches = jax.jit(ps)(params, batch)
    assert tok.shape == (2, 1)
    ds = make_decode_step(cfg)
    tok2, caches2 = jax.jit(ds)(params, tok, caches, jnp.int32(32))
    assert tok2.shape == (2, 1)
    assert int(tok2.min()) >= 0 and int(tok2.max()) < cfg.vocab_size


def test_decode_matches_teacher_forcing():
    """Greedy decode from a prefix must match argmax of the full forward
    (prefill/decode cache correctness, gemma3's local:global mix)."""
    cfg = get_config("gemma3-12b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(2))
    B, S = 1, 32
    tok = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    # full forward logits at the last position
    from repro.models.layers import unembed
    h, _ = forward_train(params, batch, cfg, remat=False)
    full_next = jnp.argmax(unembed(params["embed"], h[:, -1:]), axis=-1)
    ps = make_prefill_step(cfg)
    pre_next, _ = jax.jit(ps)(params, batch)
    assert int(full_next[0, 0]) == int(pre_next[0, 0])


def test_param_counts_near_published():
    """Analytic parameter counts are in the right ballpark for the
    headline sizes."""
    expect = {"yi-34b": 34e9, "falcon-mamba-7b": 7e9,
              "stablelm-12b": 12e9, "gemma3-12b": 12e9,
              "llama4-maverick-400b-a17b": 400e9}
    for name, n in expect.items():
        got = get_config(name).param_count()
        assert 0.6 * n < got < 1.45 * n, (name, got)


def test_moe_active_params():
    cfg = get_config("llama4-maverick-400b-a17b")
    a = cfg.active_param_count()
    assert a < 0.1 * cfg.param_count()
    assert 10e9 < a < 30e9  # ~17B active
