"""Graph mutation machinery (paper Figure 5 + Genomix use case): vertex
deletion with resolve, and the path-merging demo."""
import numpy as np

from repro.core import gather_values, load_graph, run_host
from repro.graph import PathMerge, chain_graph


def test_path_merge_compacts_chain():
    n = 32
    edges = chain_graph(n)
    pm = PathMerge(rounds=10)
    vert = load_graph(edges, n, P=2, value_dims=2)
    res = run_host(vert, pm, pm.suggested_plan, max_supersteps=12)
    vid = np.asarray(res.vertex.vid).reshape(-1)
    alive = (vid >= 0).sum()
    # chain interior collapses: strictly fewer vertices survive
    assert alive < n
    # accumulated length mass is conserved: total acc over survivors == n
    vals = np.asarray(res.vertex.value).reshape(-1, 2)
    acc = vals[np.asarray(res.vertex.vid).reshape(-1) >= 0, 0]
    assert np.isclose(acc.sum(), n), acc.sum()


def test_delete_tombstones_do_not_resurrect():
    n = 16
    edges = chain_graph(n)
    pm = PathMerge(rounds=6)
    vert = load_graph(edges, n, P=2, value_dims=2)
    res = run_host(vert, pm, pm.suggested_plan, max_supersteps=8)
    vid = np.asarray(res.vertex.vid)
    halt = np.asarray(res.vertex.halt)
    assert (halt[vid < 0] == True).all()  # noqa: E712
