"""Multi-device sharded parity + network-axis planner suite.

The tentpole claim: ``run_sharded`` — supersteps under ``shard_map`` on a
real device mesh with the bucket exchange as a ``jax.lax.all_to_all`` —
is BIT-FOR-BIT equal to the emulated-transport ``run_host`` for
PageRank / SSSP / CC across both connectors, including the per-worker
out-of-core mode (each worker's own TieredStore + spill dir) and a
mid-run capacity regrow that spans the exchange.

The device-dependent tests need a multi-device backend: they run in the
dedicated CI ``sharded`` job under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the tier-1 run,
which initializes jax with one device, skips them). Setting the flag at
module import only works when this file runs standalone — before any
other test has touched jax — hence the skipif, not an xfail.

The cost-model / readiness-protocol unit tests at the bottom are device
count independent and run everywhere.
"""
import dataclasses
import os
import pathlib
import tempfile

if "XLA_FLAGS" not in os.environ:   # effective only when run standalone
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np
import pytest

from repro.core import (EngineConfig, PhysicalPlan, gather_values,
                        load_graph, run_host)
from repro.core.sharded import (ExchangeReadiness, _exchange_wire_bytes,
                                run_sharded)
from repro.graph import SSSP, ConnectedComponents, PageRank, rmat_graph

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8 before jax init)")

N = 220
EDGES = rmat_graph(N, 1200, seed=7)
ALGOS = {
    "pagerank": (lambda: PageRank(N, iterations=6), 2),
    "sssp": (lambda: SSSP(source=3), 1),
    "cc": (lambda: ConnectedComponents(), 1),
}
_HOST_REF = {}   # (algo, connector, P) -> gathered values of run_host


def _host_ref(algo: str, connector: str, P: int = 8) -> np.ndarray:
    if (algo, connector, P) not in _HOST_REF:
        mk, vd = ALGOS[algo]
        prog = mk()
        plan = dataclasses.replace(prog.suggested_plan,
                                   connector=connector)
        vert = load_graph(EDGES, N, P=P, value_dims=vd)
        res = run_host(vert, prog, plan, max_supersteps=30)
        _HOST_REF[(algo, connector, P)] = gather_values(res.vertex, N)
    return _HOST_REF[(algo, connector, P)]


# ---------------------------------------------------------------------
# bit-for-bit parity: sharded all_to_all vs emulated transport
# ---------------------------------------------------------------------

@multi_device
@pytest.mark.parametrize("algo", list(ALGOS))
@pytest.mark.parametrize("connector", ["sort_merge", "scatter_gather"])
def test_sharded_matches_host(algo, connector):
    """P=8 partitions over 2 devices: the tiled all_to_all plus the
    dst-major reorder must reproduce the emulated exchange exactly —
    even float accumulation order agrees."""
    mk, vd = ALGOS[algo]
    prog = mk()
    plan = dataclasses.replace(prog.suggested_plan, connector=connector)
    vert = load_graph(EDGES, N, P=8, value_dims=vd)
    res = run_sharded(vert, prog, plan, devices=2, max_supersteps=30)
    assert np.array_equal(gather_values(res.vertex, N),
                          _host_ref(algo, connector))
    assert res.supersteps > 1
    recs = [s for s in res.stats if "exchange_stall_s" in s]
    assert len(recs) == res.supersteps
    assert all(s["n_workers"] == 2 and s["sharded"] for s in recs)
    assert all(s["exchange_bytes"] > 0 for s in recs)
    assert all(s["metrics"]["exchange.stall_s"] >= 0 for s in recs)


@multi_device
def test_sharded_more_workers():
    """Worker count is a pure execution knob: 4 devices, same bits."""
    prog = SSSP(source=3)
    vert = load_graph(EDGES, N, P=8, value_dims=1)
    res = run_sharded(vert, prog, prog.suggested_plan, devices=4,
                      max_supersteps=30)
    assert np.array_equal(gather_values(res.vertex, N),
                          _host_ref("sssp", "partitioning"))


@multi_device
@pytest.mark.parametrize("algo", list(ALGOS))
@pytest.mark.parametrize("connector", ["sort_merge", "scatter_gather"])
def test_sharded_ooc_matches_host(algo, connector, tmp_path):
    """Per-worker tiered stores with disk spill dirs: 2 workers x 4
    partitions each, 2 resident at a time, 16 KiB DRAM budget per store
    (forces paging). Still bit-for-bit."""
    mk, vd = ALGOS[algo]
    prog = mk()
    plan = dataclasses.replace(prog.suggested_plan, connector=connector)
    vert = load_graph(EDGES, N, P=8, value_dims=vd)
    res = run_sharded(vert, prog, plan, devices=2, budget_partitions=2,
                      disk_dir=str(tmp_path),
                      memory_budget_bytes=16 * 1024, max_supersteps=30)
    assert np.array_equal(gather_values(res.vertex, N),
                          _host_ref(algo, connector))
    # each worker spilled into ITS OWN tier directory
    for w in range(2):
        assert pathlib.Path(tmp_path, f"worker{w}").is_dir()
    recs = [s for s in res.stats if "exchange_stall_s" in s]
    assert recs and all(s["spill"] for s in recs)
    assert all(s["n_workers"] == 2 for s in recs)


@multi_device
def test_sharded_ooc_traced_observability(tmp_path):
    """Observability under the sharded disk-tier driver: a traced run
    must show (a) spans from the main loop AND the per-worker tiered
    stores' I/O engine threads, (b) the separately-timed all_to_all as
    ``exchange``-category spans (one per superstep), and (c) the
    exchange counters landing in ``SuperstepStats.extra["metrics"]``."""
    from repro.obs import chrome_trace, trace, validate_chrome_trace
    prog = PageRank(N, iterations=6)
    vert = load_graph(EDGES, N, P=8, value_dims=2)
    trace.start()
    try:
        res = run_sharded(vert, prog, prog.suggested_plan, devices=2,
                          budget_partitions=2, disk_dir=str(tmp_path),
                          memory_budget_bytes=16 * 1024, io_threads=2,
                          max_supersteps=30)
    finally:
        tracer = trace.stop()
    obj = chrome_trace(tracer)
    # (a) per-worker spans: main thread + the stores' io engines
    summary = validate_chrome_trace(obj, min_threads=3)
    assert any(t.startswith("pregelix-io-")
               for t in summary["thread_names"])
    # (b) the exchange stage is its own span category — the OOC driver
    # times one all_to_all per destination round (4 partitions/worker at
    # budget 2 -> 2 rounds per superstep)
    ex_spans = [e for e in obj["traceEvents"]
                if e.get("ph") == "X" and e.get("cat") == "exchange"]
    assert "exchange" in summary["categories"]
    assert len(ex_spans) == 2 * res.supersteps
    assert all(e["dur"] >= 0 for e in ex_spans)
    # (c) exchange counters in the per-superstep metrics snapshots
    recs = [s for s in res.stats if "exchange_stall_s" in s]
    assert recs and len(recs) == res.supersteps
    for s in recs:
        m = s["metrics"]
        assert m["exchange.bytes"] > 0
        assert m["exchange.stall_s"] >= 0


@multi_device
def test_sharded_regrow_spans_exchange():
    """bucket_cap=2 overflows on superstep 0 in BOTH modes; the sharded
    OOC redo must end-pad the already-landed inbox pages to the grown
    run width and still match the host run bit-for-bit."""
    prog = SSSP(source=3)
    ref = _host_ref("sssp", "partitioning")
    # in-memory sharded
    vert = load_graph(EDGES, N, P=8, value_dims=1)
    ec = EngineConfig(n_parts=8, bucket_cap=2,
                      frontier_cap=vert.capacity + 8)
    res = run_sharded(vert, prog, prog.suggested_plan, devices=2, ec=ec,
                      max_supersteps=30)
    assert [s for s in res.stats if s.get("event") == "regrow"]
    assert np.array_equal(gather_values(res.vertex, N), ref)
    # OOC sharded: the regrow lands MID-EXCHANGE (later rounds overflow
    # after earlier rounds already landed runs into gen+1 pages)
    with tempfile.TemporaryDirectory() as td:
        vert = load_graph(EDGES, N, P=8, value_dims=1)
        ec = EngineConfig(n_parts=8, bucket_cap=2,
                          frontier_cap=vert.capacity + 8)
        res = run_sharded(vert, prog, prog.suggested_plan, devices=2,
                          ec=ec, budget_partitions=2, disk_dir=td,
                          memory_budget_bytes=16 * 1024,
                          max_supersteps=30)
    assert [s for s in res.stats if s.get("event") == "regrow"]
    assert np.array_equal(gather_values(res.vertex, N), ref)


@multi_device
def test_sharded_auto_plan():
    """plan="auto" on the mesh: the planner sees sharded=True/n_workers
    and the run still matches; exchange EWMA feeds net_scale without
    destabilizing the choice on a small graph."""
    prog = PageRank(N, iterations=6)
    vert = load_graph(EDGES, N, P=8, value_dims=2)
    res = run_sharded(vert, prog, "auto", devices=2, max_supersteps=30)
    assert res.plan.kernel_impl == "ref"   # pinned under shard_map
    # parity against a host run of the SAME resolved plan (the auto
    # choice may differ from the suggested plan, and groupby/join change
    # float accumulation order)
    assert not [s for s in res.stats if s.get("event") == "plan-switch"]
    vert2 = load_graph(EDGES, N, P=8, value_dims=2)
    ref = run_host(vert2, prog, res.plan, max_supersteps=30)
    assert np.array_equal(gather_values(res.vertex, N),
                          gather_values(ref.vertex, N))


@multi_device
def test_make_host_mesh_device_count():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(devices=2)
    assert int(mesh.devices.size) == 2
    assert mesh.axis_names == ("data",)
    with pytest.raises(RuntimeError, match="host_platform_device_count"):
        make_host_mesh(devices=len(jax.devices()) + 1)


@multi_device
def test_sharded_rejects_indivisible():
    vert = load_graph(EDGES, N, P=6, value_dims=1)
    with pytest.raises(ValueError, match="divide"):
        run_sharded(vert, SSSP(source=3), devices=4)


# ---------------------------------------------------------------------
# device-count-independent units: readiness protocol, network cost axis
# ---------------------------------------------------------------------

def test_exchange_readiness_protocol():
    """A destination round is dispatchable only when every remote
    (src_worker, src_round) pair has landed its runs."""
    rd = ExchangeReadiness(n_workers=2, n_rounds=2)
    assert not rd.ready(0, 0)
    rd.land(0, 0, src_round=0)      # all workers' round-0 runs land
    assert not rd.ready(0, 0)       # round-1 sources still missing
    assert rd.missing(0, 0) == [(0, 1), (1, 1)]
    rd.land(0, 0, src_round=1)
    assert rd.ready(0, 0)
    assert not rd.ready_round(0)    # worker 1's page not landed
    rd.land(1, 0, src_round=0)
    rd.land(1, 0, src_round=1)
    assert rd.ready_round(0)
    assert not rd.ready_round(1)


def test_exchange_wire_bytes():
    # (P=8 rows) x (8 buckets) x (C=4 slots) x (dst 4B + 2x4B payload
    # + 1B valid), half of it remote on 2 workers
    total = 8 * 8 * 4 * 13
    assert _exchange_wire_bytes(8, 8, 4, 2, 2) == total // 2
    assert _exchange_wire_bytes(8, 8, 4, 2, 1) == 0   # single worker


def test_cost_model_network_axis():
    """The sharded observation routes (P - P_local)/P of the exchange
    through net_bw + per-stage latency; more workers -> more net
    seconds; net_scale calibrates it."""
    from repro.planner import EMULATED_MACHINE
    from repro.planner.cost import GraphStats, Observation, estimate

    g = GraphStats(n_vertices=N, n_edges=1200, n_partitions=8,
                   vertex_capacity=64, edge_capacity=256,
                   value_dims=2, msg_dims=2)
    plan = PhysicalPlan()
    local = estimate(plan, g, Observation(frontier_density=1.0),
                     EMULATED_MACHINE)
    assert local.net_seconds == 0.0
    obs2 = Observation(frontier_density=1.0, sharded=True, n_workers=2)
    obs4 = Observation(frontier_density=1.0, sharded=True, n_workers=4)
    c2 = estimate(plan, g, obs2, EMULATED_MACHINE)
    c4 = estimate(plan, g, obs4, EMULATED_MACHINE)
    assert c2.net_seconds > 0.0
    assert c4.net_bytes > c2.net_bytes     # more remote traffic
    assert "exchange_net" in c2.terms
    # the latency term keeps CPU-mesh predictions in the measurable
    # regime: one stage >= net_latency_s
    assert c2.net_seconds >= EMULATED_MACHINE.net_latency_s
    # net_scale closes the measurement loop multiplicatively
    scaled = estimate(plan, g,
                      dataclasses.replace(obs2, net_scale=2.0),
                      EMULATED_MACHINE)
    assert scaled.net_seconds == pytest.approx(2 * c2.net_seconds)
    # net seconds enter the total
    assert c2.seconds() > local.seconds() - 1e-12


def test_adaptive_exchange_ewma_calibrates_net_scale():
    """The controller EWMAs measured exchange stalls and divides by the
    analytic net leg of the current plan -> Observation.net_scale."""
    from repro.planner import AdaptiveConfig, EMULATED_MACHINE
    from repro.planner.adaptive import AdaptiveController
    from repro.planner.cost import GraphStats, estimate
    from repro.planner.stats import StatsCollector

    g = GraphStats(n_vertices=N, n_edges=1200, n_partitions=8,
                   vertex_capacity=64, edge_capacity=256,
                   value_dims=2, msg_dims=2)
    plan = PhysicalPlan()
    prog = PageRank(N, iterations=6)
    ctrl = AdaptiveController(prog, g, plan, config=AdaptiveConfig(),
                              machine=EMULATED_MACHINE)
    coll = StatsCollector(n_partitions=8, vertex_capacity=64,
                          msg_dims=2, n_vertices=N)
    stall = 4e-3
    for i in range(1, 5):
        rec = coll.record(i, active=N, messages=1200, wall_s=0.01,
                          recompiled=(i == 1), sharded=True, n_workers=2,
                          exchange_bytes=1e5, exchange_stall_s=stall)
        ctrl.observe(rec, bucket_cap=0)
    assert ctrl._exchange_ewma == pytest.approx(stall)
    obs = ctrl._make_observation(rec, bucket_cap=0)
    assert obs.sharded and obs.n_workers == 2
    analytic = estimate(plan, g, dataclasses.replace(obs, net_scale=1.0),
                        EMULATED_MACHINE).net_seconds
    assert obs.net_scale == pytest.approx(
        min(max(stall / analytic, 0.125), 8.0))
    # state round-trips through checkpoints
    state = ctrl.state_dict()
    ctrl2 = AdaptiveController(prog, g, plan, config=AdaptiveConfig(),
                               machine=EMULATED_MACHINE)
    ctrl2.load_state(state)
    assert ctrl2._exchange_ewma == pytest.approx(stall)


def test_sharded_ooc_rejects_mutations():
    from repro.graph.algorithms import PathMerge
    vert = load_graph(EDGES, N, P=8, value_dims=2)
    with pytest.raises(NotImplementedError, match="mutat"):
        run_sharded(vert, PathMerge(), devices=1, budget_partitions=2)
