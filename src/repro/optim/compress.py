"""int8 error-feedback gradient compression (1-bit-Adam-family trick).

Used by the host-loop trainer to cut DP all-reduce bytes ~4x: gradients are
quantized to int8 with per-tensor scales before the data-parallel reduction;
the quantization residual is fed back into the next step (error feedback
keeps the compression unbiased in the long run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_gradients(grads, error_fbk):
    def comp(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(jnp.float32) * scale
        return (q, scale), new_e

    flat, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_fbk)
    out = [comp(g, e) for g, e in zip(flat, flat_e)]
    qs = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in out])
    return qs, new_err


def decompress_gradients(qs):
    def dec(t):
        q, scale = t
        return q.astype(jnp.float32) * scale
    return jax.tree.map(dec, qs,
                        is_leaf=lambda x: isinstance(x, tuple))
