"""AdamW with global-norm clipping and cosine schedule (pure JAX pytrees).

Moments shard exactly like the parameters (so FSDP'd params get ZeRO-sharded
optimizer state for free). ``moment_dtype`` drops moments to bf16 for the
largest archs (llama4-400B on 256 v5e chips needs it to fit HBM — recorded
in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params, moment_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    warm = peak_lr * (step + 1) / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def adamw_update(grads, opt_state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mn = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vn = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        u = (mn / bc1) / (jnp.sqrt(vn / bc2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        pn = p.astype(jnp.float32) - lr * u
        return pn.astype(p.dtype), mn.astype(m.dtype), vn.astype(v.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}
