from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               cosine_schedule)
from repro.optim.compress import (compress_gradients, decompress_gradients,
                                  init_error_feedback)

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_schedule", "compress_gradients", "decompress_gradients",
           "init_error_feedback"]
