"""The Pregel state as relations (paper Table 1), adapted to dense sharded
arrays.

Vertex(vid, halt, value, edges) / Msg(vid, payload) / GS(halt, aggregate,
superstep) — stored struct-of-arrays with a leading partition axis P.
Hash partitioning by vid (the paper's default): owner(vid) = vid % P,
local slot = vid // P, so the dense slot array IS the vid index (the
B-tree analogue: O(1) probe = array indexing).

Edges are owned by their source partition as flat (edge_slot -> src slot,
dst vid, value) arrays — the CSR adaptation for edge-parallel sends.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclass
class VertexRel:
    vid: jax.Array        # (P, Np) int32, -1 = empty slot
    halt: jax.Array       # (P, Np) bool
    value: jax.Array      # (P, Np, V) float32
    edge_src: jax.Array   # (P, Ep) int32 local src slot, -1 = pad
    edge_dst: jax.Array   # (P, Ep) int32 global dst vid
    edge_val: jax.Array   # (P, Ep) float32

    @property
    def num_partitions(self) -> int:
        return self.vid.shape[0]

    @property
    def capacity(self) -> int:
        return self.vid.shape[1]


@jax.tree_util.register_dataclass
@dataclass
class MsgRel:
    dst: jax.Array        # (P, M) int32 global dst vid, -1 = invalid
    payload: jax.Array    # (P, M, D) float32
    valid: jax.Array      # (P, M) bool

    @property
    def capacity(self) -> int:
        return self.dst.shape[1]


# GlobalState.overflow attributes every capacity overflow to its source,
# so a regrow can double ONLY the capacity that actually overflowed — a
# frontier overflow no longer drags the bucket tensors (the device-memory
# hot spot on the budgeted OOC path) along with it.
OVF_BUCKET = 0     # message bucket capacity (EngineConfig.bucket_cap)
OVF_FRONTIER = 1   # left-outer frontier compaction (frontier_cap)
OVF_MUTATION = 2   # insert-proposal buckets (mutation_cap)
OVF_EDGE = 3       # frontier edge-stream compaction (scales with
                   # frontier_cap: EF = frontier_cap * 8)
N_OVERFLOW = 4


@jax.tree_util.register_dataclass
@dataclass
class GlobalState:
    halt: jax.Array         # () bool
    aggregate: jax.Array    # (A,) float32 user aggregate
    superstep: jax.Array    # () int32
    overflow: jax.Array     # (N_OVERFLOW,) int32 dropped tuples per source
                            # (bucket / frontier / mutation / edge)
    active_count: jax.Array  # () int32 (statistics collector)
    msg_count: jax.Array     # () int32


def empty_msgs(P: int, M: int, D: int) -> MsgRel:
    return MsgRel(dst=jnp.full((P, M), -1, jnp.int32),
                  payload=jnp.zeros((P, M, D), jnp.float32),
                  valid=jnp.zeros((P, M), bool))


def init_gs(agg_dims: int) -> GlobalState:
    return GlobalState(halt=jnp.array(False),
                       aggregate=jnp.zeros((agg_dims,), jnp.float32),
                       superstep=jnp.array(0, jnp.int32),
                       overflow=jnp.zeros((N_OVERFLOW,), jnp.int32),
                       active_count=jnp.array(0, jnp.int32),
                       msg_count=jnp.array(0, jnp.int32))


def load_graph(edges: np.ndarray, num_vertices: int, P: int, *,
               value_dims: int, edge_values: np.ndarray | None = None,
               capacity_factor: float = 1.3,
               partition: str = "hash") -> VertexRel:
    """Partition an edge list (E, 2) into a VertexRel (the paper's bulk
    load: scan, partition by vid, sort, bulk-load per-partition indexes).

    partition="hash" (paper default): vid lives at (vid % P, vid // P).
    partition="range": vid lives at (vid // cap, vid % cap) — owners are
    contiguous in vid order (see PhysicalPlan.partition); capacity_factor
    is forced to 1.0 (no insert headroom).
    """
    edges = np.asarray(edges, dtype=np.int64)
    if partition == "range":
        capacity_factor = 1.0
    Np = int(np.ceil(num_vertices / P) * capacity_factor) + 1

    def owner_slot(v):
        if partition == "range":
            o = np.minimum(v // Np, P - 1)
            return o, v - o * Np
        return v % P, v // P

    vid = np.full((P, Np), -1, np.int32)
    halt = np.zeros((P, Np), bool)
    value = np.zeros((P, Np, value_dims), np.float32)
    all_v = np.arange(num_vertices, dtype=np.int64)
    po, ps = owner_slot(all_v)
    vid[po, ps] = all_v.astype(np.int32)

    src, dst = edges[:, 0], edges[:, 1]
    ev = (np.asarray(edge_values, np.float32) if edge_values is not None
          else np.ones(len(src), np.float32))
    owner, slot = owner_slot(src)
    order = np.argsort(owner * (num_vertices + 1) + src, kind="stable")
    src, dst, ev = src[order], dst[order], ev[order]
    owner, slot = owner[order], slot[order]
    counts = np.bincount(owner, minlength=P)
    Ep = int(max(counts.max(), 1))
    e_src = np.full((P, Ep), -1, np.int32)
    e_dst = np.full((P, Ep), -1, np.int32)
    e_val = np.zeros((P, Ep), np.float32)
    start = 0
    for p in range(P):
        c = counts[p]
        e_src[p, :c] = slot[start:start + c].astype(np.int32)
        e_dst[p, :c] = dst[start:start + c].astype(np.int32)
        e_val[p, :c] = ev[start:start + c]
        start += c
    return VertexRel(vid=jnp.asarray(vid), halt=jnp.asarray(halt),
                     value=jnp.asarray(value),
                     edge_src=jnp.asarray(e_src),
                     edge_dst=jnp.asarray(e_dst),
                     edge_val=jnp.asarray(e_val))


def out_degrees(vert: VertexRel) -> jax.Array:
    """(P, Np) out-degree per vertex slot."""
    P, Np = vert.vid.shape
    valid = vert.edge_src >= 0

    def per_part(src, val):
        return jnp.zeros((Np,), jnp.float32).at[
            jnp.where(val, src, Np)].add(val.astype(jnp.float32),
                                         mode="drop")

    return jax.vmap(per_part)(vert.edge_src, valid)


def gather_values(vert: VertexRel, num_vertices: int) -> np.ndarray:
    """Dump the Vertex relation back out (HDFS write analogue):
    -> (num_vertices, V) in vid order."""
    P, Np, V = vert.value.shape
    vid = np.asarray(vert.vid).reshape(-1)
    val = np.asarray(vert.value).reshape(-1, V)
    out = np.zeros((num_vertices, V), np.float32)
    ok = vid >= 0
    out[vid[ok]] = val[ok]
    return out
