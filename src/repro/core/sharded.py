"""Multi-device sharded superstep driver (the paper's cluster story on a
real device mesh).

``run_host`` rolls one host's frontier; ``run_out_of_core`` streams
super-partitions through ONE device. This driver is the missing axis:
``run_sharded`` maps the partition dimension onto a ``jax.make_mesh`` of
N devices and runs the bucketed m-to-n exchange as a REAL
``jax.lax.all_to_all`` (``connector.exchange_shard_map``) instead of the
emulated transpose. Worker w owns the contiguous global partitions
[w * P/N, (w+1) * P/N) — exactly the tiled all_to_all chunking of the
bucket axis, which is what makes the sharded run bit-for-bit equal to
the emulated transport (``tests/test_sharded.py``).

Two modes:

* **In-memory** (default): one shard_map-wrapped jitted superstep per
  iteration, with the message exchange split out as its OWN jitted
  all_to_all stage (``EngineConfig.exchange_apart``) so the driver can
  time it — each superstep records an ``exchange`` span plus
  ``exchange_bytes`` / ``exchange_stall_s`` counters, the measurements
  behind the planner's network axis (``MachineModel.net_bw``,
  ``Observation.net_scale``). GS folds via the superstep's own psum
  reductions; vote-to-halt, overflow-regrow, adaptive replanning and
  frontier refit all work exactly as in ``run_host``.

* **Out-of-core** (``budget_partitions`` set): every worker gets its OWN
  ``TieredStore`` (+ background ``IOEngine`` when a disk dir is set, at
  ``disk_dir/worker{w}``) so the storage tiers shard with the graph.
  Workers stream their partition blocks through the device in lockstep
  rounds; each round's collected buckets cross the mesh through the raw
  (worker-major) all_to_all and LAND into per-destination-round inbox
  pages. The per-destination readiness protocol extends to the
  distributed setting: a destination round dispatches only when ALL
  remote sources have landed its runs (``ExchangeReadiness``). A mid-run
  regrow can span the exchange — already-landed pages are end-padded to
  the new run width (valid entries are a bucket prefix, so padding
  preserves the run layout) and the overflowed round is redone.
  Mutating programs are not supported sharded+OOC (the host mutation
  inbox is not distributed yet).

CI exercises all of it on CPU under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as PSpec

from repro.core import connector
from repro.core.driver import (PlanArg, RunResult, _regrow_msgs,
                               _resolve_plan, apply_kernel_impl,
                               default_engine_config, grow_overflowed,
                               init_vertex_values)
from repro.core.plan import FRONTIER_FLOOR, PhysicalPlan
from repro.core.program import VertexProgram
from repro.core.relations import (GlobalState, MsgRel, VertexRel,
                                  empty_msgs, init_gs)
from repro.core.superstep import EngineConfig, make_superstep
from repro.obs import explain, memwatch, trace
from repro.obs.metrics import MetricsRegistry

_MSG_W = lambda D: (1 + D) * 4 + 1   # dst + payload + valid wire bytes


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map across jax versions (same fallbacks as pregel_run)."""
    try:
        from jax import shard_map
    except ImportError:      # JAX < 0.6 keeps shard_map in experimental
        from jax.experimental.shard_map import shard_map
    try:
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except TypeError:        # older shard_map spells check_vma check_rep
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def _lead_spec(axes):
    """Leading-axis sharding spec builder: dim 0 over the mesh axes."""
    return lambda x: PSpec(*([axes] + [None] * (len(x.shape) - 1)))


def _sharded_machine():
    """Machine model for the sharded driver's planner: roofline constants
    for the backend we actually run on (the CPU fake-device mesh prices
    like the emulated machine — same memory system, ms-class dispatch
    latency per exchange stage), TPU-class otherwise."""
    from repro.planner import DEFAULT_MACHINE, EMULATED_MACHINE
    return (EMULATED_MACHINE if jax.default_backend() == "cpu"
            else DEFAULT_MACHINE)


def _exchange_wire_bytes(P: int, n_parts: int, C: int, D: int,
                         n_workers: int) -> int:
    """Capacity-based bytes the all_to_all moves BETWEEN workers: the
    bucket block is (P, n_parts, C) slots of (dst+payload+valid), and
    (N-1)/N of every worker's slots target remote workers."""
    total = P * n_parts * C * _MSG_W(D)
    return int(total * (n_workers - 1) / max(n_workers, 1))


def _fit_devices(P: int, healthy: int) -> int:
    """Largest worker count ≤ ``healthy`` that P partitions divide over —
    the elastic re-mesh rule. P itself never changes on recovery, so the
    replay stays bit-for-bit (per-partition results are device-count
    invariant); only the blocks-per-worker mapping shrinks."""
    for n in range(min(max(healthy, 1), P), 0, -1):
        if P % n == 0:
            return n
    return 1


class ExchangeReadiness:
    """Distributed per-destination readiness bookkeeping.

    The barrier-free OOC executor dispatches a destination when all LOCAL
    sources have produced its runs; on a mesh the sources are remote. A
    destination round (dst_worker, dst_round) becomes dispatchable for
    superstep i+1 once every (src_worker, src_round) pair of superstep i
    has landed its runs into the destination's inbox page — tracked here,
    asserted at dispatch, and surfaced as the distributed readiness
    stall when a dispatch has to wait."""

    def __init__(self, n_workers: int, n_rounds: int):
        self.n_workers = n_workers
        self.n_rounds = n_rounds
        self._landed: dict = {}   # (dst_w, dst_r) -> {(src_w, src_r)}

    def land(self, dst_worker: int, dst_round: int, src_round: int):
        """Record that ALL source workers' round-`src_round` runs landed
        for (dst_worker, dst_round) — one all_to_all delivers every
        source worker's chunk at once."""
        s = self._landed.setdefault((dst_worker, dst_round), set())
        s.update((w, src_round) for w in range(self.n_workers))

    def ready(self, dst_worker: int, dst_round: int) -> bool:
        got = self._landed.get((dst_worker, dst_round), ())
        return len(got) == self.n_workers * self.n_rounds

    def ready_round(self, dst_round: int) -> bool:
        return all(self.ready(w, dst_round)
                   for w in range(self.n_workers))

    def missing(self, dst_worker: int, dst_round: int) -> list:
        got = self._landed.get((dst_worker, dst_round), set())
        return sorted({(w, r) for w in range(self.n_workers)
                       for r in range(self.n_rounds)} - got)


def run_sharded(vert: VertexRel, program: VertexProgram,
                plan: PlanArg = PhysicalPlan(), *,
                mesh=None, devices: Optional[int] = None,
                max_supersteps: int = 50,
                ec: Optional[EngineConfig] = None,
                on_superstep: Optional[Callable] = None,
                auto_config=None, auto_space: Optional[dict] = None,
                kernel_impl: Optional[str] = None,
                budget_partitions: int = 0,
                disk_dir: Optional[str] = None,
                memory_budget_bytes: Optional[int] = None,
                io_threads: Optional[int] = None,
                readahead_pages: int = 8,
                eviction: str = "lru",
                checkpoint_every: int = 0,
                checkpoint_dir: Optional[str] = None,
                resume_from: Optional[str] = None,
                recover: bool = False,
                max_retries: int = 3,
                machine=None) -> RunResult:
    """Run `program` on a device mesh. ``mesh`` (or ``devices`` for a 1-D
    host mesh) sets the worker count N; the P partitions shard over it in
    contiguous blocks. With ``budget_partitions`` set, each worker
    streams its block through the device ``budget_partitions`` at a time
    from its own tiered store (per-worker OOC). ``on_superstep`` is
    called as ``on_superstep(i, stats_dict)``.

    ``checkpoint_every``/``checkpoint_dir`` snapshot the gathered global
    relations as npz at superstep boundaries (in-memory mode only);
    ``resume_from=<ckpt npz>`` restarts from one. ``recover=True`` runs
    under the failure manager's recovery supervisor: a recoverable
    failure blacklists the failed worker, restores the latest VALID
    checkpoint, re-meshes onto the largest divisor of P that fits the
    surviving device count (P itself never changes, so the replay is
    bit-for-bit — per-partition results are device-count invariant),
    and replays."""
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import faults

    t0 = time.time()
    if mesh is None:
        mesh = make_host_mesh(devices)
    axes = tuple(mesh.axis_names)
    N = int(mesh.devices.size)
    P = vert.num_partitions
    if P % N:
        raise ValueError(f"n_partitions {P} must divide over {N} devices")
    machine = machine or _sharded_machine()

    if recover:
        from repro.runtime.checkpoint import latest_checkpoint
        from repro.runtime.failure import supervised_run

        def _attempt(healthy, resume):
            return run_sharded(
                vert, program, plan, mesh=None,
                devices=_fit_devices(P, healthy),
                max_supersteps=max_supersteps, ec=ec,
                on_superstep=on_superstep, auto_config=auto_config,
                auto_space=auto_space, kernel_impl=kernel_impl,
                budget_partitions=budget_partitions, disk_dir=disk_dir,
                memory_budget_bytes=memory_budget_bytes,
                io_threads=io_threads, readahead_pages=readahead_pages,
                eviction=eviction, checkpoint_every=checkpoint_every,
                checkpoint_dir=checkpoint_dir, resume_from=resume,
                recover=False, machine=machine)

        def _pick(bad):
            if not checkpoint_dir:
                return None
            return latest_checkpoint(checkpoint_dir, skip=bad,
                                     verify=True)

        return supervised_run(_attempt, _pick, n_workers=N,
                              max_retries=max_retries,
                              initial_resume=resume_from)

    if budget_partitions:
        if checkpoint_every or resume_from:
            raise ValueError("sharded npz checkpointing is in-memory "
                             "mode only (per-worker OOC stores keep "
                             "their state on their own disk tiers)")
        return _run_sharded_ooc(
            vert, program, plan, mesh=mesh, axes=axes, n_workers=N,
            max_supersteps=max_supersteps, ec=ec,
            budget_partitions=budget_partitions, disk_dir=disk_dir,
            memory_budget_bytes=memory_budget_bytes,
            io_threads=io_threads, readahead_pages=readahead_pages,
            eviction=eviction, machine=machine, kernel_impl=kernel_impl,
            auto_space=auto_space, on_superstep=on_superstep, t0=t0)

    from repro.planner.cost import Observation
    from repro.planner.stats import StatsCollector
    from repro.runtime.checkpoint import save_checkpoint

    i0, rmsg, rgs = 0, None, None
    if resume_from is not None:
        from repro.runtime.checkpoint import load_checkpoint
        vert, rmsg, rgs = load_checkpoint(resume_from)
        if vert.num_partitions != P:
            raise ValueError(
                f"checkpoint has {vert.num_partitions} partitions; the "
                f"sharded driver resumes at a fixed P={P}")
        i0 = int(rgs.superstep)
    plan, auto_space = apply_kernel_impl(plan, kernel_impl, auto_space)
    if not isinstance(plan, PhysicalPlan):
        # pin the kernel dispatch to the jnp reference inside shard_map
        # unless the caller asked for something else (pallas_call under
        # shard_map is untested here)
        auto_space = dict(auto_space or {})
        auto_space.setdefault("kernel_impls", ("ref",))
    obs0 = Observation(frontier_density=1.0, sharded=True, n_workers=N)
    plan, controller = _resolve_plan(vert, program, plan, adaptive=True,
                                     auto_config=auto_config,
                                     auto_space=auto_space,
                                     machine=machine, obs0=obs0)
    ec = ec or default_engine_config(vert, program, plan)
    ec = dataclasses.replace(ec, axis_name=axes, exchange_apart=True)
    if rmsg is not None and rmsg.capacity > ec.n_parts * ec.bucket_cap:
        ec = dataclasses.replace(
            ec, bucket_cap=-(-rmsg.capacity // ec.n_parts))
    if explain.enabled():
        explain.attach(
            program, vert=vert,
            g=controller.g if controller is not None else None,
            plan=plan, machine=machine, space_kw=auto_space)
    if memwatch.enabled():
        memwatch.configure(ec=ec, Np=vert.capacity,
                           Ep=vert.edge_src.shape[1],
                           value_dims=program.value_dims,
                           msg_dims=program.msg_dims)

    lead = _lead_spec(axes)
    rep = lambda x: PSpec()
    put_lead = lambda tree: jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, lead(x))), tree)
    put_rep = lambda tree: jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, PSpec())), tree)

    def build_step(plan, ec):
        """shard_map-wrapped jitted superstep (exchange_apart: returns
        the pre-exchange buckets as new_msg) + the separately-timed
        all_to_all exchange stage."""
        fn = make_superstep(program, plan, ec)
        body = lambda v, m, g: fn(v, m, g, None, None)

        # out_specs are written by hand: the body contains psums over the
        # mesh axes, so eval_shape outside shard_map would fail on the
        # unbound axis names
        v_specs = jax.tree.map(lead, vert)
        m_specs = MsgRel(dst=PSpec(axes, None),
                         payload=PSpec(axes, None, None),
                         valid=PSpec(axes, None))
        g_specs = jax.tree.map(rep, init_gs(program.agg_dims))
        bkt_specs = MsgRel(dst=PSpec(axes, None, None),
                           payload=PSpec(axes, None, None, None),
                           valid=PSpec(axes, None, None))
        in_specs = (v_specs, m_specs, g_specs)
        out_specs = (v_specs, bkt_specs, g_specs)
        step = jax.jit(_shard_map(body, mesh, in_specs, out_specs))

        def ex_body(m: MsgRel) -> MsgRel:
            r_dst, r_pay, r_val = connector.exchange_shard_map(
                m.dst, m.payload, m.valid, axes)
            P_l = m.dst.shape[0]
            flat = lambda a: a.reshape((P_l, -1) + a.shape[3:])
            return MsgRel(dst=flat(r_dst), payload=flat(r_pay),
                          valid=flat(r_val))

        ex = jax.jit(_shard_map(ex_body, mesh, (bkt_specs,), m_specs))
        return step, ex

    step, exchange = build_step(plan, ec)
    if rgs is not None:
        gs = put_rep(rgs)
        vert = put_lead(vert)
        msg = put_lead(_regrow_msgs(rmsg, ec))
    else:
        gs = init_gs(program.agg_dims)
        vert = init_vertex_values(vert, program, gs)
        vert = put_lead(vert)
        gs = put_rep(gs)
        msg = put_lead(empty_msgs(P, ec.n_parts * ec.bucket_cap,
                                  program.msg_dims))

    n_live = (controller.g.n_vertices if controller is not None
              else int(jnp.sum(vert.vid >= 0)))
    metrics = MetricsRegistry()
    coll = StatsCollector(n_partitions=P, vertex_capacity=vert.capacity,
                          msg_dims=program.msg_dims, n_vertices=n_live,
                          metrics=metrics)
    m_exb = metrics.counter("exchange.bytes")
    m_exs = metrics.counter("exchange.stall_s")
    m_regrows = metrics.counter("host.regrows")
    m_switches = metrics.counter("host.plan_switches")
    stats = []
    i = i0
    recompiled = True
    while i < max_supersteps:
        faults.superstep_tick(i, "sharded")
        ts = time.time()
        this_recompiled = recompiled
        recompiled = False
        prev = (vert, msg, gs)
        with trace.annotate("superstep", "compute"):
            vert2, buckets, gs2 = step(vert, msg, gs)
            jax.block_until_ready(gs2.superstep)
        ovf_delta = np.asarray(gs2.overflow) - np.asarray(gs.overflow)
        if (ovf_delta > 0).any():
            ec = grow_overflowed(ec, ovf_delta,
                                 vertex_capacity=vert.capacity)
            step, exchange = build_step(plan, ec)
            vert, msg, gs = prev
            msg = put_lead(_regrow_msgs(msg, ec))
            stats.append(coll.event(
                i, "regrow", bucket_cap=ec.bucket_cap,
                frontier_cap=ec.frontier_cap,
                mutation_cap=ec.mutation_cap,
                sources=np.flatnonzero(ovf_delta > 0).tolist()).as_dict())
            m_regrows.inc()
            trace.instant("regrow", "replan", superstep=i)
            recompiled = True
            if controller is not None:
                controller.note_shape_change()
            continue
        # ---- the all_to_all exchange, as its own timed stage ----------
        faults.hit("sharded.exchange", f"s{i}")
        t_ex = time.time()
        msg = exchange(buckets)
        jax.block_until_ready(msg.valid)
        t_done = time.time()
        ex_stall = t_done - t_ex
        ex_bytes = _exchange_wire_bytes(P, ec.n_parts, ec.bucket_cap,
                                        program.msg_dims, N)
        trace.complete("exchange", "exchange", t_ex, t_done,
                       superstep=i + 1, bytes=ex_bytes, workers=N)
        m_exb.inc(ex_bytes)
        m_exs.inc(ex_stall)
        vert, gs = vert2, gs2
        i += 1
        rec = coll.record(i, active=int(gs.active_count),
                          messages=int(gs.msg_count),
                          wall_s=time.time() - ts,
                          recompiled=this_recompiled,
                          sharded=True, n_workers=N,
                          exchange_bytes=ex_bytes,
                          exchange_stall_s=ex_stall)
        stats.append(rec.as_dict())
        if explain.enabled():
            explain.superstep(rec, plan=plan, bucket_cap=ec.bucket_cap)
        if memwatch.enabled():
            memwatch.sample(i)
        switched = False
        if controller is not None and not bool(gs.halt):
            with trace.span("replan", "replan"):
                new_plan = controller.observe(rec, bucket_cap=ec.bucket_cap)
            if new_plan is not None:
                from repro.planner import migrate_msgs
                msg = put_lead(migrate_msgs(msg, plan, new_plan,
                                            ec.n_parts))
                plan = new_plan
                if plan.join == "left_outer":
                    act = int(gs.active_count) // max(P, 1) + 1
                    ec = dataclasses.replace(
                        ec, frontier_cap=min(max(FRONTIER_FLOOR, act * 4),
                                             vert.capacity + 8))
                need = default_engine_config(vert, program, plan)
                if need.bucket_cap > ec.bucket_cap:
                    ec = dataclasses.replace(ec,
                                             bucket_cap=need.bucket_cap)
                    msg = put_lead(_regrow_msgs(msg, ec))
                step, exchange = build_step(plan, ec)
                stats.append(coll.event(
                    i, "plan-switch", join=plan.join,
                    groupby=plan.groupby, connector=plan.connector,
                    sender_combine=plan.sender_combine,
                    storage=plan.storage,
                    frontier_cap=ec.frontier_cap).as_dict())
                m_switches.inc()
                recompiled = True
                switched = True
                controller.note_shape_change()
        if plan.join == "left_outer" and not switched:
            act = int(gs.active_count) // max(P, 1) + 1
            if act * 4 < ec.frontier_cap and \
                    ec.frontier_cap > FRONTIER_FLOOR:
                ec = dataclasses.replace(
                    ec, frontier_cap=max(FRONTIER_FLOOR, act * 2))
                step, exchange = build_step(plan, ec)
                stats.append(coll.event(
                    i, "frontier-refit",
                    frontier_cap=ec.frontier_cap).as_dict())
                recompiled = True
                if controller is not None:
                    controller.note_shape_change()
        if checkpoint_every and i % checkpoint_every == 0 \
                and checkpoint_dir:
            with trace.span("checkpoint", "checkpoint"):
                save_checkpoint(checkpoint_dir, i, vert, msg, gs)
        if on_superstep is not None:
            on_superstep(i, rec.as_dict())
        if bool(gs.halt):
            break
    return RunResult(vertex=vert, gs=gs, supersteps=i, stats=stats,
                     wall_s=time.time() - t0, plan=plan)


# ---------------------------------------------------------------------
# out-of-core sharded: per-worker tiered stores, lockstep rounds
# ---------------------------------------------------------------------

_VFIELDS = ("vid", "halt", "value", "edge_src", "edge_dst", "edge_val")


def _run_sharded_ooc(vert, program, plan, *, mesh, axes, n_workers,
                     max_supersteps, ec, budget_partitions, disk_dir,
                     memory_budget_bytes, io_threads, readahead_pages,
                     eviction, machine, kernel_impl, auto_space,
                     on_superstep, t0):
    from repro.planner.cost import Observation
    from repro.planner.stats import StatsCollector
    from repro.runtime import faults
    from repro.storage.tiered import TieredStore

    if getattr(program, "mutates", False):
        raise NotImplementedError(
            "mutating programs are not supported in sharded OOC mode "
            "(the host mutation inbox is not distributed); run in-memory "
            "sharded or single-host OOC")
    N = n_workers
    P = vert.num_partitions
    P_w = P // N                     # partitions owned per worker
    b = int(budget_partitions)       # resident partitions per worker
    if P_w % b:
        raise ValueError(f"budget_partitions {b} must divide the "
                         f"per-worker block {P_w}")
    R = P_w // b                     # lockstep rounds per superstep
    D, V = program.msg_dims, program.value_dims

    plan, auto_space = apply_kernel_impl(plan, kernel_impl, auto_space)
    if not isinstance(plan, PhysicalPlan):
        auto_space = dict(auto_space or {})
        auto_space.setdefault("kernel_impls", ("ref",))
    # "auto" resolves ONCE (non-adaptive): every round re-jits on a plan
    # switch, so mid-run switching would thrash the jit cache at R times
    # the in-memory rate — future work
    obs0 = Observation(frontier_density=1.0, sharded=True, n_workers=N,
                       ooc=True, super_partitions=R)
    plan, _ = _resolve_plan(vert, program, plan, adaptive=False,
                            auto_space=auto_space, machine=machine,
                            obs0=obs0)
    base_ec = ec or default_engine_config(vert, program, plan)
    ec = dataclasses.replace(base_ec, axis_name=axes, ooc_collect=True)
    Np = vert.capacity
    if explain.enabled():
        # static plan here (resolved once): the shadow auditor still
        # re-prices it per superstep against the measured legs
        explain.attach(program, vert=vert, plan=plan, machine=machine,
                       space_kw=auto_space)
    if memwatch.enabled():
        memwatch.configure(ec=ec, Np=Np, Ep=vert.edge_src.shape[1],
                           value_dims=V, msg_dims=D,
                           budget_bytes=(memory_budget_bytes * N
                                         if memory_budget_bytes
                                         else None))

    metrics = MetricsRegistry()
    n_live = int(np.asarray(vert.vid >= 0).sum())
    coll = StatsCollector(n_partitions=P, vertex_capacity=Np,
                          msg_dims=D, n_vertices=n_live, metrics=metrics)
    m_exb = metrics.counter("exchange.bytes")
    m_exs = metrics.counter("exchange.stall_s")
    m_regrows = metrics.counter("host.regrows")

    # ---- per-worker tiered stores (the OOC tiers shard with the graph)
    threads = (io_threads if io_threads is not None
               else (1 if disk_dir else 0))
    stores = []
    for w in range(N):
        wdir = f"{disk_dir}/worker{w}" if disk_dir else None
        stores.append(TieredStore(
            n_sp=R, budget_bytes=memory_budget_bytes, disk_dir=wdir,
            policy=eviction, io_threads=threads,
            readahead_pages=readahead_pages, metrics=metrics))

    gs = init_gs(program.agg_dims)
    vert = init_vertex_values(vert, program, gs)
    for w in range(N):
        blk = slice(w * P_w, (w + 1) * P_w)
        for f in _VFIELDS:
            stores[w].register(f, np.asarray(getattr(vert, f))[blk])
    del vert

    lead = _lead_spec(axes)
    rep = lambda x: PSpec()
    put_lead = lambda tree: jax.tree.map(
        lambda x: jax.device_put(jnp.asarray(x),
                                 NamedSharding(mesh, lead(x))), tree)
    put_rep = lambda tree: jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, PSpec())), tree)

    def build_step(ec, C_in):
        """Jitted shard_map superstep for resident blocks of N*b
        partitions with an inbox of run width C_in, plus the raw
        (worker-major) all_to_all for its collected buckets."""
        fn = make_superstep(program, plan, ec)
        body = lambda v, m, g: fn(v, m, g, None, None)
        # hand-written specs (psums in the body rule out eval_shape
        # outside shard_map); the inbox run width C_in only affects
        # SHAPES, which jit re-specializes on — the specs are rank-fixed
        v_specs = VertexRel(vid=PSpec(axes, None),
                            halt=PSpec(axes, None),
                            value=PSpec(axes, None, None),
                            edge_src=PSpec(axes, None),
                            edge_dst=PSpec(axes, None),
                            edge_val=PSpec(axes, None))
        m_specs = MsgRel(dst=PSpec(axes, None),
                         payload=PSpec(axes, None, None),
                         valid=PSpec(axes, None))
        g_specs = jax.tree.map(rep, init_gs(program.agg_dims))
        bkt_specs = MsgRel(dst=PSpec(axes, None, None),
                           payload=PSpec(axes, None, None, None),
                           valid=PSpec(axes, None, None))
        in_specs = (v_specs, m_specs, g_specs)
        # 5-tuple under ooc_collect: (vert, buckets, gs, counts,
        # mut_buckets); mutating programs are rejected up front so the
        # mutation buckets are always the static None leaf
        out_specs = (v_specs, bkt_specs, g_specs, PSpec(axes, None),
                     None)
        step = jax.jit(_shard_map(body, mesh, in_specs, out_specs))

        def ex_body(m: MsgRel) -> MsgRel:
            # RAW worker-major all_to_all: the landing pass reorders
            # into per-destination pages itself
            r_dst, r_pay, r_val = connector.exchange_shard_map(
                m.dst, m.payload, m.valid, axes, dst_major=False)
            return MsgRel(dst=r_dst, payload=r_pay, valid=r_val)

        ex = jax.jit(_shard_map(ex_body, mesh, (bkt_specs,), bkt_specs))
        return step, ex

    gen = 0
    gen_width = {0: ec.bucket_cap}   # inbox run width per generation
    step, exchange = build_step(ec, gen_width[0])
    ready_prev = None   # landings that built the current inbox gen

    def empty_inbox(C_in):
        return (np.full((b, P, C_in), -1, np.int32),
                np.zeros((b, P, C_in, D), np.float32),
                np.zeros((b, P, C_in), bool))

    def read_inbox(w, r):
        try:
            d = stores[w].get_page(("inbox", gen, r, "dst"))
            p = stores[w].get_page(("inbox", gen, r, "pay"))
            v = stores[w].get_page(("inbox", gen, r, "val"))
            return d, p, v
        except KeyError:
            return empty_inbox(gen_width[gen])

    stats = []
    i = 0
    supersteps_done = 0
    halted = False
    recompiled = True
    while i < max_supersteps and not halted:
        faults.superstep_tick(i, "sharded")
        ts = time.time()
        this_recompiled = recompiled
        recompiled = False
        nxt: dict = {}           # (worker, dst_round) -> (d, p, v) pages
        readiness = ExchangeReadiness(N, R)
        fold_active = 0
        fold_msgs = 0
        fold_agg = np.zeros((program.agg_dims,), np.float32)
        fold_halt = True
        ex_stall_total = 0.0
        ex_bytes_total = 0
        stall_total = 0.0
        delta_bytes = full_bytes = 0
        r = 0
        while r < R:
            # ---- distributed readiness gate: every source must have
            # landed this destination round's runs before dispatch
            t_gate = time.time()
            if ready_prev is not None and not ready_prev.ready_round(r):
                missing = [ready_prev.missing(w, r) for w in range(N)]
                raise RuntimeError(
                    f"superstep {i} round {r} dispatched before all "
                    f"sources landed: missing {missing}")
            stall_total += time.time() - t_gate
            # ---- assemble the resident block (N*b partitions)
            with trace.span("dispatch", "dispatch", superstep=i, round=r):
                vblk = {f: np.concatenate(
                    [stores[w].read(f, r) for w in range(N)])
                    for f in _VFIELDS}
                inbox = [read_inbox(w, r) for w in range(N)]
                C_in = gen_width[gen]
                mblk = MsgRel(
                    dst=np.concatenate([x[0] for x in inbox])
                    .reshape(N * b, P * C_in),
                    payload=np.concatenate([x[1] for x in inbox])
                    .reshape(N * b, P * C_in, D),
                    valid=np.concatenate([x[2] for x in inbox])
                    .reshape(N * b, P * C_in))
                vdev = put_lead(VertexRel(**vblk))
                mdev = put_lead(mblk)
                gdev = put_rep(gs)
            vert2, buckets, gs2, counts, _ = step(vdev, mdev, gdev)
            jax.block_until_ready(gs2.superstep)
            ovf_delta = (np.asarray(gs2.overflow) -
                         np.asarray(gs.overflow))
            if (ovf_delta > 0).any():
                # regrow SPANNING the exchange: grow, re-jit, end-pad the
                # pages already landed for gen+1 to the new run width,
                # and redo this round (nothing of round r landed yet)
                ec = grow_overflowed(ec, ovf_delta, vertex_capacity=Np)
                step, exchange = build_step(ec, gen_width[gen])
                C_new = ec.bucket_cap
                for key, (pd, pp, pv) in list(nxt.items()):
                    pad = C_new - pd.shape[2]
                    if pad > 0:
                        nxt[key] = (
                            np.pad(pd, ((0, 0), (0, 0), (0, pad)),
                                   constant_values=-1),
                            np.pad(pp, ((0, 0), (0, 0), (0, pad),
                                        (0, 0))),
                            np.pad(pv, ((0, 0), (0, 0), (0, pad))))
                stats.append(coll.event(
                    i, "regrow", bucket_cap=ec.bucket_cap,
                    frontier_cap=ec.frontier_cap, round=r,
                    sources=np.flatnonzero(ovf_delta > 0).tolist())
                    .as_dict())
                m_regrows.inc()
                trace.instant("regrow", "replan", superstep=i, round=r)
                recompiled = True
                continue
            C = ec.bucket_cap
            # ---- the all_to_all exchange stage (timed)
            t_ex = time.time()
            exchanged = exchange(buckets)
            jax.block_until_ready(exchanged.valid)
            t_done = time.time()
            ex_bytes = _exchange_wire_bytes(N * b, P, C, D, N)
            trace.complete("exchange", "exchange", t_ex, t_done,
                           superstep=i, round=r, bytes=ex_bytes)
            ex_stall_total += t_done - t_ex
            ex_bytes_total += ex_bytes
            m_exb.inc(ex_bytes)
            m_exs.inc(t_done - t_ex)
            # ---- land the worker-major runs into per-destination pages
            t_land = time.time()
            xd = np.asarray(exchanged.dst)
            xp = np.asarray(exchanged.payload)
            xv = np.asarray(exchanged.valid)
            with trace.span("commit", "commit", superstep=i, round=r):
                for w in range(N):
                    blk = slice(w * b, (w + 1) * b)
                    # y[p, j*P_w + t] = src worker j local p -> my dst t
                    yd = xd[blk].reshape(b, N, P_w, C)
                    yp = xp[blk].reshape(b, N, P_w, C, D)
                    yv = xv[blk].reshape(b, N, P_w, C)
                    for rd in range(R):
                        key = (w, rd)
                        if key not in nxt:
                            nxt[key] = empty_inbox(C)
                        pd, pp, pv = nxt[key]
                        tsl = slice(rd * b, (rd + 1) * b)
                        ssl = slice(r * b, (r + 1) * b)
                        # page run index = GLOBAL src partition
                        # j*P_w + r*b + p; valid entries stay a prefix
                        pd.reshape(b, N, P_w, C)[:, :, ssl] = \
                            yd[:, :, tsl].transpose(2, 1, 0, 3)
                        pp.reshape(b, N, P_w, C, D)[:, :, ssl] = \
                            yp[:, :, tsl].transpose(2, 1, 0, 3, 4)
                        pv.reshape(b, N, P_w, C)[:, :, ssl] = \
                            yv[:, :, tsl].transpose(2, 1, 0, 3)
                        readiness.land(w, rd, r)
                # ---- commit the updated vertex blocks per worker store
                nv = {f: np.asarray(getattr(vert2, f))
                      for f in ("vid", "halt", "value", "edge_dst",
                                "edge_val")}
                fold_halt &= bool(np.all(nv["halt"] | (nv["vid"] < 0)))
                for w in range(N):
                    blk = slice(w * b, (w + 1) * b)
                    for f in ("vid", "halt", "value", "edge_dst",
                              "edge_val"):
                        new = nv[f][blk]
                        old = stores[w].read(f, r)
                        if plan.storage == "delta":
                            mask = (new != old).reshape(b, -1).any(1)
                            delta_bytes += int(mask.sum()) * \
                                new[0].nbytes if b else 0
                            stores[w].write_rows(f, r, mask, new[mask])
                        else:
                            delta_bytes += new.nbytes
                            stores[w].write(f, r, new)
                        full_bytes += new.nbytes
                    if threads and r + 1 < R:
                        stores[w].readahead(
                            [(f, r + 1) for f in _VFIELDS])
            stall_total += time.time() - t_land
            fold_active += int(gs2.active_count)
            fold_msgs += int(gs2.msg_count)
            fold_agg += np.asarray(gs2.aggregate)
            r += 1
        # ---- GS fold across rounds (the rolling-fold analogue)
        i += 1
        supersteps_done = i
        new_gen = gen + 1
        gen_width[new_gen] = ec.bucket_cap
        for (w, rd), (pd, pp, pv) in nxt.items():
            stores[w].put_page(("inbox", new_gen, rd, "dst"), pd)
            stores[w].put_page(("inbox", new_gen, rd, "pay"), pp)
            stores[w].put_page(("inbox", new_gen, rd, "val"), pv)
        for w in range(N):
            for rd in range(R):
                for f in ("dst", "pay", "val"):
                    try:
                        stores[w].delete_page(("inbox", gen, rd, f))
                    except KeyError:
                        pass
        gen = new_gen
        ready_prev = readiness
        conv = bool(np.asarray(program.is_converged(gs)))
        halted = (fold_halt and fold_msgs == 0) or conv
        gs = GlobalState(
            halt=jnp.asarray(halted),
            aggregate=jnp.asarray(fold_agg, jnp.float32).reshape(
                np.asarray(gs.aggregate).shape),
            superstep=gs.superstep + 1,
            overflow=gs.overflow,
            active_count=jnp.asarray(fold_active, jnp.int32),
            msg_count=jnp.asarray(fold_msgs, jnp.int32))
        tier = {}
        for w in range(N):
            for k, v in stores[w].take_interval().items():
                tier[k] = tier.get(k, 0) + v
        extra = dict(ooc=True, sharded=True, n_workers=N,
                     super_partitions=R, streaming=False,
                     barrier_free=False,
                     exchange_bytes=ex_bytes_total,
                     exchange_stall_s=ex_stall_total,
                     readiness_stall_s=stall_total,
                     delta_bytes=delta_bytes, full_bytes=full_bytes,
                     change_density=(delta_bytes / full_bytes
                                     if full_bytes else 1.0),
                     storage=plan.storage,
                     spill=any(s.spilling for s in stores))
        # per-superstep pager interval keys are "hits"/"misses"
        # (BufferPool.take_interval), summed across the worker stores
        hits = tier.get("hits", 0)
        total_lookups = hits + tier.get("misses", 0)
        if total_lookups:
            extra["cache_hit_rate"] = hits / total_lookups
        for k in ("spill_read_bytes", "spill_write_bytes"):
            if k in tier:
                extra[k] = tier[k]
        rec = coll.record(i, active=fold_active, messages=fold_msgs,
                          wall_s=time.time() - ts,
                          recompiled=this_recompiled, **extra)
        stats.append(rec.as_dict())
        if explain.enabled():
            explain.superstep(rec, plan=plan, bucket_cap=ec.bucket_cap)
        if memwatch.enabled():
            # N workers each keep b partitions resident at once
            memwatch.sample(i, stores=stores, resident_parts=N * b)
        if on_superstep is not None:
            on_superstep(i, rec.as_dict())
    # ---- final gather (the HDFS-write analogue, per worker)
    out = {f: np.concatenate([stores[w].gather(f) for w in range(N)])
           for f in _VFIELDS}
    for s in stores:
        s.close()
    vert_out = VertexRel(**{f: jnp.asarray(out[f]) for f in _VFIELDS})
    return RunResult(vertex=vert_out, gs=gs, supersteps=supersteps_done,
                     stats=stats, wall_s=time.time() - t0, plan=plan)
