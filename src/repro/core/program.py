"""Vectorized Pregel programs (the paper's UDFs, Table 2).

The paper's per-vertex Java ``compute`` becomes a batched JAX function over
vid-aligned arrays; message generation along out-edges becomes an
edge-parallel ``send``. Identical semantics for combiner-based Pregel
programs (everything in the paper's evaluation + built-in library).

UDFs:
  compute   executed at each active vertex every superstep
  send      produces the payload for each out-edge of a sending vertex
  combine   associative message aggregation (named monoid or custom fn)
  aggregate global aggregation contribution (summed via two-stage psum)
  resolve   conflict resolution for graph mutations
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclass
class ComputeOut:
    """Output of the vectorized compute UDF (the paper's compute output
    tuple, Section 3)."""
    value: jax.Array                 # (P, Np, V) updated vertex values
    halt: jax.Array                  # (P, Np) vote-to-halt
    send_gate: jax.Array             # (P, Np) emit messages along out-edges?
    aggregate: Optional[jax.Array] = None   # (P, Np, A) global contribution
    # graph mutations (all optional):
    insert_vid: Optional[jax.Array] = None    # (P, Np) vid to insert or -1
    insert_value: Optional[jax.Array] = None  # (P, Np, V)
    delete_self: Optional[jax.Array] = None   # (P, Np) bool
    # own-edge rewrites (edges are owned by the src partition -> local):
    new_edge_dst: Optional[jax.Array] = None  # (P, Ep) or -2 keep
    new_edge_val: Optional[jax.Array] = None  # (P, Ep) or nan keep


class VertexProgram:
    """Subclass and override. All arrays carry the (P, partition-local)
    leading axes."""

    value_dims: int = 1
    msg_dims: int = 1
    agg_dims: int = 1
    combine_op: str = "sum"   # "sum" | "min" | "max" | "custom"

    # -- identity element of the combiner monoid
    def combine_identity(self) -> jax.Array:
        return {"sum": jnp.zeros((self.msg_dims,), jnp.float32),
                "min": jnp.full((self.msg_dims,), jnp.inf, jnp.float32),
                "max": jnp.full((self.msg_dims,), -jnp.inf, jnp.float32),
                }.get(self.combine_op,
                      jnp.zeros((self.msg_dims,), jnp.float32))

    def combine(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """Custom associative combine (used when combine_op == 'custom')."""
        raise NotImplementedError

    def init_value(self, vid: jax.Array, out_degree: jax.Array,
                   gs) -> jax.Array:
        """Initial vertex value. vid: (P,Np). -> (P,Np,V)."""
        return jnp.zeros(vid.shape + (self.value_dims,), jnp.float32)

    def compute(self, vid, value, msg, has_msg, active, gs) -> ComputeOut:
        raise NotImplementedError

    def send(self, src_vid, src_value, edge_val, dst_vid, gs) -> jax.Array:
        """Edge-parallel message payloads. src_value: (P,Ep,V) gathered new
        values of each edge's source. -> (P,Ep,D)."""
        raise NotImplementedError

    def aggregate_identity(self) -> jax.Array:
        return jnp.zeros((self.agg_dims,), jnp.float32)

    def resolve(self, vid, values, count) -> jax.Array:
        """Resolve conflicting inserts of the same vid (values summed by
        default). values: (..., V) pre-combined sum; count: multiplicity."""
        return values

    def is_converged(self, gs) -> jax.Array:
        """Optional extra convergence predicate on the global state."""
        return jnp.array(False)
