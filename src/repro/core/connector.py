"""Connectors (paper Section 4): m-to-n partitioning / partitioning-merging
data exchange, with fixed-capacity buckets + validity masks (the static-
shape adaptation of tuple streams; overflow is counted and surfaces in GS
so the driver can grow capacity — the moral equivalent of a spill).

Two transports for the same bucketed exchange:
* emulated   — partitions stacked on a leading axis, exchange = transpose
               (single-host tests/benches);
* shard_map  — ``jax.lax.all_to_all`` over the mesh axis (production; on
               the multi-pod mesh the flattened ("pod","data","model") axis
               makes XLA generate the hierarchical ICI/DCI exchange).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def bucket_by_owner(dst, payload, valid, P: int, bucket_cap: int, *,
                    sort_by_dst: bool, partition: str = "hash",
                    capacity: int = 0, presorted: bool = False):
    """Per partition: route messages into P fixed-capacity buckets.

    dst: (K,) global vid; payload: (K, D). sort_by_dst=True is the
    'partitioning merging' connector (buckets arrive dst-sorted).
    partition="range" with presorted=True (input already dst-sorted, e.g.
    from the sender combine) skips the sort entirely — owners are
    contiguous in dst order.
    Returns (b_dst (P,C), b_payload (P,C,D), b_valid (P,C), overflow ()).

    Layout contract (every code path below): valid entries occupy a
    PREFIX of each bucket — positions are per-owner ranks 0..count-1, so
    b_valid[p] is True on [0, count_p) and False after. The out-of-core
    inbox (core/ooc.py) relies on this to trim and end-pad collected
    buckets without disturbing run structure, and the in-memory regrow
    path (driver._regrow_msgs) relies on it to widen runs in place."""
    K = dst.shape[0]
    D = payload.shape[-1]
    if partition == "range":
        owner = jnp.where(valid, jnp.minimum(dst // capacity, P - 1), P)
    else:
        owner = jnp.where(valid, dst % P, P)
    if partition == "range" and presorted:
        # dst ascending among valid rows => owners contiguous: positions
        # are computable WITHOUT any sort (rank among valid minus the
        # owner's first rank, via an O(P) scatter-min)
        vrank = jnp.cumsum(valid) - 1
        big = jnp.iinfo(jnp.int32).max
        owner_start = jnp.full((P + 1,), big, jnp.int32).at[owner].min(
            jnp.where(valid, vrank, big).astype(jnp.int32))
        so, sd, sp, sv = owner, dst, payload, valid
        pos = (vrank - owner_start[owner.clip(0, P)]).astype(jnp.int32)
    else:
        if sort_by_dst or partition == "range":
            # stable two-pass radix: by dst, then owner (no 64-bit keys);
            # for range partitioning dst order already groups owners
            o1 = jnp.argsort(jnp.where(valid, dst,
                                       jnp.iinfo(jnp.int32).max),
                             stable=True)
            order = o1 if partition == "range" else \
                o1[jnp.argsort(owner[o1], stable=True)]
        else:
            order = jnp.argsort(owner, stable=True)
        so = owner[order]
        sd = dst[order]
        sp = payload[order]
        sv = valid[order]
        # position within owner bucket: arange - first index of this owner
        first = jnp.searchsorted(so, jnp.arange(P + 1), side="left")
        pos = jnp.arange(K) - first[so.clip(0, P)]
    keep = sv & (pos < bucket_cap)
    flat = jnp.where(keep, so * bucket_cap + pos, P * bucket_cap)
    b_dst = jnp.full((P * bucket_cap + 1,), -1, jnp.int32)
    b_dst = b_dst.at[flat].set(sd, mode="drop")
    b_pay = jnp.zeros((P * bucket_cap + 1, D), payload.dtype)
    b_pay = b_pay.at[flat].set(sp, mode="drop")
    b_val = jnp.zeros((P * bucket_cap + 1,), bool)
    b_val = b_val.at[flat].set(keep, mode="drop")
    overflow = jnp.sum(sv & (pos >= bucket_cap))
    return (b_dst[:-1].reshape(P, bucket_cap),
            b_pay[:-1].reshape(P, bucket_cap, D),
            b_val[:-1].reshape(P, bucket_cap),
            overflow)


def exchange_emulated(b_dst, b_pay, b_val):
    """Stacked-global transport: (P_src, P_dst, C, ...) -> transpose.
    Receiver p sees P_src runs of C messages."""
    return (b_dst.transpose(1, 0, 2),
            b_pay.transpose(1, 0, 2, 3),
            b_val.transpose(1, 0, 2))


def exchange_shard_map(b_dst, b_pay, b_val, axis_name, *,
                       dst_major: bool = True):
    """shard_map transport: per-shard buckets (P_local, n_parts, C, ...)
    exchanged with all_to_all over `axis_name` (tuple axes = the flattened
    multi-pod mesh; XLA emits the hierarchical ICI/DCI exchange).

    Worker d owns the CONTIGUOUS global partitions [d*P_local,
    (d+1)*P_local) — exactly the tiled all_to_all chunking of the bucket
    axis, so chunk j of axis 1 is worker j's owned range.

    The raw tiled result is worker-major: on worker d,
    ``y[p, j*P_local + q]`` holds source worker j's local partition p
    destined to local partition q. ``dst_major=True`` (default) reorders
    it to the global layout ``out[q, s]`` = the run from global source
    partition s into local destination q — bit-for-bit the
    ``exchange_emulated`` transpose, which is what the in-memory sharded
    driver and the receiver group-by's run contract assume. The OOC
    sharded driver takes ``dst_major=False``: it lands the worker-major
    runs into per-destination inbox pages itself."""
    def a2a(x):
        y = jax.lax.all_to_all(x, axis_name, split_axis=1,
                               concat_axis=1, tiled=True)
        P_local, n_parts = x.shape[0], x.shape[1]
        if not dst_major or P_local == 1:
            return y     # worker-major requested, or reorder is identity
        N = n_parts // P_local
        rest = y.shape[2:]
        y = y.reshape((P_local, N, P_local) + rest)   # (p, j, q, ...)
        y = jnp.swapaxes(y, 0, 2)                     # (q, j, p, ...)
        return y.reshape((P_local, n_parts) + rest)   # run s = j*P_l + p
    return a2a(b_dst), a2a(b_pay), a2a(b_val)
