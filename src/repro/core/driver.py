"""Job drivers.

* ``run_jit``  — whole computation as one ``lax.while_loop`` (fastest;
                 fixed capacities; overflow aborts via GS flag).
* ``run_host`` — Python superstep loop around the jitted superstep: this is
                 the driver that can checkpoint at superstep boundaries
                 (paper Section 5.5), collect per-superstep statistics
                 (Section 5.7 statistics collector), and transparently GROW
                 message capacity on overflow by re-running the superstep
                 from the retained previous state (the static-shape
                 analogue of an operator spilling to disk).
* ``run_out_of_core`` — lives in core/ooc.py.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import FRONTIER_FLOOR, PhysicalPlan
from repro.core.program import VertexProgram
from repro.core.relations import (OVF_BUCKET, OVF_EDGE, OVF_FRONTIER,
                                  OVF_MUTATION, GlobalState, MsgRel,
                                  VertexRel, empty_msgs, init_gs,
                                  out_degrees)
from repro.core.superstep import EngineConfig, make_superstep
from repro.kernels import backend as kbackend
from repro.obs import explain, memwatch, trace
from repro.obs.metrics import MetricsRegistry

PlanArg = Union[PhysicalPlan, str]   # a PhysicalPlan or the string "auto"


def apply_kernel_impl(plan: PlanArg, kernel_impl: Optional[str],
                      auto_space: Optional[dict]):
    """Thread a driver-level ``kernel_impl`` override into either a
    concrete plan (replace the field) or the "auto" search space (pin the
    kernel_impls dimension so the initial choice AND every mid-run switch
    carry it)."""
    if kernel_impl is None:
        return plan, auto_space
    if isinstance(plan, PhysicalPlan):
        return dataclasses.replace(plan, kernel_impl=kernel_impl), \
            auto_space
    auto_space = dict(auto_space or {})
    auto_space.setdefault("kernel_impls", (kernel_impl,))
    return plan, auto_space


def plan_gather_layout(plan: PhysicalPlan, vert: VertexRel):
    """Device-resident gather layout for the kernel path, or None when the
    resolved plan doesn't consume one. Depends only on edge_src (which the
    engine never rewrites — mutations touch edge_dst/edge_val), so one
    layout serves a whole run; recompute only on plan switches."""
    if not kbackend.wants_edge_layout(plan):
        return None
    perm, tile_row = kbackend.plan_edge_layout(
        np.asarray(vert.edge_src), vert.capacity)
    return jnp.asarray(perm), jnp.asarray(tile_row)


@dataclass
class RunResult:
    vertex: VertexRel
    gs: GlobalState
    supersteps: int
    stats: list = field(default_factory=list)
    wall_s: float = 0.0
    plan: Optional[PhysicalPlan] = None   # plan in effect at the end
    recovery: list = field(default_factory=list)  # supervisor events


def _resolve_plan(vert, program, plan: PlanArg, *, adaptive: bool,
                  ec: Optional[EngineConfig] = None,
                  auto_config=None, auto_space=None, graph_stats=None,
                  machine=None, obs0=None):
    """plan="auto" -> (cost-model-chosen plan, AdaptiveController|None).
    `graph_stats` short-circuits the vertex scan (the OOC resume path
    rebuilds the counts page-at-a-time and never holds a VertexRel).
    `machine` overrides the emulated-vs-default machine-model choice
    (the sharded driver picks per backend); `obs0` seeds the initial
    observation (sharded=True / n_workers for the network axis)."""
    if isinstance(plan, PhysicalPlan):
        return plan, None
    if plan != "auto":
        raise ValueError(f"plan must be a PhysicalPlan or 'auto', "
                         f"got {plan!r}")
    from repro.planner import (DEFAULT_MACHINE, EMULATED_MACHINE,
                               AdaptiveConfig, resolve_auto_plan)
    emulated = ec is None or ec.axis_name is None
    config = auto_config or AdaptiveConfig()
    if machine is None:
        machine = EMULATED_MACHINE if emulated else DEFAULT_MACHINE
    if config.calibrate:
        # one-shot startup calibration (opt-in): lower a probe superstep
        # per backend and refit the analytic cost constants against the
        # trip-count-aware HLO analyzer instead of trusting the
        # hand-tuned K_COMPUTE / K_SCATTER / SORT_PASS_FRAC
        from repro.planner.cost import GraphStats, calibrate_machine
        machine = calibrate_machine(
            program, graph_stats or GraphStats.from_vertex(vert, program),
            machine)
    return resolve_auto_plan(
        vert, program, adaptive=adaptive, config=config,
        machine=machine, space_kw=auto_space, g=graph_stats, obs0=obs0)


def default_engine_config(vert: VertexRel, program: VertexProgram,
                          plan: PhysicalPlan, *, slack: float = 1.5,
                          axis_name=None) -> EngineConfig:
    from repro.core.plan import bucket_capacity
    P, Np = vert.vid.shape
    Ep = vert.edge_src.shape[1]
    return EngineConfig(n_parts=P,
                        bucket_cap=bucket_capacity(plan, Ep, Np, P,
                                                   slack=slack),
                        frontier_cap=int(Np * plan.frontier_capacity) + 8,
                        axis_name=axis_name)


def init_vertex_values(vert: VertexRel, program: VertexProgram,
                       gs: GlobalState) -> VertexRel:
    deg = out_degrees(vert)
    value = program.init_value(vert.vid, deg, gs)
    return dataclasses.replace(vert, value=jnp.where(
        (vert.vid >= 0)[..., None], value, 0.0))


def grow_overflowed(ec: EngineConfig, delta, *,
                    vertex_capacity: int = 0) -> EngineConfig:
    """Double only the capacities whose per-source overflow counter grew
    (`delta` = the GlobalState.overflow increase of the failed step).
    Edge-stream overflow is attributed to the frontier: the edge
    compaction capacity is derived from frontier_cap (EF = 8 *
    frontier_cap in gen_messages). A frontier_cap of 0 (the "Np/2"
    EngineConfig default) is resolved against `vertex_capacity` first so
    the doubling cannot wedge at 0."""
    delta = np.asarray(delta)
    kw = {}
    if delta[OVF_BUCKET] > 0:
        kw["bucket_cap"] = ec.bucket_cap * 2
    if delta[OVF_FRONTIER] > 0 or delta[OVF_EDGE] > 0:
        cur = ec.frontier_cap or max(vertex_capacity // 2, 1)
        kw["frontier_cap"] = cur * 2
    if delta[OVF_MUTATION] > 0:
        kw["mutation_cap"] = ec.mutation_cap * 2
    return dataclasses.replace(ec, **kw)


def run_jit(vert: VertexRel, program: VertexProgram,
            plan: PlanArg = PhysicalPlan(), *,
            max_supersteps: int = 50,
            ec: Optional[EngineConfig] = None,
            kernel_impl: Optional[str] = None) -> RunResult:
    t0 = time.time()
    # "auto" resolves once up front (whole-loop jit: no mid-run switching)
    plan, _ = _resolve_plan(vert, program, plan, adaptive=False, ec=ec)
    if kernel_impl is not None:
        plan = dataclasses.replace(plan, kernel_impl=kernel_impl)
    ec = ec or default_engine_config(vert, program, plan)
    step = make_superstep(program, plan, ec)
    layout = plan_gather_layout(plan, vert)
    gs = init_gs(program.agg_dims)
    vert = init_vertex_values(vert, program, gs)
    msg = empty_msgs(vert.num_partitions, ec.n_parts * ec.bucket_cap,
                     program.msg_dims)

    def cond(state):
        v, m, g = state
        return (~g.halt) & (g.superstep < max_supersteps) & \
            jnp.all(g.overflow == 0)

    def body(state):
        return step(*state, None, layout)

    v, m, g = jax.jit(
        lambda s: jax.lax.while_loop(cond, body, s))((vert, msg, gs))
    jax.block_until_ready(g.superstep)
    if int(np.asarray(g.overflow).sum()) > 0:
        raise RuntimeError(
            f"capacity overflow (bucket/frontier/mutation/edge = "
            f"{np.asarray(g.overflow).tolist()} dropped); "
            "use run_host (auto-grows) or raise the capacities")
    return RunResult(vertex=v, gs=g, supersteps=int(g.superstep),
                     wall_s=time.time() - t0, plan=plan)


def run_host(vert: VertexRel, program: VertexProgram,
             plan: PlanArg = PhysicalPlan(), *,
             max_supersteps: int = 50,
             ec: Optional[EngineConfig] = None,
             checkpoint_every: int = 0,
             checkpoint_dir: Optional[str] = None,
             resume_from: Optional[str] = None,
             resume_parts: Optional[int] = None,
             recover: bool = False,
             max_retries: int = 3,
             on_superstep: Optional[Callable] = None,
             failure_injector: Optional[Callable] = None,
             auto_config=None,
             auto_space: Optional[dict] = None,
             kernel_impl: Optional[str] = None) -> RunResult:
    """Host-loop driver with statistics, checkpointing, capacity growth and
    (for tests) failure injection. plan="auto" turns on the cost-based
    planner: the initial plan is chosen for superstep 0's all-active
    frontier and re-chosen at superstep boundaries as observed frontier
    density crosses the model's thresholds (planner.adaptive).

    ``resume_from=<ckpt npz>`` restarts from a checkpoint (optionally
    re-hashed onto ``resume_parts`` partitions — the elastic restore).
    ``recover=True`` runs the whole job under the failure manager's
    recovery supervisor: a recoverable failure (WorkerFailure, disk
    I/O, typed corruption) restores the latest VALID checkpoint onto
    the surviving partitions and replays; application errors forward."""
    from repro.planner.stats import StatsCollector
    from repro.runtime import faults
    from repro.runtime.checkpoint import save_checkpoint

    if recover:
        from repro.runtime.checkpoint import latest_checkpoint
        from repro.runtime.failure import supervised_run
        P0 = vert.num_partitions

        def _attempt(healthy, resume):
            return run_host(
                vert, program, plan, max_supersteps=max_supersteps,
                ec=ec, checkpoint_every=checkpoint_every,
                checkpoint_dir=checkpoint_dir, resume_from=resume,
                resume_parts=(healthy if resume is not None
                              and healthy < P0 else None),
                recover=False, on_superstep=on_superstep,
                failure_injector=failure_injector,
                auto_config=auto_config, auto_space=auto_space,
                kernel_impl=kernel_impl)

        def _pick(bad):
            if not checkpoint_dir:
                return None
            return latest_checkpoint(checkpoint_dir, skip=bad,
                                     verify=True)

        return supervised_run(_attempt, _pick, n_workers=P0,
                              max_retries=max_retries,
                              initial_resume=resume_from)

    t0 = time.time()
    i0, rmsg, rgs = 0, None, None
    if resume_from is not None:
        from repro.runtime.checkpoint import load_checkpoint, repartition
        vert, rmsg, rgs = load_checkpoint(resume_from)
        if resume_parts is not None \
                and resume_parts != vert.num_partitions:
            vert, rmsg = repartition(vert, rmsg, resume_parts)
        i0 = int(rgs.superstep)
    plan, auto_space = apply_kernel_impl(plan, kernel_impl, auto_space)
    plan, controller = _resolve_plan(vert, program, plan, adaptive=True,
                                     ec=ec, auto_config=auto_config,
                                     auto_space=auto_space)
    ec = ec or default_engine_config(vert, program, plan)
    if rmsg is not None and rmsg.capacity > ec.n_parts * ec.bucket_cap:
        # the checkpointed inbox is wider than the derived config (it
        # grew mid-run): adopt its capacity instead of truncating it
        ec = dataclasses.replace(
            ec, bucket_cap=-(-rmsg.capacity // ec.n_parts))
    if explain.enabled():
        # plan-audit ledger: bind the run context so each superstep's
        # stats record can be re-priced under the in-effect plan
        from repro.planner.cost import DEFAULT_MACHINE, EMULATED_MACHINE
        explain.attach(
            program, vert=vert,
            g=controller.g if controller is not None else None,
            plan=plan,
            machine=(controller.machine if controller is not None else
                     (EMULATED_MACHINE if ec.axis_name is None
                      else DEFAULT_MACHINE)),
            space_kw=auto_space)
    step = jax.jit(make_superstep(program, plan, ec))
    layout = plan_gather_layout(plan, vert)
    if rgs is not None:
        gs, msg = rgs, _regrow_msgs(rmsg, ec)
    else:
        gs = init_gs(program.agg_dims)
        vert = init_vertex_values(vert, program, gs)
        msg = empty_msgs(vert.num_partitions, ec.n_parts * ec.bucket_cap,
                         program.msg_dims)
    n_live = (controller.g.n_vertices if controller is not None
              else int(jnp.sum(vert.vid >= 0)))
    metrics = MetricsRegistry()
    coll = StatsCollector(n_partitions=vert.num_partitions,
                          vertex_capacity=vert.capacity,
                          msg_dims=program.msg_dims, n_vertices=n_live,
                          metrics=metrics)
    m_regrows = metrics.counter("host.regrows")
    m_switches = metrics.counter("host.plan_switches")
    stats = []
    i = i0
    recompiled = True  # first step includes the jit compile
    while i < max_supersteps:
        faults.superstep_tick(i, "host")
        ts = time.time()
        this_recompiled = recompiled
        recompiled = False
        prev = (vert, msg, gs)
        with trace.annotate("superstep", "compute"):
            vert2, msg2, gs2 = step(vert, msg, gs, None, layout)
            jax.block_until_ready(gs2.superstep)
        ovf_delta = np.asarray(gs2.overflow) - np.asarray(gs.overflow)
        if (ovf_delta > 0).any():
            # grow ONLY the overflowed capacities x2 and REDO this
            # superstep from `prev` (per-source counters keep a frontier
            # overflow from dragging the bucket tensors along)
            ec = grow_overflowed(ec, ovf_delta,
                                 vertex_capacity=vert.capacity)
            step = jax.jit(make_superstep(program, plan, ec))
            vert, msg, gs = prev
            msg = _regrow_msgs(msg, ec)
            stats.append(coll.event(
                i, "regrow", bucket_cap=ec.bucket_cap,
                frontier_cap=ec.frontier_cap,
                mutation_cap=ec.mutation_cap,
                sources=np.flatnonzero(ovf_delta > 0).tolist()).as_dict())
            m_regrows.inc()
            trace.instant("regrow", "replan", superstep=i)
            recompiled = True
            if controller is not None:
                controller.note_shape_change()
            continue
        vert, msg, gs = vert2, msg2, gs2
        i += 1
        rec = coll.record(i, active=int(gs.active_count),
                          messages=int(gs.msg_count),
                          wall_s=time.time() - ts,
                          recompiled=this_recompiled)
        stats.append(rec.as_dict())
        if explain.enabled():
            # audit the plan that EXECUTED this superstep (a switch
            # below only affects the next one)
            explain.superstep(rec, plan=plan, bucket_cap=ec.bucket_cap)
        if memwatch.enabled():
            memwatch.configure(ec=ec, Np=vert.capacity,
                               Ep=vert.edge_src.shape[1],
                               value_dims=program.value_dims,
                               msg_dims=program.msg_dims)
            memwatch.sample(i)
        switched = False
        if controller is not None and not bool(gs.halt):
            # mid-run replanning: switch the physical plan when observed
            # frontier density pushes another plan below the current one
            with trace.span("replan", "replan"):
                new_plan = controller.observe(rec,
                                              bucket_cap=ec.bucket_cap)
            if new_plan is not None:
                from repro.planner import migrate_msgs
                msg = migrate_msgs(msg, plan, new_plan, ec.n_parts)
                plan = new_plan
                if plan.join == "left_outer":
                    act = int(gs.active_count) // \
                        max(vert.num_partitions, 1) + 1
                    ec = dataclasses.replace(
                        ec, frontier_cap=min(max(FRONTIER_FLOOR, act * 4),
                                             vert.capacity + 8))
                # dropping the sender combine needs room for uncombined
                # sends: grow the buckets now instead of paying an
                # overflow-redo on the next superstep
                need = default_engine_config(vert, program, plan)
                if need.bucket_cap > ec.bucket_cap:
                    ec = dataclasses.replace(ec,
                                             bucket_cap=need.bucket_cap)
                    msg = _regrow_msgs(msg, ec)
                step = jax.jit(make_superstep(program, plan, ec))
                layout = plan_gather_layout(plan, vert)
                stats.append(coll.event(
                    i, "plan-switch", join=plan.join,
                    groupby=plan.groupby, connector=plan.connector,
                    sender_combine=plan.sender_combine,
                    storage=plan.storage,
                    frontier_cap=ec.frontier_cap).as_dict())
                m_switches.inc()
                recompiled = True
                switched = True
                controller.note_shape_change()
        # adaptive frontier refit (left-outer plan): when the live set
        # collapses, shrink the frontier capacity so each superstep only
        # pays O(|frontier|) — one recompile, amortized across supersteps
        if plan.join == "left_outer" and not switched:
            act = int(gs.active_count) // max(vert.num_partitions, 1) + 1
            if act * 4 < ec.frontier_cap and ec.frontier_cap > \
                    FRONTIER_FLOOR:
                ec = dataclasses.replace(
                    ec, frontier_cap=max(FRONTIER_FLOOR, act * 2))
                step = jax.jit(make_superstep(program, plan, ec))
                stats.append(coll.event(
                    i, "frontier-refit",
                    frontier_cap=ec.frontier_cap).as_dict())
                recompiled = True
                if controller is not None:
                    controller.note_shape_change()
        if controller is not None and not bool(gs.halt):
            # periodic cost-model re-calibration (opt-in): refit the
            # analytic constants after lowered shapes changed, at most
            # once per AdaptiveConfig.recalibrate_every supersteps
            recal = controller.maybe_recalibrate(program, i)
            if recal is not None:
                stats.append(coll.event(i, "recalibrate",
                                        **recal).as_dict())
        if failure_injector is not None:
            failure_injector(i, vert, msg, gs)
        if checkpoint_every and i % checkpoint_every == 0 \
                and checkpoint_dir:
            with trace.span("checkpoint", "checkpoint"):
                save_checkpoint(checkpoint_dir, i, vert, msg, gs)
        if on_superstep is not None:
            on_superstep(i, vert, msg, gs, rec.as_dict())
        if bool(gs.halt):
            break
    return RunResult(vertex=vert, gs=gs, supersteps=i, stats=stats,
                     wall_s=time.time() - t0, plan=plan)


def _regrow_msgs(msg: MsgRel, ec: EngineConfig) -> MsgRel:
    """Pad capacity per source-run (preserves the (n_parts, C) run layout
    that the merging connector's receiver group-by relies on). Restored
    checkpoints whose capacity is not run-structured are end-padded (their
    first superstep must use a sorting group-by, which the default plans
    do)."""
    P = msg.dst.shape[0]
    n, C_new = ec.n_parts, ec.bucket_cap
    if msg.capacity % n:
        pad = n * C_new - msg.capacity
        if pad <= 0:
            return msg
        return MsgRel(
            dst=jnp.pad(msg.dst, ((0, 0), (0, pad)), constant_values=-1),
            payload=jnp.pad(msg.payload, ((0, 0), (0, pad), (0, 0))),
            valid=jnp.pad(msg.valid, ((0, 0), (0, pad))))
    C_old = msg.capacity // n
    pad = C_new - C_old
    if pad <= 0:
        return msg

    def r(a, fill):
        a = a.reshape((P, n, C_old) + a.shape[2:])
        widths = [(0, 0), (0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 3)
        a = jnp.pad(a, widths, constant_values=fill)
        return a.reshape((P, n * C_new) + a.shape[3:])

    return MsgRel(dst=r(msg.dst, -1), payload=r(msg.payload, 0),
                  valid=r(msg.valid, False))
