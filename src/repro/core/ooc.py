"""Out-of-core execution (the paper's central claim, Sections 2.3/5.4/7.2).

On Hyracks, operators spill to disk through the buffer cache, so the same
plans run in-memory and out-of-core. The TPU-adapted memory hierarchy is
three tiers: HBM <-> host DRAM <-> DISK. The Vertex relation and the
run-structured message inbox live in a ``storage.TieredStore`` — a
page-granular buffer cache (``storage/pager.py``) chunked one page per
(relation, super-partition) with a configurable DRAM byte budget
(``memory_budget_bytes``), evicting cold pages to mmap-backed spill files
(``--disk-dir``; ``storage/spillfile.py``) and faulting them back on
access. Each superstep streams SUPER-PARTITIONS (groups of partitions
sized to a device-memory budget) through the jitted partial superstep;
prefetch is disk -> DRAM -> HBM and commit is HBM -> DRAM with lazy
write-back to disk, both hidden behind compute by the pipelined executor
below. With no disk dir and no budget the store degenerates to the pure
DRAM tier — the previous two-level hierarchy — and results are
bit-for-bit identical either way (the disk tier only moves bytes).

Eviction is pluggable (``eviction="lru" | "mru"``): the superstep's page
access pattern is a cyclic sequential scan over super-partitions, which
floods LRU (hit rate 0 when the working set outgrows the budget); MRU
retains a stable prefix of the cycle and converges to hit rate
budget/working-set (the GraphH hot-data-cache observation). In-flight
pipeline slots PIN their pages so prefetched state cannot be evicted
under them.

PIPELINED STREAMING (``stream=True``, the default): the executor keeps up
to ``prefetch_depth`` super-partitions in flight. A DISPATCHER uploads
super-partition s+1's vertex slices and inbox runs with non-blocking
``jax.device_put`` and enqueues its jitted step while s is still
computing; a COLLECTOR consumes completed super-partitions — out of
dispatch order when a later one finishes first — committing each one's
host write-back while the device works on the next. Steady-state wall
time per superstep therefore approaches ``max(compute, transfer)``
instead of their sum (the GraphD/GraphH overlap discipline, arXiv
1601.05590 / 1705.05595). The uploaded vertex block is DONATED to its
updated output (``superstep.jit_superstep``), so a pipeline slot costs
one resident vertex block, not two. ``stream=False`` degenerates to the
synchronous upload -> step -> block -> collect loop (a window of 1).

BARRIER-FREE SUPERSTEP PIPELINE (``barrier_free=True``, the default with
``stream=True``): PR 3/4 still paid two global stalls per superstep —
the whole-inbox rebuild + mutation apply + GS fold ran serially between
supersteps with the device idle, and (on the disk tier) page faults and
dirty write-backs ran synchronously on the dispatcher/collector thread.
Both are gone:

* **Per-destination inbox-run readiness.** A destination super-partition
  of superstep i+1 is dispatchable the moment all P source partitions of
  superstep i have LANDED THEIR RUNS for it (their collected out-blocks)
  — the run-width trim and the GS chain pin that moment to the last
  collect, so what used to be a global barrier of serial work collapses
  into a per-destination ``prepare`` step: rebuild ONLY destination q's
  inbox chunk, apply ONLY q's mutation-inbox columns, then dispatch q —
  while the device is already computing earlier destinations, the host
  rolls the frontier forward by preparing the later ones. Per-superstep
  serial work drops from O(inbox) to O(inbox / n_sp).
* **Rolling fold.** The GS fold, vote-to-halt, write-back/combinability/
  mutation measurements all commit per-destination at collect time (in
  super-partition order for the float aggregate — bit-for-bit with the
  synchronous loop); the executor only SYNCHRONIZES the frontier for
  plan switches (the one-off run sort a merging switch needs is folded
  into the next chunk builds), regrows (the deferred-overflow drain),
  and checkpoints (which eagerly prepare the full generation so the
  saved inbox is complete).
* **Background page I/O** (``storage/io_engine.py``): with a disk tier,
  ``io_threads`` worker threads own the disk legs — the dispatcher
  announces the next dispatchable destination's pages (``readahead``,
  bounded by ``readahead_pages``) so they fault in off the critical
  path, and cold dirty pages drain in eviction order (coalesced) so
  evictions find clean victims and never block on a synchronous write.

The statistics stream records the per-superstep ``readiness_stall_s``
(device-idle gap between a superstep's last collect and the next
superstep's first dispatch — the quantity this mode minimizes) and the
I/O engine's queue depth; ``benchmarks/out_of_core.py`` races
barrier-free against the PR-4 barrier executor into
``BENCH_pipeline.json``.

Because results land asynchronously, the overflow/regrow protocol is
DEFERRED: host state for a super-partition commits only when its result
is collected clean. When a collected result reports overflow, the
collector drains the pipeline — committing in-flight super-partitions
that finished clean, marking overflowed ones for redo — then doubles
ONLY the overflowed capacities (per-source ``GlobalState.overflow``
counters), re-jits, end-pads the already-committed bucket blocks, and
re-dispatches the redo set from retained host state. Float-sensitive
reductions (the user aggregate) are folded in super-partition order at
the rolling fold, so streaming runs are bit-for-bit identical to
synchronous ones.

The host inbox is RUN-STRUCTURED: the per-super-partition bucket tensors
coming off the device — ``(sp, P, C)`` with valid entries occupying a
PREFIX of every ``(src, dst)`` bucket (``connector.bucket_by_owner``'s
layout contract) — are restacked destination-major into per-destination
chunks ``(sp, P_src, C)`` (the host-side analogue of the emulated
exchange) and trimmed to the widest occupied run. The rebuild runs one
destination super-partition at a time through the pager, so peak DRAM
for the exchange is inbox/n_sp, not the full inbox. Because each
destination partition's message block is exactly ``n_parts`` sender runs
of equal width — dst-sorted whenever the sender sorts — the merging
receiver's run-capacity assumption holds host-side and ``plan="auto"``
searches the FULL join x group-by x connector x sender-combine x storage
space here, switching any of them with a re-jit at a superstep boundary.

MUTATIONS span super-partitions through a HOST MUTATION INBOX mirroring
the message one: under ``ec.ooc_collect`` the superstep buckets insert
proposals by owner over all P partitions and hands them back
(``superstep.apply_mutations``) instead of exchanging them in-device
(which only spans the resident super-partition). The collector spills
the collected ``(sp, P, Cm)`` blocks through the same pager; the
per-destination prepare applies them host-side with the same
scatter/resolve semantics the in-memory path uses — so inserting
programs are exact across super-partition boundaries. (Whether any
proposal will land — the vote-to-halt input — is decided from the
collected blocks at commit time, so the fold never waits for the apply.)

storage="delta" (LSM analogue): only CHANGED vertex values are written
back to the host store each superstep instead of the full value array —
the deferred-merge write path, right for sparse-update workloads; on the
disk tier a super-partition with no changed rows never even dirties its
page, so converged regions cost zero disk write-back. Both policies'
write-back bytes are measured every superstep and feed the cost model's
storage dimension (``planner/cost.py`` ``storage_writeback``); the
statistics stream also carries the pager's PER-SUPERSTEP hit rate and
spill bytes (interval counters, reset each superstep — the planner
observes current paging behavior, not cumulative), the measured message
COMBINABILITY (messages/distinct-destination — the signal behind the
sender_combine replan dimension), the mutation rate, and the dispatch /
collect-wait / commit wall-time split, so the planner prices plans with
the critical-path rule (``max(device, host_link, disk)`` plus the serial
readiness leg) when the pipelined executor is active.

Checkpoints hard-link/copy the spill files at the FILE level
(``runtime/checkpoint.py`` ``save_ooc_checkpoint``) — no DRAM
re-serialization — and ``resume_from=`` restarts a job directly from a
checkpoint directory, faulting pages in on first touch. The checkpoint
meta also persists the AdaptiveController's hysteresis state
(window/streak/cooldown), so a resume right before a pending plan switch
does not re-pay the patience window.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.driver import (PlanArg, RunResult, _resolve_plan,
                               default_engine_config, grow_overflowed)
from repro.core.plan import FRONTIER_FLOOR, STORAGES, PhysicalPlan
from repro.core.program import VertexProgram
from repro.core.relations import GlobalState, MsgRel, VertexRel, init_gs
from repro.core.superstep import EngineConfig, jit_superstep
from repro.kernels import backend as kbackend
from repro.obs import explain, memwatch, trace
from repro.obs.metrics import MetricsRegistry
from repro.storage import TieredStore

# the OOC planner searches both storage policies on top of the full
# per-superstep space (in-memory drivers inherit the base plan's storage:
# they never pay a write-back, so the dimension would only produce ties)
_OOC_AUTO_SPACE = {"storages": STORAGES}

# host-resident relations (the chunked pages of the TieredStore)
_RELS = ("vid", "halt", "value", "edge_src", "edge_dst", "edge_val")
_OUT = ("out_dst", "out_pay", "out_val")     # collected sender buckets
_MUT = ("mut_dst", "mut_pay", "mut_val")     # collected insert proposals
_INBOX = ("inbox_dst", "inbox_pay", "inbox_val")


@dataclasses.dataclass
class _InFlight:
    """One dispatched, uncollected super-partition (async device refs)."""
    s: int
    v2: VertexRel
    buckets: MsgRel
    g2: GlobalState
    counts: jax.Array      # (sp, P) per-bucket occupancy, device-computed
    mut: Optional[tuple]   # (dst, payload, valid) insert buckets or None


@dataclasses.dataclass
class _Done:
    """One committed super-partition (host-side results; the bucket and
    mutation blocks themselves live as pages in the TieredStore)."""
    counts: np.ndarray    # (sp, P) per-bucket occupancy of the out block
    halt_ok: bool
    active: int
    agg: np.ndarray
    delta_bytes: int
    full_bytes: int
    has_mut: bool


def _round_run_width(max_count: int, cap: int) -> int:
    """Trim width for the inbox runs: next power of two >= the widest
    occupied run, clamped to [1, bucket_cap]. Power-of-two rounding keeps
    the set of distinct jitted message shapes logarithmic in cap, so the
    jit cache amortizes across supersteps as the frontier breathes."""
    w = 1
    while w < max_count:
        w *= 2
    return max(1, min(w, cap))


def _sort_inbox_runs(inbox):
    """Sort every (dst, src) run of a host inbox chunk by dst — the
    host-side mirror of ``planner.adaptive.migrate_msgs`` for a mid-run
    switch onto the merging connector when the previous plan produced
    UNSORTED runs (plain partitioning without a sender combine). Invalid
    slots key as int32 max, so the stable sort keeps valid entries a run
    prefix."""
    d, p, v = inbox
    key = np.where(v, d, np.iinfo(np.int32).max)
    order = np.argsort(key, axis=2, kind="stable")
    return (np.take_along_axis(d, order, axis=2),
            np.take_along_axis(p, order[..., None], axis=2),
            np.take_along_axis(v, order, axis=2))


def _pad_run_width(block, C_new: int):
    """End-pad a collected (sp, P, C_old) bucket block to C_old=C_new.
    Valid entries occupy a prefix per bucket, so end-padding with invalid
    slots preserves the run layout (cf. driver._regrow_msgs)."""
    d, p, v = block
    pad = C_new - d.shape[2]
    if pad <= 0:
        return block
    return (np.pad(d, ((0, 0), (0, 0), (0, pad)), constant_values=-1),
            np.pad(p, ((0, 0), (0, 0), (0, pad), (0, 0))),
            np.pad(v, ((0, 0), (0, 0), (0, pad))))


def _host_slot_of(dst, valid, Np: int, P: int, partition: str):
    """Host-side mirror of superstep._slot_of (the vid -> local slot
    map), for applying the mutation inbox at the barrier. Slots past
    the capacity clamp to the drop row Np — the device scatter drops
    out-of-bounds insert vids, and np.add.at would raise instead."""
    if partition == "range":
        owner = np.minimum(dst // Np, P - 1)
        slot = np.where(valid, dst - owner * Np, Np)
    else:
        slot = np.where(valid, dst // P, Np)
    return np.minimum(slot, Np)


def _distinct_run_dsts(b_dst: np.ndarray, b_val: np.ndarray) -> int:
    """Distinct destinations PER (source, dst-partition) RUN of one
    collected bucket block — the duplicates a SENDER-side combine could
    actually collapse (global distinct would also count cross-source
    fan-in, which no sender can remove). Sort each run and count value
    boundaries; invalid slots key as int max. Measured at COMMIT time —
    overlapped by the pipeline — instead of during the serial inbox
    rebuild, so the barrier-free fold has the combinability signal the
    moment the last result lands. The trim only drops invalid slots, so
    this equals the old rebuild-time measurement exactly. Caveat: when
    the producing plan already combined, every run is duplicate-free and
    the measured ratio is ~1 — the model then prices the inbox leg
    neutrally and the sender-combine decision falls to the sort-cost
    terms, which is the honest post-combine view."""
    key = np.where(b_val, b_dst, np.iinfo(np.int32).max)
    srt = np.sort(key, axis=2)
    new_run = np.ones(srt.shape, bool)
    new_run[:, :, 1:] = srt[:, :, 1:] != srt[:, :, :-1]
    return int((new_run & (srt != np.iinfo(np.int32).max)).sum())


def _apply_mutation_chunk(store: TieredStore, program, plan, P: int,
                          sp: int, n_sp: int, gen: int, q: int):
    """Apply destination super-partition ``q``'s collected insert
    proposals to the host store — the per-destination half of the host
    mutation inbox (the barrier-free prepare calls it right before
    dispatching ``q``; the barrier path calls it for every q at the
    fold). Mirrors the in-memory ``superstep.apply_mutations``
    scatter/resolve exactly: per destination partition, sum conflicting
    proposals per slot, count them, recover the vid, run
    ``program.resolve``, and install the result (vid set, value replaced,
    halt cleared) where any proposal landed. Touches one destination
    super-partition's columns, so peak DRAM is mut-inbox / n_sp."""
    d = np.concatenate([store.get_page(("mut_dst", gen, s, q))
                        for s in range(n_sp)])    # (P, sp, Cm)
    pv = np.concatenate([store.get_page(("mut_pay", gen, s, q))
                         for s in range(n_sp)])   # (P, sp, Cm, V)
    ok = np.concatenate([store.get_page(("mut_val", gen, s, q))
                         for s in range(n_sp)])   # (P, sp, Cm)
    V = pv.shape[-1]
    vid_pg = store.read("vid", q)
    Np = vid_pg.shape[1]
    touched = False
    val_pg = halt_pg = None
    for p_local in range(sp):
        dd = d[:, p_local, :].reshape(-1)
        oo = ok[:, p_local, :].reshape(-1)
        if not oo.any():
            continue
        vv = pv[:, p_local, :, :].reshape(-1, V)
        slot = _host_slot_of(dd, oo, Np, P, plan.partition)
        # same dtypes as the device per_part (float32 sums, int32
        # counts): a custom resolve must see identical promotion
        # rules host-side or parity breaks in the last ulp
        summed = np.zeros((Np + 1, V), np.float32)
        np.add.at(summed, slot,
                  np.where(oo[:, None], vv, np.float32(0.0)))
        cnt = np.zeros((Np + 1,), np.int32)
        np.add.at(cnt, slot, oo)
        newvid = np.full((Np + 1,), -1, np.int32)
        np.maximum.at(newvid, slot,
                      np.where(oo, dd, -1).astype(np.int32))
        resolved = np.asarray(program.resolve(
            newvid[:Np], summed[:Np], cnt[:Np]), np.float32)
        take = cnt[:Np] > 0
        if not take.any():
            continue
        if not touched:
            val_pg = store.read("value", q)
            halt_pg = store.read("halt", q)
            touched = True
        vid_pg[p_local][take] = newvid[:Np][take]
        val_pg[p_local][take] = resolved[take]
        halt_pg[p_local][take] = False
    if touched:
        # pages were mutated in place: re-put to mark them dirty
        store.write("vid", q, vid_pg)
        store.write("value", q, val_pg)
        store.write("halt", q, halt_pg)


def _adopt_checkpoint(store: TieredStore, z: dict, src):
    """Install a spill-directory checkpoint into a fresh store (pages
    hard-linked/copied at the file level; on the disk tier nothing is
    read into DRAM until first touch). ``z``/``src`` come from the
    caller's ``load_ooc_meta``. Returns the restored GlobalState."""
    for nm in _RELS:
        for s in range(store.n_sp):
            store.adopt_page((nm, s), src / f"{nm}_{s}.npy", relation=nm)
    for nm in _INBOX:
        for q in range(store.n_sp):
            store.adopt_page((nm, 0, q), src / f"{nm}_{q}.npy",
                             immutable=True)
    return GlobalState(
        halt=jnp.asarray(bool(z["halt"])),
        aggregate=jnp.asarray(z["aggregate"]),
        superstep=jnp.asarray(int(z["superstep"]), jnp.int32),
        overflow=jnp.asarray(z["overflow"]),
        active_count=jnp.asarray(int(z["active"]), jnp.int32),
        msg_count=jnp.asarray(int(z["msgs"]), jnp.int32))


class _ShapeVert:
    """Shape-only stand-in for a VertexRel (resume path: the capacity
    policies only read ``.vid.shape`` / ``.edge_src.shape``)."""

    def __init__(self, P, Np, Ep):
        self.vid = np.empty((P, Np), np.bool_)
        self.edge_src = np.empty((P, Ep), np.bool_)


def run_out_of_core(vert: Optional[VertexRel], program: VertexProgram,
                    plan: PlanArg = PhysicalPlan(), *,
                    budget_partitions: int,
                    max_supersteps: int = 50,
                    ec: Optional[EngineConfig] = None,
                    auto_config=None,
                    auto_space: Optional[dict] = None,
                    kernel_impl: Optional[str] = None,
                    stream: bool = True,
                    prefetch_depth: int = 2,
                    barrier_free: bool = True,
                    memory_budget_bytes: Optional[int] = None,
                    disk_dir: Optional[str] = None,
                    eviction: str = "lru",
                    io_threads: Optional[int] = None,
                    readahead_pages: int = 8,
                    checkpoint_every: int = 0,
                    checkpoint_dir: Optional[str] = None,
                    resume_from: Optional[str] = None,
                    recover: bool = False,
                    max_retries: int = 3,
                    on_superstep=None) -> RunResult:
    """budget_partitions = how many partitions fit in device memory at once
    (the HBM budget). P % budget_partitions must be 0. plan="auto" picks
    the plan from the cost model and re-picks it at superstep boundaries —
    over the FULL plan space including connector and storage (messages
    live host-side between supersteps in run-structured buffers, so any
    switch is just a re-jit — no in-flight layout migration).

    stream=True (default) pipelines the super-partition stream: up to
    ``prefetch_depth`` super-partitions are in flight at once, hiding
    host<->device transfer behind compute; stream=False is the
    synchronous loop (a pipeline window of 1). Results are bit-for-bit
    identical either way.

    barrier_free=True (default; requires stream=True) removes the global
    inter-superstep barrier: the inbox rebuild and mutation apply run
    per destination, interleaved with the next superstep's dispatches
    (per-destination readiness), and the executor only synchronizes for
    plan switches, regrows and checkpoints. Results are bit-for-bit
    identical to the barrier executor and the synchronous loop.

    DISK TIER: ``memory_budget_bytes`` caps the host-DRAM bytes the
    run's relations and inbox may occupy at once; cold pages spill to
    mmap-backed files under ``disk_dir`` (required when a budget is set)
    and fault back in on access. ``eviction`` picks the page-replacement
    policy: "lru", or "mru" — which resists the superstep's cyclic
    sequential scan (see ``storage/pager.py``). ``io_threads`` (default:
    1 whenever a disk dir is configured, else 0) moves the disk legs to
    a background page-I/O engine — readahead of the next dispatchable
    destination's pages (at most ``readahead_pages`` per tick) plus a
    coalesced dirty-page drain — so the dispatcher/collector never touch
    disk on the critical path. Results are bit-for-bit identical to the
    pure-DRAM tier.

    ``checkpoint_every``/``checkpoint_dir`` snapshot the host store at
    superstep boundaries by hard-linking/copying its spill files (no
    DRAM re-serialization); ``resume_from=<checkpoint dir>`` restarts
    from such a snapshot — ``vert`` may then be None.

    OBSERVABILITY: every pipeline leg records a span when ``repro.obs``
    tracing is on (``trace.start()`` / ``pregel_run --trace``) —
    prepare/dispatch on the main loop, collect-wait/commit per collected
    super-partition, the readiness stall as an explicit span from the
    previous superstep's last collect to the next first dispatch, plus
    replan/regrow/checkpoint events; the I/O-engine workers record their
    own fault/writeback spans on their threads. A per-run
    ``MetricsRegistry`` (shared with the store's I/O engine) merges its
    interval snapshot into every record's ``extra["metrics"]``.
    ``on_superstep(i, rec_dict)`` is called after each superstep's
    record lands — the live progress hook ``pregel_run --progress``
    uses.

    ``recover=True`` runs the job under the failure manager's recovery
    supervisor: a recoverable failure (WorkerFailure, disk I/O, typed
    page/checkpoint corruption) restores the latest VALID committed
    checkpoint under ``checkpoint_dir`` — deep-verified, skipping any
    snapshot whose restore surfaced corruption — and replays from it.
    Replays resume at the checkpoint's own partition layout, so the
    recovered run converges bit-for-bit with an unfailed one."""
    from repro.planner.stats import StatsCollector
    from repro.runtime import faults as chaos
    from repro.runtime.checkpoint import save_ooc_checkpoint

    if recover:
        from repro.runtime.checkpoint import latest_ooc_checkpoint
        from repro.runtime.failure import supervised_run
        n_workers = (vert.vid.shape[0] // budget_partitions
                     if vert is not None else max(1, max_retries + 1))

        def _attempt(healthy, resume):
            if resume is None and vert is None:
                raise RuntimeError(
                    "no valid checkpoint to restore and no initial "
                    "relations to restart from")
            return run_out_of_core(
                vert, program, plan,
                budget_partitions=budget_partitions,
                max_supersteps=max_supersteps, ec=ec,
                auto_config=auto_config, auto_space=auto_space,
                kernel_impl=kernel_impl, stream=stream,
                prefetch_depth=prefetch_depth, barrier_free=barrier_free,
                memory_budget_bytes=memory_budget_bytes,
                disk_dir=disk_dir, eviction=eviction,
                io_threads=io_threads, readahead_pages=readahead_pages,
                checkpoint_every=checkpoint_every,
                checkpoint_dir=checkpoint_dir, resume_from=resume,
                recover=False, on_superstep=on_superstep)

        def _pick(bad):
            if not checkpoint_dir:
                return None
            return latest_ooc_checkpoint(checkpoint_dir, skip=bad,
                                         deep=True)

        return supervised_run(_attempt, _pick, n_workers=n_workers,
                              max_retries=max_retries,
                              initial_resume=resume_from)

    t0 = time.time()
    sp = budget_partitions
    if checkpoint_every and not checkpoint_dir:
        raise ValueError("checkpoint_every needs a checkpoint_dir — "
                         "otherwise the job would silently run "
                         "without any checkpoints")
    barrier_free = bool(barrier_free and stream)
    if io_threads is None:
        io_threads = 1 if disk_dir else 0
    store = None
    try:
        ck_meta = ck_gs = ck_src = None
        if resume_from is not None:
            # shapes come from the checkpoint pages; vert is not needed
            from repro.runtime.checkpoint import load_ooc_meta
            ck_meta, ck_gs, ck_src = load_ooc_meta(resume_from)
            n_sp = ck_meta["n_sp"]
            P = n_sp * sp
            if ck_meta.get("sp", sp) != sp:
                raise ValueError(
                    f"checkpoint streams {ck_meta.get('sp')} "
                    f"partitions per super-partition; got "
                    f"budget_partitions={sp}")
        else:
            P = vert.vid.shape[0]
            assert P % sp == 0
            n_sp = P // sp
        metrics = MetricsRegistry()
        store = TieredStore(n_sp=n_sp, budget_bytes=memory_budget_bytes,
                            disk_dir=disk_dir, policy=eviction,
                            io_threads=io_threads,
                            readahead_pages=readahead_pages,
                            metrics=metrics)
        gen = 0            # inbox generation (one per superstep fold)
        if resume_from is not None:
            gs = _adopt_checkpoint(store, ck_gs, ck_src)
            i = int(ck_meta["superstep"])
            Np = store.read("vid", 0).shape[1]
            Ep = store.read("edge_src", 0).shape[1]
            C_in = store.get_page(("inbox_dst", 0, 0)).shape[2]
            shape_vert = _ShapeVert(P, Np, Ep)
            graph_stats = None
            if plan == "auto":
                # only the auto-planner needs graph statistics: a static
                # resume must not stream two whole relations through the
                # budgeted cache just to discard the counts
                n_live = sum(int((store.read("vid", s) >= 0).sum())
                             for s in range(n_sp))
                n_edges = sum(int((store.read("edge_src", s) >= 0).sum())
                              for s in range(n_sp))
                from repro.planner.cost import GraphStats
                graph_stats = GraphStats(
                    n_vertices=n_live, n_edges=n_edges, n_partitions=P,
                    vertex_capacity=Np, edge_capacity=Ep,
                    value_dims=program.value_dims,
                    msg_dims=program.msg_dims)
        else:
            Np = vert.vid.shape[1]
            shape_vert = vert
            i = 0
            graph_stats = None
        saved_plan = None
        if ck_meta is not None and ck_meta.get("plan"):
            saved_plan = PhysicalPlan(**ck_meta["plan"])
        wanted_auto = plan == "auto"
        if kernel_impl is not None:
            # pin the hot-path kernel dispatch: into the concrete plan
            # directly, or into the auto search space so every candidate
            # (initial choice and mid-run switches) carries it
            if isinstance(plan, PhysicalPlan):
                plan = dataclasses.replace(plan, kernel_impl=kernel_impl)
            else:
                auto_space = dict(_OOC_AUTO_SPACE if auto_space is None
                                  else auto_space)
                auto_space.setdefault("kernel_impls", (kernel_impl,))
        plan, controller = _resolve_plan(
            shape_vert if resume_from is None else None, program, plan,
            adaptive=True, ec=ec, auto_config=auto_config,
            auto_space=_OOC_AUTO_SPACE if auto_space is None
            else auto_space, graph_stats=graph_stats)
        if saved_plan is not None:
            if wanted_auto:
                # restart auto jobs from the plan IN EFFECT at the
                # checkpoint (it produced the restored inbox's layout)
                # rather than re-choosing blind at superstep-0 stats;
                # the controller re-plans from live statistics as usual
                plan = saved_plan
                if kernel_impl is not None:
                    plan = dataclasses.replace(plan,
                                               kernel_impl=kernel_impl)
                if controller is not None:
                    controller.plan = plan
            if (plan.connector == "partitioning_merging"
                    and saved_plan.connector != "partitioning_merging"
                    and not saved_plan.sender_combine):
                # the checkpointed inbox's runs are unsorted but the
                # resumed plan's merging receiver assumes dst order:
                # one-off sort, the resume analogue of the mid-run
                # switch guard below
                for q in range(n_sp):
                    triple = _sort_inbox_runs(tuple(
                        store.get_page((nm, 0, q)) for nm in _INBOX))
                    for nm, a in zip(_INBOX, triple):
                        store.put_page((nm, 0, q), a, immutable=True)
        if controller is not None and ck_meta is not None \
                and ck_meta.get("controller"):
            # restore the hysteresis window/streak/cooldown, so a resume
            # right before a pending switch does not re-pay the patience
            # window
            controller.load_state(ck_meta["controller"])
        caller_ec = ec is not None
        ec = ec or default_engine_config(shape_vert, program, plan)
        if not caller_ec and ck_meta is not None and ck_meta.get("caps"):
            # restore the checkpointed (possibly overflow-regrown)
            # capacities instead of replaying the regrow cascade from
            # the defaults on every restart
            ec = dataclasses.replace(ec, **ck_meta["caps"])
        # resolve frontier_cap=0 (the EngineConfig "Np/2" default) to its
        # concrete value up front: the overflow regrow path doubles it,
        # and 0 * 2 = 0 would re-jit the identical config forever
        ec = dataclasses.replace(ec, ooc_collect=True,
                                 frontier_cap=ec.frontier_cap or
                                 max(Np // 2, 1))
        if explain.enabled():
            # plan-audit ledger: the shadow auditor re-prices the
            # in-effect plan per superstep (static resumes without
            # graph statistics stay decision-log-only)
            from repro.planner.cost import EMULATED_MACHINE
            explain.attach(
                program,
                vert=shape_vert if resume_from is None else None,
                g=(controller.g if controller is not None
                   else graph_stats),
                plan=plan,
                machine=(controller.machine if controller is not None
                         else EMULATED_MACHINE),
                space_kw=(_OOC_AUTO_SPACE if auto_space is None
                          else auto_space))
        if memwatch.enabled():
            memwatch.configure(
                ec=ec, Np=Np, Ep=shape_vert.edge_src.shape[1],
                value_dims=program.value_dims,
                msg_dims=program.msg_dims,
                budget_bytes=memory_budget_bytes)
        step = jit_superstep(program, plan, ec, donate_vertex=True)
        seen_widths = set()   # inbox widths this `step` has already traced

        # kernel-path gather layouts, one per super-partition q. edge_src
        # is immutable for the whole run (mutations rewrite edge_dst /
        # edge_val only; commit never writes edge_src), so the cache is
        # valid across regrows AND plan switches; plan_layout_fixed pads
        # every q's layout to the SAME shape, so the shared jitted step
        # traces once and takes each q's layout as a plain traced argument
        gather_layouts = {}

        def gather_layout(q):
            if not kbackend.wants_edge_layout(plan):
                return None
            lay = gather_layouts.get(q)
            if lay is None:
                perm, tile = kbackend.plan_edge_layout(
                    store.read("edge_src", q), Np)
                lay = (jax.device_put(perm), jax.device_put(tile))
                gather_layouts[q] = lay
            return lay

        D = program.msg_dims
        if resume_from is None:
            # host-resident state through the buffer cache (DRAM pages
            # backed by the disk tier when configured)
            for k in _RELS:
                store.register(k, np.asarray(getattr(vert, k)))
            gs = init_gs(program.agg_dims)
            # init values on device per super-partition (streams once)
            from repro.core.driver import init_vertex_values
            for s in range(n_sp):
                vpart = VertexRel(**{k: jnp.asarray(store.read(k, s))
                                     for k in _RELS})
                vpart = init_vertex_values(vpart, program, gs)
                store.write("value", s, np.asarray(vpart.value))
            # run-structured empty inbox: one invalid slot per (dst, src)
            # run, chunked per destination super-partition
            C_in = 1
            for q in range(n_sp):
                store.put_page(("inbox_dst", 0, q),
                               np.full((sp, P, 1), -1, np.int32),
                               immutable=True)
                store.put_page(("inbox_pay", 0, q),
                               np.zeros((sp, P, 1, D), np.float32),
                               immutable=True)
                store.put_page(("inbox_val", 0, q),
                               np.zeros((sp, P, 1), bool),
                               immutable=True)
        n_live = (controller.g.n_vertices if controller is not None
                  else sum(int((store.read("vid", s) >= 0).sum())
                           for s in range(n_sp)))
        coll = StatsCollector(n_partitions=P, vertex_capacity=Np,
                              msg_dims=D, n_vertices=n_live,
                              metrics=metrics)
        m_prepare = metrics.histogram("ooc.prepare_s")
        m_regrows = metrics.counter("ooc.regrows")
        m_switches = metrics.counter("ooc.plan_switches")
        stats = []
        delta_bytes = full_bytes = 0
        recompiled = True  # first superstep includes the jit compile
        window = max(int(prefetch_depth), 1) if stream else 1
        store.take_interval()    # reset per-superstep pager counters
        # ---- rolling-frontier state (reassigned at every fold; the
        # closures below read the CURRENT binding at call time) ---------
        prepared = set(range(n_sp))   # gen-0 chunks exist (init / resume)
        cur_has_mut = False           # no mutation pages precede gen 0
        sort_on_build = False         # one-off run sort on a merging switch
        todo = deque()
        committed = {}
        t_io = {"dispatch": 0.0, "wait": 0.0, "commit": 0.0}
        acc = {"distinct": 0, "proposals": 0, "applied": False}
        stall_cell = [None]
        t_ready0 = time.time()

        def prepare(q):
            """Per-destination readiness work for generation ``gen``:
            restack destination q's inbox chunk from the runs all n_sp
            sources landed for it (the host-side emulated exchange —
            source-major stack, destination-major transpose, trim every
            run to the fold's C_in; valid entries are a bucket PREFIX,
            so the trim drops only invalid tail slots), then apply q's
            mutation-inbox columns. Under barrier_free this runs
            interleaved with dispatches — the device computes earlier
            destinations while the host prepares later ones; the barrier
            path calls it for every q at the fold."""
            if q in prepared:
                return
            tp = time.time()
            d_q = np.concatenate([store.get_page(("out_dst", gen, s, q))
                                  for s in range(n_sp)], axis=0)
            p_q = np.concatenate([store.get_page(("out_pay", gen, s, q))
                                  for s in range(n_sp)], axis=0)
            v_q = np.concatenate([store.get_page(("out_val", gen, s, q))
                                  for s in range(n_sp)], axis=0)
            triple = (np.ascontiguousarray(
                          d_q.transpose(1, 0, 2)[:, :, :C_in]),
                      np.ascontiguousarray(
                          p_q.transpose(1, 0, 2, 3)[:, :, :C_in]),
                      np.ascontiguousarray(
                          v_q.transpose(1, 0, 2)[:, :, :C_in]))
            if sort_on_build:
                # a plan switch onto the merging receiver landed at the
                # fold before this chunk was built: give it dst-sorted
                # runs at build time (the rolling analogue of the
                # post-switch inbox sort)
                triple = _sort_inbox_runs(triple)
            for nm, a in zip(_INBOX, triple):
                store.put_page((nm, gen, q), a, immutable=True)
            for s in range(n_sp):
                for nm in _OUT:
                    store.delete_page((nm, gen, s, q))
            if gen > 0:
                for nm in _INBOX:
                    store.delete_page((nm, gen - 1, q))
            if cur_has_mut:
                _apply_mutation_chunk(store, program, plan, P, sp, n_sp,
                                      gen, q)
                for s in range(n_sp):
                    for nm in _MUT:
                        store.delete_page((nm, gen, s, q))
            m_prepare.observe(time.time() - tp)
            trace.complete("prepare", "prepare", tp, time.time(), q=q)
            prepared.add(q)

        def dispatch(q):
            """Non-blocking disk->DRAM->HBM prefetch + step enqueue
            for one super-partition: pages fault in from the spill
            tier if evicted, upload with ``jax.device_put``, and the
            device starts (or queues) the work while the host moves
            on to prepare or collect another one. The value page stays
            PINNED until commit (the delta compare needs the
            pre-step values resident)."""
            td = time.time()
            if store.engine is not None:
                # announce the NEXT destination's pages to the I/O
                # engine so its faults happen off the critical path.
                # When this superstep's queue has drained, warm the
                # NEXT superstep's first destination instead — its
                # relation pages are the coldest (touched first after
                # the fold) and would otherwise fault inside the
                # readiness stall.
                if todo:
                    qn = todo[0]
                    keys = [(nm, qn) for nm in _RELS]
                    if qn in prepared:
                        keys += [(nm, gen, qn) for nm in _INBOX]
                    else:
                        keys += [(nm, gen, s2, qn)
                                 for s2 in range(n_sp) for nm in _OUT]
                        if cur_has_mut:
                            keys += [(nm, gen, s2, qn)
                                     for s2 in range(n_sp)
                                     for nm in _MUT]
                else:
                    keys = [(nm, 0) for nm in _RELS]
                    keys += [(nm, gen + 1, s2, 0)
                             for s2 in range(n_sp) for nm in _OUT]
                store.readahead(keys)
            store.pin("value", q)
            vpart = VertexRel(**{k: jax.device_put(store.read(k, q))
                                 for k in _RELS})
            # incoming chunk: the run-structured inbox page for this
            # destination super-partition, runs flattened — already
            # the receiver's layout
            d_in = store.get_page(("inbox_dst", gen, q))
            p_in = store.get_page(("inbox_pay", gen, q))
            v_in = store.get_page(("inbox_val", gen, q))
            msg = MsgRel(
                dst=jax.device_put(d_in.reshape(sp, P * C_in)),
                payload=jax.device_put(
                    p_in.reshape(sp, P * C_in, D)),
                valid=jax.device_put(v_in.reshape(sp, P * C_in)))
            # part0 = this block's first GLOBAL partition index, so
            # resurrect mints correct vids past super-partition 0
            with trace.annotate("step_enqueue", "compute"):
                v2, buckets, g2, cnts, mut = step(
                    vpart, msg, gs, jnp.asarray(q * sp, jnp.int32),
                    gather_layout(q))
            now = time.time()
            t_io["dispatch"] += now - td
            trace.complete("dispatch", "dispatch", td, now, q=q)
            if stall_cell[0] is None:
                # device-idle gap: from the previous superstep's last
                # collect to this superstep's first step enqueue — the
                # readiness stall the barrier-free pipeline minimizes
                stall_cell[0] = now - t_ready0
                trace.complete("readiness_stall", "dispatch",
                               t_ready0, now)
            return _InFlight(q, v2, buckets, g2, cnts, mut)

        def commit(e):
            """Drain one clean super-partition D2H and commit its
            host state (delta vs full write-back policy; both byte
            counts are measured every superstep to feed the cost
            model's storage dimension). Blocking on the value pull
            is the pipeline's compute-wait; everything after is
            host-side commit time. Dirty pages write back to disk
            lazily (on eviction, background drain or checkpoint),
            overlapped by the pipeline like every other page move.
            The fold-time signals — combinability, mutation proposal
            count, will-any-insert-land — are measured HERE, on the
            full-width collected blocks, so the rolling fold never
            waits for the inbox rebuild to learn them."""
            tw = time.time()
            new_value = np.asarray(e.v2.value)   # blocks on e's step
            tc = time.time()
            t_io["wait"] += tc - tw
            trace.complete("collect_wait", "collect", tw, tc, q=e.s)
            old_value = store.read("value", e.s)
            changed = np.any(new_value != old_value, axis=-1)
            d_b = int(changed.sum()) * new_value.shape[-1] * 4
            f_b = new_value.size * 4
            if plan.storage == "delta":
                store.write_rows("value", e.s, changed,
                                 new_value[changed])
            else:
                store.write("value", e.s, new_value)
            new_halt = np.asarray(e.v2.halt)
            new_vid = np.asarray(e.v2.vid)
            store.write("halt", e.s, new_halt)
            store.write("vid", e.s, new_vid)
            store.write("edge_dst", e.s, np.asarray(e.v2.edge_dst))
            store.write("edge_val", e.s, np.asarray(e.v2.edge_val))
            store.unpin("value", e.s)
            # collected sender buckets -> per-destination out pages of
            # the NEXT generation (chunking here is what keeps the
            # prepare's inbox rebuild at inbox/n_sp peak DRAM). Once
            # every source has landed its runs for destination q, q is
            # dispatchable — per-destination readiness.
            b_dst = np.asarray(e.buckets.dst)
            b_pay = np.asarray(e.buckets.payload)
            b_val = np.asarray(e.buckets.valid)
            counts = np.asarray(e.counts)
            if controller is not None:
                # only the adaptive controller consumes the signal, so
                # fixed-plan runs skip the O(M log C) pass; trim the
                # sort to the block's occupancy (valid entries are a
                # bucket prefix) — bucket_cap carries slack the sort
                # must not pay for
                w = max(int(counts.max(initial=0)), 1)
                acc["distinct"] += _distinct_run_dsts(
                    b_dst[:, :, :w], b_val[:, :, :w])
            for q in range(n_sp):
                qsl = slice(q * sp, (q + 1) * sp)
                store.put_page(("out_dst", gen + 1, e.s, q),
                               b_dst[:, qsl])
                store.put_page(("out_pay", gen + 1, e.s, q),
                               b_pay[:, qsl])
                store.put_page(("out_val", gen + 1, e.s, q),
                               b_val[:, qsl])
            has_mut = e.mut is not None
            if has_mut:
                # chunked per destination like the out blocks, so the
                # prepare's apply pass runs at mut-inbox / n_sp peak
                # DRAM and never re-faults full-width pages. The
                # vote-to-halt input ("will any proposal land?") is
                # decided here from the same slot math the apply uses.
                m_dst = np.asarray(e.mut[0])
                m_pay = np.asarray(e.mut[1])
                m_ok = np.asarray(e.mut[2])
                acc["proposals"] += int(m_ok.sum())
                if not acc["applied"]:
                    lands = _host_slot_of(m_dst, m_ok, Np, P,
                                          plan.partition) < Np
                    if bool((m_ok & lands).any()):
                        acc["applied"] = True
                for q in range(n_sp):
                    qsl = slice(q * sp, (q + 1) * sp)
                    store.put_page(("mut_dst", gen + 1, e.s, q),
                                   m_dst[:, qsl])
                    store.put_page(("mut_pay", gen + 1, e.s, q),
                                   m_pay[:, qsl])
                    store.put_page(("mut_val", gen + 1, e.s, q),
                                   m_ok[:, qsl])
            done = _Done(
                counts=counts,
                halt_ok=bool(np.all(new_halt | (new_vid < 0))),
                active=int(e.g2.active_count),
                agg=np.asarray(e.g2.aggregate),
                delta_bytes=d_b, full_bytes=f_b, has_mut=has_mut)
            now = time.time()
            t_io["commit"] += now - tc
            trace.complete("commit", "commit", tc, now, q=e.s)
            return done

        while i < max_supersteps and not bool(gs.halt):
            chaos.superstep_tick(i, "ooc")
            ts = time.time()
            this_recompiled = recompiled
            recompiled = False
            if C_in not in seen_widths:
                # a new message width retraces inside jit: this
                # superstep's wall time includes a compile
                seen_widths.add(C_in)
                this_recompiled = True
            ovf0 = np.asarray(gs.overflow)
            t_io = {"dispatch": 0.0, "wait": 0.0, "commit": 0.0}
            acc = {"distinct": 0, "proposals": 0, "applied": False}
            stall_cell = [None]
            committed = {}                # s -> _Done
            todo = deque(range(n_sp))     # dispatch queue (redo re-enters)
            pending = []                  # _InFlight, dispatch order

            while todo or pending:
                # fill the pipeline window, preparing each destination
                # (chunk rebuild + mutation apply) just before its
                # dispatch — under barrier_free this is where the old
                # barrier's serial work overlaps the device
                while todo and len(pending) < window:
                    q = todo.popleft()
                    prepare(q)
                    pending.append(dispatch(q))
                # collect a completed super-partition — out of dispatch
                # order when a later one is already done — else block on
                # the oldest
                j = 0
                if len(pending) > 1:
                    j = next((k for k, e in enumerate(pending)
                              if e.g2.overflow.is_ready()), 0)
                e = pending.pop(j)
                delta = np.asarray(e.g2.overflow) - ovf0   # blocks on e
                if (delta > 0).any():
                    # DEFERRED OVERFLOW: a bucket / frontier / mutation /
                    # edge capacity overflowed mid-pipeline. Unwind the
                    # in-flight prefetch: drain every pending result,
                    # committing the ones that finished clean and marking
                    # overflowed ones for redo; then double ONLY the
                    # overflowed capacities, re-jit, end-pad the
                    # committed blocks and redo from retained host state
                    # (nothing from a dirty step was committed). This is
                    # one of the three events the barrier-free frontier
                    # synchronizes on.
                    t_rg = time.time()
                    redo = {e.s}
                    store.unpin("value", e.s)
                    for other in pending:
                        od = np.asarray(other.g2.overflow) - ovf0
                        if (od > 0).any():
                            delta = delta + od
                            redo.add(other.s)
                            store.unpin("value", other.s)
                        else:
                            committed[other.s] = commit(other)
                    pending = []
                    ec = grow_overflowed(ec, delta)
                    step = jit_superstep(program, plan, ec,
                                         donate_vertex=True)
                    seen_widths = {C_in}
                    for s2, done in committed.items():
                        for q in range(n_sp):
                            old = tuple(
                                store.get_page((nm, gen + 1, s2, q))
                                for nm in _OUT)
                            new = _pad_run_width(old, ec.bucket_cap)
                            if new[0] is not old[0]:
                                for nm, a in zip(_OUT, new):
                                    store.put_page((nm, gen + 1, s2, q),
                                                   a)
                        if done.has_mut:
                            for q in range(n_sp):
                                old = tuple(
                                    store.get_page((nm, gen + 1, s2, q))
                                    for nm in _MUT)
                                new = _pad_run_width(old,
                                                     ec.mutation_cap)
                                if new[0] is not old[0]:
                                    for nm, a in zip(_MUT, new):
                                        store.put_page(
                                            (nm, gen + 1, s2, q), a)
                    todo = deque(sorted(redo | set(todo)))
                    stats.append(coll.event(
                        i, "regrow", bucket_cap=ec.bucket_cap,
                        frontier_cap=ec.frontier_cap,
                        mutation_cap=ec.mutation_cap,
                        sources=np.flatnonzero(delta > 0).tolist(),
                        redo=sorted(redo)).as_dict())
                    m_regrows.inc()
                    trace.complete("overflow_regrow", "replan",
                                   t_rg, time.time())
                    this_recompiled = True
                    if controller is not None:
                        controller.note_shape_change()
                    continue
                committed[e.s] = commit(e)
            t_ready0 = time.time()

            # ROLLING FOLD: every input was measured at collect time, so
            # this is scalar work — the per-super-partition results fold
            # in super-partition order (float aggregate order must not
            # depend on pipeline completion order — bit-for-bit vs the
            # synchronous loop), and the next superstep's first
            # destination dispatches right after, without waiting for
            # any inbox rebuild or mutation apply.
            t_fold = time.time()
            ordered = [committed[s] for s in range(n_sp)]
            halt_all = all(d.halt_ok for d in ordered)
            active = sum(d.active for d in ordered)
            agg = np.zeros((program.agg_dims,), np.float32)
            for d in ordered:
                agg += d.agg
            step_delta = sum(d.delta_bytes for d in ordered)
            step_full = sum(d.full_bytes for d in ordered)
            delta_bytes += step_delta
            full_bytes += step_full
            msg_count = int(sum(int(d.counts.sum()) for d in ordered))
            C_eff = _round_run_width(
                int(max((int(d.counts.max(initial=0)) for d in ordered),
                        default=0)), ec.bucket_cap)
            combinability = (msg_count / acc["distinct"]
                             if acc["distinct"] else 1.0)
            # host mutation inbox vote: an insert that WILL land (decided
            # at commit time from the collected blocks) clears halt on
            # its slot, exactly as the in-device path would have; the
            # apply itself happens per destination in prepare()
            mutation_rate = 0.0
            if any(d.has_mut for d in ordered):
                mutation_rate = acc["proposals"] / max(n_live, 1)
                if acc["applied"]:
                    halt_all = False
            gen += 1
            C_in = C_eff
            prepared = set()
            cur_has_mut = any(d.has_mut for d in ordered)
            sort_on_build = False
            i += 1
            gs = GlobalState(halt=jnp.asarray(halt_all and msg_count == 0),
                             aggregate=jnp.asarray(agg),
                             superstep=jnp.asarray(i, jnp.int32),
                             overflow=gs.overflow,
                             active_count=jnp.asarray(active, jnp.int32),
                             msg_count=jnp.asarray(msg_count, jnp.int32))
            trace.complete("fold", "commit", t_fold, time.time(), i=i)
            if not barrier_free:
                # the PR-4 barrier: rebuild the whole generation and
                # apply every destination's mutations before anything
                # else dispatches
                for q in range(n_sp):
                    prepare(q)
            if store.engine is not None:
                # close the I/O pacing loop: fit the readahead depth to
                # how many observed-latency page faults the superstep's
                # compute window (the collect-wait) can hide
                store.engine.autopace(t_io["wait"])
            interval = store.take_interval()
            pool_now = store.stats()
            faults = interval["misses"]
            looks = faults + interval["hits"]
            spill_rd = interval["spill_read_bytes"]
            spill_wr = interval["spill_write_bytes"]
            rec = coll.record(
                i, active=active, messages=msg_count,
                wall_s=time.time() - ts, recompiled=this_recompiled,
                delta_bytes=delta_bytes, full_bytes=full_bytes,
                change_density=step_delta / max(step_full, 1),
                storage=plan.storage, ooc=True, streaming=stream,
                barrier_free=barrier_free,
                super_partitions=n_sp,
                readiness_stall_s=stall_cell[0] or 0.0,
                dispatch_s=t_io["dispatch"], collect_wait_s=t_io["wait"],
                commit_s=t_io["commit"],
                combinability=combinability,
                mutation_rate=mutation_rate,
                # MEASURED paging, not configuration: a disk_dir whose
                # budget never forces an eviction must not make the cost
                # model price phantom disk traffic. All pager counters
                # are PER-SUPERSTEP (interval counters, reset each
                # record), so the planner sees current behavior.
                spill=bool(spill_rd or spill_wr),
                cache_hit_rate=(1.0 - faults / looks) if looks else 1.0,
                spill_read_bytes=spill_rd,
                spill_write_bytes=spill_wr,
                io_queue_depth=interval.get("io_queue_depth_peak", 0),
                io_queue_depth_mean=interval.get("io_queue_depth_mean",
                                                 0.0),
                # queue-depth DISTRIBUTION (metrics histogram), not just
                # the mean: a spiky engine with a calm average still
                # stalls evictions at its p90
                io_queue_depth_p50=interval.get("io_queue_depth_p50",
                                                0.0),
                io_queue_depth_p90=interval.get("io_queue_depth_p90",
                                                0.0),
                io_queue_depth_max=interval.get("io_queue_depth_max",
                                                0.0),
                readahead_depth=interval.get("readahead_depth",
                                             readahead_pages),
                pager_resident_bytes=pool_now["resident_bytes"],
                pager_peak_bytes=pool_now["peak_resident_bytes"])
            stats.append(rec.as_dict())
            if explain.enabled():
                # audit the plan that EXECUTED this superstep (a switch
                # below only takes effect on the next one)
                explain.superstep(rec, plan=plan,
                                  bucket_cap=ec.bucket_cap)
            if memwatch.enabled():
                # tier snapshot at the superstep boundary: only `sp`
                # partitions are device-resident under the OOC stream
                memwatch.sample(i, store=store, resident_parts=sp)
            if trace.enabled():
                trace.counter("active", active)
                trace.counter("messages", msg_count)
                trace.counter("io_queue_depth",
                              interval.get("io_queue_depth_peak", 0))
            if on_superstep is not None:
                on_superstep(i, stats[-1])
            switched = False
            if controller is not None and not bool(gs.halt):
                with trace.span("replan", "replan"):
                    new_plan = controller.observe(rec,
                                                  bucket_cap=ec.bucket_cap)
                if new_plan is not None:
                    if (new_plan.connector == "partitioning_merging"
                            and plan.connector != "partitioning_merging"
                            and not plan.sender_combine):
                        # the old plan left runs unsorted; give the
                        # merging receiver its dst-sorted runs. Chunks
                        # already built get a one-off host-side sort;
                        # chunks the rolling frontier has not built yet
                        # are sorted at build time (sort_on_build) — the
                        # plan switch is a synchronization event only
                        # for the re-jit, never a full-inbox stall.
                        for q in sorted(prepared):
                            triple = _sort_inbox_runs(tuple(
                                store.get_page((nm, gen, q))
                                for nm in _INBOX))
                            for nm, a in zip(_INBOX, triple):
                                store.put_page((nm, gen, q), a,
                                               immutable=True)
                        sort_on_build = True
                    plan = new_plan
                    if plan.join == "left_outer":
                        # refit the frontier to the live set — safe now
                        # that an outgrown refit regrows instead of
                        # aborting
                        act = active // max(P, 1) + 1
                        ec = dataclasses.replace(
                            ec, frontier_cap=min(
                                max(FRONTIER_FLOOR, act * 4), Np + 8))
                    # dropping the sender combine needs room for
                    # uncombined sends: grow the buckets now instead of
                    # paying an overflow-redo on the next superstep
                    need = default_engine_config(shape_vert, program, plan)
                    if need.bucket_cap > ec.bucket_cap:
                        ec = dataclasses.replace(
                            ec, bucket_cap=need.bucket_cap)
                    step = jit_superstep(program, plan, ec,
                                         donate_vertex=True)
                    seen_widths = set()
                    stats.append(coll.event(
                        i, "plan-switch", join=plan.join,
                        groupby=plan.groupby, connector=plan.connector,
                        sender_combine=plan.sender_combine,
                        storage=plan.storage,
                        frontier_cap=ec.frontier_cap).as_dict())
                    m_switches.inc()
                    recompiled = True
                    switched = True
                    controller.note_shape_change()
            # adaptive frontier refit (left-outer plan), mirroring
            # run_host: when the live set collapses, shrink the frontier
            # capacity so each super-partition only pays O(|frontier|)
            if plan.join == "left_outer" and not switched \
                    and not bool(gs.halt):
                act = active // max(P, 1) + 1
                if act * 4 < ec.frontier_cap and ec.frontier_cap > \
                        FRONTIER_FLOOR:
                    ec = dataclasses.replace(
                        ec, frontier_cap=max(FRONTIER_FLOOR, act * 2))
                    step = jit_superstep(program, plan, ec,
                                         donate_vertex=True)
                    seen_widths = set()
                    stats.append(coll.event(
                        i, "frontier-refit",
                        frontier_cap=ec.frontier_cap).as_dict())
                    recompiled = True
                    if controller is not None:
                        controller.note_shape_change()
            if controller is not None and not bool(gs.halt):
                # periodic cost-model re-calibration: after a regrow /
                # refit / switch changed the lowered shapes, refit the
                # analytic constants against the HLO analyzer — at most
                # once per recalibrate_every supersteps (amortizes the
                # probe compiles)
                recal = controller.maybe_recalibrate(program, i)
                if recal is not None:
                    stats.append(coll.event(
                        i, "recalibrate", **recal).as_dict())
            if checkpoint_every and checkpoint_dir \
                    and i % checkpoint_every == 0:
                # checkpoints synchronize the rolling frontier: the
                # saved inbox generation must be complete and every
                # pending mutation applied before the pages export
                t_ck = time.time()
                for q in range(n_sp):
                    prepare(q)
                if store.engine is not None:
                    store.engine.drain()
                save_ooc_checkpoint(
                    checkpoint_dir, i, store, gs, inbox_gen=gen,
                    inbox_width=C_in, sp=sp, plan=plan, ec=ec,
                    controller_state=(controller.state_dict()
                                      if controller is not None else None))
                trace.complete("checkpoint_sync", "checkpoint",
                               t_ck, time.time(), superstep=i)
            if bool(gs.halt):
                break
        # the rolling frontier defers mutation application to each
        # destination's prepare; a run that stops here (max_supersteps,
        # or a halt vote — where the pending applies are no-ops by
        # construction, else the vote would have failed) must land them
        # before the final gather, exactly like run_host's in-step apply
        if cur_has_mut:
            for q in range(n_sp):
                if q not in prepared:
                    _apply_mutation_chunk(store, program, plan, P, sp,
                                          n_sp, gen, q)
        final = VertexRel(**{k: jnp.asarray(store.gather(k))
                             for k in _RELS})
        return RunResult(vertex=final, gs=gs, supersteps=i, stats=stats,
                         wall_s=time.time() - t0, plan=plan)
    finally:
        if store is not None:
            store.close()
