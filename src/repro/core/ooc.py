"""Out-of-core execution (the paper's central claim, Sections 2.3/5.4/7.2).

On Hyracks, operators spill to disk through the buffer cache, so the same
plans run in-memory and out-of-core. The TPU-adapted memory hierarchy is
HBM <-> host DRAM: the Vertex relation lives on the HOST; each superstep
streams SUPER-PARTITIONS (groups of partitions sized to a device-memory
budget) through the jitted partial superstep, collecting outgoing message
buckets host-side (the "sender-side materializing pipelined" policy) and
delivering them at the next superstep.

PIPELINED STREAMING (``stream=True``, the default): the executor keeps up
to ``prefetch_depth`` super-partitions in flight. A DISPATCHER uploads
super-partition s+1's vertex slices and inbox runs with non-blocking
``jax.device_put`` and enqueues its jitted step while s is still
computing; a COLLECTOR consumes completed super-partitions — out of
dispatch order when a later one finishes first — committing each one's
host write-back while the device works on the next. Steady-state wall
time per superstep therefore approaches ``max(compute, transfer)``
instead of their sum (the GraphD/GraphH overlap discipline, arXiv
1601.05590 / 1705.05595). The uploaded vertex block is DONATED to its
updated output (``superstep.jit_superstep``), so a pipeline slot costs
one resident vertex block, not two. ``stream=False`` degenerates to the
synchronous upload -> step -> block -> collect loop (a window of 1).

Because results land asynchronously, the overflow/regrow protocol is
DEFERRED: host state for a super-partition commits only when its result
is collected clean. When a collected result reports overflow, the
collector drains the pipeline — committing in-flight super-partitions
that finished clean, marking overflowed ones for redo — then doubles
ONLY the overflowed capacities (per-source ``GlobalState.overflow``
counters), re-jits, end-pads the already-committed bucket blocks, and
re-dispatches the redo set from retained host state. Float-sensitive
reductions (the user aggregate) are folded in super-partition order at
the superstep barrier, so streaming runs are bit-for-bit identical to
synchronous ones.

The host inbox is RUN-STRUCTURED: the per-super-partition bucket tensors
coming off the device — ``(sp, P, C)`` with valid entries occupying a
PREFIX of every ``(src, dst)`` bucket (``connector.bucket_by_owner``'s
layout contract) — are stacked with one ``np.concatenate`` into
``(P_src, P_dst, C)``, transposed to ``(P_dst, P_src, C)`` (the host-side
analogue of the emulated exchange), and trimmed to the widest occupied
run. No per-message Python iteration anywhere. Because each destination
partition's message block is therefore exactly ``n_parts`` sender runs of
equal width — dst-sorted whenever the sender sorts (merging connector, or
the sender combine's dst-ascending output) — the merging receiver's
run-capacity assumption holds host-side and ``plan="auto"`` searches the
FULL join x group-by x connector x sender-combine x storage space here,
switching any of them with a re-jit at a superstep boundary. Messages
live host-side between supersteps, so the only in-flight migration that
can ever be needed is a one-off dst-sort of each run when a switch
adopts the merging receiver from an unsorted producer
(``_sort_inbox_runs``, mirroring ``planner.adaptive.migrate_msgs``).

storage="delta" (LSM analogue): only CHANGED vertex values are written
back to the host store each superstep instead of the full value array —
the deferred-merge write path, right for sparse-update workloads. Both
policies' write-back bytes are measured every superstep and feed the cost
model's storage dimension (``planner/cost.py`` ``storage_writeback``);
the statistics stream also carries the dispatch / collect-wait / commit
wall-time split and the ``streaming`` flag, so the planner prices plans
with the overlap-aware ``max(step, transfer)`` host-link term when the
pipelined executor is active.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.driver import (PlanArg, RunResult, _resolve_plan,
                               default_engine_config, grow_overflowed)
from repro.core.plan import FRONTIER_FLOOR, STORAGES, PhysicalPlan
from repro.core.program import VertexProgram
from repro.core.relations import GlobalState, MsgRel, VertexRel, init_gs
from repro.core.superstep import EngineConfig, jit_superstep

# the OOC planner searches both storage policies on top of the full
# per-superstep space (in-memory drivers inherit the base plan's storage:
# they never pay a write-back, so the dimension would only produce ties)
_OOC_AUTO_SPACE = {"storages": STORAGES}


@dataclasses.dataclass
class _InFlight:
    """One dispatched, uncollected super-partition (async device refs)."""
    s: int
    v2: VertexRel
    buckets: MsgRel
    g2: GlobalState


@dataclasses.dataclass
class _Done:
    """One committed super-partition (host-side results)."""
    block: tuple          # (dst, payload, valid) sender buckets, np
    halt_ok: bool
    active: int
    agg: np.ndarray
    delta_bytes: int
    full_bytes: int


def _empty_inbox(P: int, D: int):
    """Run-structured empty inbox: one invalid slot per (dst, src) run."""
    return (np.full((P, P, 1), -1, np.int32),
            np.zeros((P, P, 1, D), np.float32),
            np.zeros((P, P, 1), bool))


def _round_run_width(max_count: int, cap: int) -> int:
    """Trim width for the inbox runs: next power of two >= the widest
    occupied run, clamped to [1, bucket_cap]. Power-of-two rounding keeps
    the set of distinct jitted message shapes logarithmic in cap, so the
    jit cache amortizes across supersteps as the frontier breathes."""
    w = 1
    while w < max_count:
        w *= 2
    return max(1, min(w, cap))


def _sort_inbox_runs(inbox):
    """Sort every (dst, src) run of the host inbox by dst — the host-side
    mirror of ``planner.adaptive.migrate_msgs`` for a mid-run switch onto
    the merging connector when the previous plan produced UNSORTED runs
    (plain partitioning without a sender combine). Invalid slots key as
    int32 max, so the stable sort keeps valid entries a run prefix."""
    d, p, v = inbox
    key = np.where(v, d, np.iinfo(np.int32).max)
    order = np.argsort(key, axis=2, kind="stable")
    return (np.take_along_axis(d, order, axis=2),
            np.take_along_axis(p, order[..., None], axis=2),
            np.take_along_axis(v, order, axis=2))


def _pad_run_width(block, C_new: int):
    """End-pad a collected (sp, P, C_old) bucket block to C_old=C_new.
    Valid entries occupy a prefix per bucket, so end-padding with invalid
    slots preserves the run layout (cf. driver._regrow_msgs)."""
    d, p, v = block
    pad = C_new - d.shape[2]
    if pad <= 0:
        return block
    return (np.pad(d, ((0, 0), (0, 0), (0, pad)), constant_values=-1),
            np.pad(p, ((0, 0), (0, 0), (0, pad), (0, 0))),
            np.pad(v, ((0, 0), (0, 0), (0, pad))))


def run_out_of_core(vert: VertexRel, program: VertexProgram,
                    plan: PlanArg = PhysicalPlan(), *,
                    budget_partitions: int,
                    max_supersteps: int = 50,
                    ec: Optional[EngineConfig] = None,
                    auto_config=None,
                    auto_space: Optional[dict] = None,
                    stream: bool = True,
                    prefetch_depth: int = 2) -> RunResult:
    """budget_partitions = how many partitions fit in device memory at once
    (the HBM budget). P % budget_partitions must be 0. plan="auto" picks
    the plan from the cost model and re-picks it at superstep boundaries —
    over the FULL plan space including connector and storage (messages
    live host-side between supersteps in run-structured buffers, so any
    switch is just a re-jit — no in-flight layout migration).

    stream=True (default) pipelines the super-partition stream: up to
    ``prefetch_depth`` super-partitions are in flight at once, hiding
    host<->device transfer behind compute; stream=False is the
    synchronous loop (a pipeline window of 1). Results are bit-for-bit
    identical either way."""
    from repro.planner.stats import StatsCollector

    t0 = time.time()
    P, Np = vert.vid.shape
    assert P % budget_partitions == 0
    n_sp = P // budget_partitions
    sp = budget_partitions
    window = max(int(prefetch_depth), 1) if stream else 1
    plan, controller = _resolve_plan(
        vert, program, plan, adaptive=True, ec=ec, auto_config=auto_config,
        auto_space=_OOC_AUTO_SPACE if auto_space is None else auto_space)
    ec = ec or default_engine_config(vert, program, plan)
    # resolve frontier_cap=0 (the EngineConfig "Np/2" default) to its
    # concrete value up front: the overflow regrow path doubles it, and
    # 0 * 2 = 0 would re-jit the identical config forever
    ec = dataclasses.replace(ec, ooc_collect=True,
                             frontier_cap=ec.frontier_cap or
                             max(Np // 2, 1))
    step = jit_superstep(program, plan, ec, donate_vertex=True)
    seen_widths = set()   # inbox widths this `step` has already traced

    # host-resident state (the "disk")
    host = {k: np.array(getattr(vert, k)) for k in
            ("vid", "halt", "value", "edge_src", "edge_dst", "edge_val")}
    gs = init_gs(program.agg_dims)
    # init values on device per super-partition (streams once)
    from repro.core.driver import init_vertex_values
    for s in range(n_sp):
        sl = slice(s * sp, (s + 1) * sp)
        vpart = VertexRel(**{k: jnp.asarray(host[k][sl]) for k in host})
        vpart = init_vertex_values(vpart, program, gs)
        host["value"][sl] = np.asarray(vpart.value)

    D = program.msg_dims
    # run-structured host inbox: dst (P_dst, P_src, C), payload, valid —
    # row q holds P source runs, exactly the layout the receiver group-by
    # sees in-memory after the exchange
    inbox = _empty_inbox(P, D)
    n_live = (controller.g.n_vertices if controller is not None
              else int((host["vid"] >= 0).sum()))
    coll = StatsCollector(n_partitions=P, vertex_capacity=Np, msg_dims=D,
                          n_vertices=n_live)
    stats = []
    i = 0
    delta_bytes = full_bytes = 0
    recompiled = True  # first superstep includes the jit compile
    while i < max_supersteps:
        ts = time.time()
        this_recompiled = recompiled
        recompiled = False
        in_dst, in_pay, in_val = inbox
        C_in = in_dst.shape[2]
        if C_in not in seen_widths:
            # a new message width retraces inside jit: this superstep's
            # wall time includes a compile
            seen_widths.add(C_in)
            this_recompiled = True
        ovf0 = np.asarray(gs.overflow)
        t_io = {"dispatch": 0.0, "wait": 0.0, "commit": 0.0}
        committed = {}                # s -> _Done
        todo = deque(range(n_sp))     # dispatch queue (redo re-enters it)
        pending = []                  # _InFlight, dispatch order

        def dispatch(s):
            """Non-blocking H2D upload + step enqueue for one
            super-partition: the device starts (or queues) the work while
            the host moves on to collect an earlier one."""
            td = time.time()
            sl = slice(s * sp, (s + 1) * sp)
            vpart = VertexRel(**{k: jax.device_put(host[k][sl])
                                 for k in host})
            # incoming block: slice the run-structured inbox and flatten
            # the (P_src, C_in) runs — already the receiver's layout
            msg = MsgRel(
                dst=jax.device_put(in_dst[sl].reshape(sp, P * C_in)),
                payload=jax.device_put(
                    in_pay[sl].reshape(sp, P * C_in, D)),
                valid=jax.device_put(in_val[sl].reshape(sp, P * C_in)))
            v2, buckets, g2 = step(vpart, msg, gs)
            t_io["dispatch"] += time.time() - td
            return _InFlight(s, v2, buckets, g2)

        def commit(e):
            """Drain one clean super-partition D2H and commit its host
            state (delta vs full write-back policy; both byte counts are
            measured every superstep to feed the cost model's storage
            dimension). Blocking on the value pull is the pipeline's
            compute-wait; everything after is host-side commit time."""
            tw = time.time()
            new_value = np.asarray(e.v2.value)   # blocks on e's step
            t_io["wait"] += time.time() - tw
            tc = time.time()
            sl = slice(e.s * sp, (e.s + 1) * sp)
            changed = np.any(new_value != host["value"][sl], axis=-1)
            d_b = int(changed.sum()) * new_value.shape[-1] * 4
            f_b = new_value.size * 4
            if plan.storage == "delta":
                host["value"][sl][changed] = new_value[changed]
            else:
                host["value"][sl] = new_value
            host["halt"][sl] = np.asarray(e.v2.halt)
            host["vid"][sl] = np.asarray(e.v2.vid)
            host["edge_dst"][sl] = np.asarray(e.v2.edge_dst)
            host["edge_val"][sl] = np.asarray(e.v2.edge_val)
            done = _Done(
                block=(np.asarray(e.buckets.dst),
                       np.asarray(e.buckets.payload),
                       np.asarray(e.buckets.valid)),
                halt_ok=bool(np.all(host["halt"][sl] |
                                    (host["vid"][sl] < 0))),
                active=int(e.g2.active_count),
                agg=np.asarray(e.g2.aggregate),
                delta_bytes=d_b, full_bytes=f_b)
            t_io["commit"] += time.time() - tc
            return done

        while todo or pending:
            # fill the pipeline window
            while todo and len(pending) < window:
                pending.append(dispatch(todo.popleft()))
            # collect a completed super-partition — out of dispatch order
            # when a later one is already done — else block on the oldest
            j = 0
            if len(pending) > 1:
                j = next((k for k, e in enumerate(pending)
                          if e.g2.overflow.is_ready()), 0)
            e = pending.pop(j)
            delta = np.asarray(e.g2.overflow) - ovf0    # blocks on e
            if (delta > 0).any():
                # DEFERRED OVERFLOW: a bucket / frontier / mutation /
                # edge capacity overflowed mid-pipeline. Unwind the
                # in-flight prefetch: drain every pending result,
                # committing the ones that finished clean and marking
                # overflowed ones for redo; then double ONLY the
                # overflowed capacities, re-jit, end-pad the committed
                # blocks and redo from retained host state (nothing from
                # a dirty step was committed).
                redo = {e.s}
                for other in pending:
                    od = np.asarray(other.g2.overflow) - ovf0
                    if (od > 0).any():
                        delta = delta + od
                        redo.add(other.s)
                    else:
                        committed[other.s] = commit(other)
                pending = []
                ec = grow_overflowed(ec, delta)
                step = jit_superstep(program, plan, ec, donate_vertex=True)
                seen_widths = {C_in}
                for s2, done in committed.items():
                    committed[s2] = dataclasses.replace(
                        done, block=_pad_run_width(done.block,
                                                   ec.bucket_cap))
                todo = deque(sorted(redo | set(todo)))
                stats.append(coll.event(
                    i, "regrow", bucket_cap=ec.bucket_cap,
                    frontier_cap=ec.frontier_cap,
                    mutation_cap=ec.mutation_cap,
                    sources=np.flatnonzero(delta > 0).tolist(),
                    redo=sorted(redo)).as_dict())
                this_recompiled = True
                continue
            committed[e.s] = commit(e)

        # superstep barrier: fold the per-super-partition results in
        # super-partition order (float aggregate order must not depend on
        # pipeline completion order — bit-for-bit vs the synchronous loop)
        ordered = [committed[s] for s in range(n_sp)]
        halt_all = all(d.halt_ok for d in ordered)
        active = sum(d.active for d in ordered)
        agg = np.zeros((program.agg_dims,), np.float32)
        for d in ordered:
            agg += d.agg
        step_delta = sum(d.delta_bytes for d in ordered)
        step_full = sum(d.full_bytes for d in ordered)
        out_blocks = [d.block for d in ordered]
        delta_bytes += step_delta
        full_bytes += step_full
        # vectorized inbox rebuild: stack the (sp, P, C) blocks into
        # (P_src, P_dst, C), transpose to destination-major (the host-side
        # emulated exchange) and trim every run to the widest occupancy —
        # valid entries are a bucket PREFIX, so the trim drops only
        # invalid tail slots
        b_dst = np.concatenate([b[0] for b in out_blocks], axis=0)
        b_pay = np.concatenate([b[1] for b in out_blocks], axis=0)
        b_val = np.concatenate([b[2] for b in out_blocks], axis=0)
        counts = b_val.sum(axis=2)
        msg_count = int(counts.sum())
        C_eff = _round_run_width(int(counts.max(initial=0)), ec.bucket_cap)
        inbox = (
            np.ascontiguousarray(b_dst.transpose(1, 0, 2)[:, :, :C_eff]),
            np.ascontiguousarray(
                b_pay.transpose(1, 0, 2, 3)[:, :, :C_eff]),
            np.ascontiguousarray(b_val.transpose(1, 0, 2)[:, :, :C_eff]))
        i += 1
        gs = GlobalState(halt=jnp.asarray(halt_all and msg_count == 0),
                         aggregate=jnp.asarray(agg),
                         superstep=jnp.asarray(i, jnp.int32),
                         overflow=gs.overflow,
                         active_count=jnp.asarray(active, jnp.int32),
                         msg_count=jnp.asarray(msg_count, jnp.int32))
        rec = coll.record(i, active=active, messages=msg_count,
                          wall_s=time.time() - ts,
                          recompiled=this_recompiled,
                          delta_bytes=delta_bytes, full_bytes=full_bytes,
                          change_density=step_delta / max(step_full, 1),
                          storage=plan.storage, ooc=True,
                          streaming=stream,
                          dispatch_s=t_io["dispatch"],
                          collect_wait_s=t_io["wait"],
                          commit_s=t_io["commit"])
        stats.append(rec.as_dict())
        switched = False
        if controller is not None and not bool(gs.halt):
            new_plan = controller.observe(rec, bucket_cap=ec.bucket_cap)
            if new_plan is not None:
                if (new_plan.connector == "partitioning_merging"
                        and plan.connector != "partitioning_merging"
                        and not plan.sender_combine):
                    # the old plan left runs unsorted; give the merging
                    # receiver its dst-sorted runs (one-off, host-side —
                    # the OOC analogue of migrate_msgs)
                    inbox = _sort_inbox_runs(inbox)
                plan = new_plan
                if plan.join == "left_outer":
                    # refit the frontier to the live set — safe now that
                    # an outgrown refit regrows instead of aborting
                    act = active // max(P, 1) + 1
                    ec = dataclasses.replace(
                        ec, frontier_cap=min(max(FRONTIER_FLOOR, act * 4),
                                             Np + 8))
                # dropping the sender combine needs room for uncombined
                # sends: grow the buckets now instead of paying an
                # overflow-redo on the next superstep
                need = default_engine_config(vert, program, plan)
                if need.bucket_cap > ec.bucket_cap:
                    ec = dataclasses.replace(ec,
                                             bucket_cap=need.bucket_cap)
                step = jit_superstep(program, plan, ec, donate_vertex=True)
                seen_widths = set()
                stats.append(coll.event(
                    i, "plan-switch", join=plan.join,
                    groupby=plan.groupby, connector=plan.connector,
                    sender_combine=plan.sender_combine,
                    storage=plan.storage,
                    frontier_cap=ec.frontier_cap).as_dict())
                recompiled = True
                switched = True
        # adaptive frontier refit (left-outer plan), mirroring run_host:
        # when the live set collapses, shrink the frontier capacity so
        # each super-partition only pays O(|frontier|)
        if plan.join == "left_outer" and not switched and not bool(gs.halt):
            act = active // max(P, 1) + 1
            if act * 4 < ec.frontier_cap and ec.frontier_cap > \
                    FRONTIER_FLOOR:
                ec = dataclasses.replace(
                    ec, frontier_cap=max(FRONTIER_FLOOR, act * 2))
                step = jit_superstep(program, plan, ec, donate_vertex=True)
                seen_widths = set()
                stats.append(coll.event(
                    i, "frontier-refit",
                    frontier_cap=ec.frontier_cap).as_dict())
                recompiled = True
        if bool(gs.halt):
            break
    final = VertexRel(**{k: jnp.asarray(host[k]) for k in host})
    return RunResult(vertex=final, gs=gs, supersteps=i, stats=stats,
                     wall_s=time.time() - t0, plan=plan)
