"""Out-of-core execution (the paper's central claim, Sections 2.3/5.4/7.2).

On Hyracks, operators spill to disk through the buffer cache, so the same
plans run in-memory and out-of-core. The TPU-adapted memory hierarchy is
HBM <-> host DRAM: the Vertex relation lives on the HOST; each superstep
streams SUPER-PARTITIONS (groups of partitions sized to a device-memory
budget) through the jitted partial superstep, collecting outgoing message
buckets host-side (the "sender-side materializing pipelined" policy) and
delivering them at the next superstep.

storage="delta" (LSM analogue): only CHANGED vertex values are shipped
back to the host each superstep instead of the full value array — the
deferred-merge write path, right for sparse-update workloads.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.driver import (PlanArg, RunResult, _resolve_plan,
                               default_engine_config)
from repro.core.plan import PhysicalPlan
from repro.core.program import VertexProgram
from repro.core.relations import GlobalState, MsgRel, VertexRel, init_gs
from repro.core.superstep import EngineConfig, make_superstep

# the merging connector's receiver needs run-structured message capacity;
# the OOC inbox re-packs messages into arbitrary-width blocks, so the
# auto-planner only searches the plain partitioning connector here
_OOC_PLAN_SPACE = {"connectors": ("partitioning",)}


def run_out_of_core(vert: VertexRel, program: VertexProgram,
                    plan: PlanArg = PhysicalPlan(), *,
                    budget_partitions: int,
                    max_supersteps: int = 50,
                    ec: Optional[EngineConfig] = None,
                    auto_config=None) -> RunResult:
    """budget_partitions = how many partitions fit in device memory at once
    (the HBM budget). P % budget_partitions must be 0. plan="auto" picks
    the plan from the cost model and re-picks it at superstep boundaries
    (messages live host-side between supersteps, so a switch is just a
    re-jit — no in-flight layout migration)."""
    from repro.planner.stats import StatsCollector

    t0 = time.time()
    P, Np = vert.vid.shape
    assert P % budget_partitions == 0
    n_sp = P // budget_partitions
    sp = budget_partitions
    plan, controller = _resolve_plan(vert, program, plan, adaptive=True,
                                     ec=ec, auto_config=auto_config,
                                     auto_space=_OOC_PLAN_SPACE)
    ec = ec or default_engine_config(vert, program, plan)
    ec = dataclasses.replace(ec, ooc_collect=True)
    step = jax.jit(make_superstep(program, plan, ec))

    # host-resident state (the "disk")
    host = {k: np.array(getattr(vert, k)) for k in
            ("vid", "halt", "value", "edge_src", "edge_dst", "edge_val")}
    gs = init_gs(program.agg_dims)
    # init values on device per super-partition (streams once)
    from repro.core.driver import init_vertex_values
    for s in range(n_sp):
        sl = slice(s * sp, (s + 1) * sp)
        vpart = VertexRel(**{k: jnp.asarray(host[k][sl]) for k in host})
        vpart = init_vertex_values(vpart, program, gs)
        host["value"][sl] = np.asarray(vpart.value)

    D = program.msg_dims
    C = ec.bucket_cap
    # per-destination-partition host message queues
    inbox = [[] for _ in range(P)]
    n_live = (controller.g.n_vertices if controller is not None
              else int((host["vid"] >= 0).sum()))
    coll = StatsCollector(n_partitions=P, vertex_capacity=Np, msg_dims=D,
                          n_vertices=n_live)
    stats = []
    i = 0
    delta_bytes = full_bytes = 0
    while i < max_supersteps:
        ts = time.time()
        M_in = max(max((sum(len(a[0]) for a in inbox[q])
                        for q in range(P)), default=1), 1)
        new_inbox = [[] for _ in range(P)]
        halt_all = True
        msg_count = 0
        overflow = 0
        active = 0
        agg = np.zeros((program.agg_dims,), np.float32)
        for s in range(n_sp):
            sl = slice(s * sp, (s + 1) * sp)
            vpart = VertexRel(**{k: jnp.asarray(host[k][sl]) for k in host})
            # build padded incoming message block for these partitions
            md = np.full((sp, M_in), -1, np.int32)
            mp = np.zeros((sp, M_in, D), np.float32)
            mv = np.zeros((sp, M_in), bool)
            for j in range(sp):
                q = s * sp + j
                pos = 0
                for d_arr, p_arr in inbox[q]:
                    c = len(d_arr)
                    md[j, pos:pos + c] = d_arr
                    mp[j, pos:pos + c] = p_arr
                    mv[j, pos:pos + c] = True
                    pos += c
            msg = MsgRel(dst=jnp.asarray(md), payload=jnp.asarray(mp),
                         valid=jnp.asarray(mv))
            old_value = host["value"][sl].copy()
            v2, buckets, g2 = step(vpart, msg, gs)
            jax.block_until_ready(g2.superstep)
            # write back vertex state (delta vs full storage policy)
            new_value = np.asarray(v2.value)
            if plan.storage == "delta":
                changed = np.any(new_value != old_value, axis=-1)
                host["value"][sl][changed] = new_value[changed]
                delta_bytes += int(changed.sum()) * new_value.shape[-1] * 4
            else:
                host["value"][sl] = new_value
                full_bytes += new_value.size * 4
            host["halt"][sl] = np.asarray(v2.halt)
            host["vid"][sl] = np.asarray(v2.vid)
            host["edge_dst"][sl] = np.asarray(v2.edge_dst)
            host["edge_val"][sl] = np.asarray(v2.edge_val)
            # collect outgoing buckets into destination inboxes
            b_dst = np.asarray(buckets.dst)      # (sp, P, C)
            b_pay = np.asarray(buckets.payload)  # (sp, P, C, D)
            b_val = np.asarray(buckets.valid)
            for j in range(sp):
                for q in range(P):
                    ok = b_val[j, q]
                    if ok.any():
                        new_inbox[q].append((b_dst[j, q][ok],
                                             b_pay[j, q][ok]))
            halt_all &= bool(np.all(np.asarray(v2.halt) |
                                    (np.asarray(v2.vid) < 0)))
            msg_count += int(np.asarray(buckets.valid).sum())
            overflow += int(g2.overflow) - int(gs.overflow)
            active += int(g2.active_count)
            agg += np.asarray(g2.aggregate)
        if overflow:
            raise RuntimeError("OOC bucket overflow; raise bucket_cap")
        inbox = new_inbox
        i += 1
        gs = GlobalState(halt=jnp.asarray(halt_all and msg_count == 0),
                         aggregate=jnp.asarray(agg),
                         superstep=jnp.asarray(i, jnp.int32),
                         overflow=gs.overflow,
                         active_count=jnp.asarray(active, jnp.int32),
                         msg_count=jnp.asarray(msg_count, jnp.int32))
        rec = coll.record(i, active=active, messages=msg_count,
                          wall_s=time.time() - ts,
                          delta_bytes=delta_bytes, full_bytes=full_bytes)
        stats.append(rec.as_dict())
        if controller is not None and not bool(gs.halt):
            new_plan = controller.observe(rec, bucket_cap=ec.bucket_cap)
            if new_plan is not None:
                # keep the full frontier capacity: OOC has no overflow
                # regrow path, so a refit that the frontier later outgrows
                # would abort the run (ROADMAP open item). Bucket capacity
                # CAN only grow here — dropping the sender combine needs
                # room for uncombined sends, and inter-superstep messages
                # live host-side so a re-jit is all it takes.
                plan = new_plan
                need = default_engine_config(vert, program, plan)
                if need.bucket_cap > ec.bucket_cap:
                    ec = dataclasses.replace(ec,
                                             bucket_cap=need.bucket_cap)
                step = jax.jit(make_superstep(program, plan, ec))
                stats.append(coll.event(
                    i, "plan-switch", join=plan.join,
                    groupby=plan.groupby, connector=plan.connector,
                    sender_combine=plan.sender_combine,
                    frontier_cap=ec.frontier_cap).as_dict())
        if bool(gs.halt):
            break
    final = VertexRel(**{k: jnp.asarray(host[k]) for k in host})
    return RunResult(vertex=final, gs=gs, supersteps=i, stats=stats,
                     wall_s=time.time() - t0, plan=plan)
