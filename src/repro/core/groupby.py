"""Group-by operators (paper Section 5.3.1, Figure 7).

All functions operate per partition and are vmapped over the leading P axis
by the superstep. Two families:

* scatter  — hash group-by analogue: monoid scatter straight into dense
             vid-slot-aligned buffers (named ops only).
* sort     — sort-based group-by: argsort by key + segmented fold via
             ``lax.associative_scan`` (supports arbitrary associative
             combine UDFs, like the paper's combine).
* run-combine — one-pass combine of presorted runs (the receiver side of
             the m-to-n partitioning MERGING connector: "preclustered").

The monoid table mirrors Hyracks' aggregate library.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

MONOIDS = {
    "sum": (lambda a, b: a + b, 0.0),
    "min": (jnp.minimum, jnp.inf),
    "max": (jnp.maximum, -jnp.inf),
}


def compact(mask: jax.Array, cap: int):
    """O(N) stream compaction: indices of True entries, -1 padded.
    Returns (idx (cap,), count, overflow)."""
    n = mask.shape[0]
    pos = jnp.cumsum(mask) - 1
    count = jnp.sum(mask)
    idx = jnp.full((cap,), -1, jnp.int32)
    idx = idx.at[jnp.where(mask, pos, cap)].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    return idx, jnp.minimum(count, cap), jnp.maximum(count - cap, 0)


# ---------------------------------------------------------------------------
# scatter (hash) group-by -> dense slots
# ---------------------------------------------------------------------------


def scatter_combine_dense(slot, payload, valid, Np: int, op: str):
    """slot: (M,) int32; payload: (M,D); -> (dense (Np,D), has_msg (Np,))."""
    fn, ident = MONOIDS[op]
    D = payload.shape[-1]
    tgt = jnp.where(valid, slot, Np)
    dense = jnp.full((Np, D), ident, payload.dtype)
    upd = jnp.where(valid[:, None], payload,
                    jnp.full_like(payload, ident))
    if op == "sum":
        dense = dense.at[tgt].add(upd, mode="drop")
    elif op == "min":
        dense = dense.at[tgt].min(upd, mode="drop")
    else:
        dense = dense.at[tgt].max(upd, mode="drop")
    has = jnp.zeros((Np,), bool).at[tgt].max(valid, mode="drop")
    return dense, has


# ---------------------------------------------------------------------------
# sort-based group-by -> compact unique (slot, payload) runs
# ---------------------------------------------------------------------------


def _segmented_fold(flags, vals, combine):
    """Inclusive segmented fold: flags mark segment starts."""
    def op(a, b):
        fa, va = a
        fb, vb = b
        return (fa | fb,
                jnp.where(fb[..., None] if vb.ndim > fb.ndim else fb,
                          vb, combine(va, vb)))
    f, v = jax.lax.associative_scan(op, (flags, vals))
    return v


def sort_combine(slot, payload, valid, combine: Callable, identity):
    """Sort by slot and fold each run. Returns (sorted_slot (M,),
    folded (M,D), is_last (M,)) where is_last marks one entry per group."""
    M = slot.shape[0]
    key = jnp.where(valid, slot, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(key)
    ks = key[order]
    ps = payload[order]
    vs = valid[order]
    starts = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    folded = _segmented_fold(starts, ps, combine)
    is_last = jnp.concatenate([ks[1:] != ks[:-1], jnp.ones((1,), bool)])
    return ks, folded, is_last & vs


def sort_combine_dense(slot, payload, valid, Np: int, combine, identity):
    """Sort group-by materialized to dense slots (full-outer join input)."""
    ks, folded, is_last = sort_combine(slot, payload, valid, combine,
                                       identity)
    D = payload.shape[-1]
    tgt = jnp.where(is_last & (ks < Np), ks, Np)
    dense = jnp.broadcast_to(identity, (Np, D)).astype(payload.dtype)
    dense = dense.at[tgt].set(folded, mode="drop")
    has = jnp.zeros((Np,), bool).at[tgt].max(is_last, mode="drop")
    return dense, has


# ---------------------------------------------------------------------------
# run-combine (receiver of the merging connector): input is R presorted
# runs of length C; one segmented pass per run, then <=R partials per slot
# are scatter-combined (strictly cheaper than a fresh full sort).
# ---------------------------------------------------------------------------


def run_combine_dense(slot_runs, payload_runs, valid_runs, Np: int,
                      op: str):
    """slot_runs: (R, C); payload_runs: (R, C, D)."""
    fn, ident = MONOIDS[op]
    R, C = slot_runs.shape

    def per_run(slot, pay, val):
        key = jnp.where(val, slot, jnp.iinfo(jnp.int32).max)
        starts = jnp.concatenate([jnp.ones((1,), bool),
                                  key[1:] != key[:-1]])
        folded = _segmented_fold(starts, pay, lambda a, b: fn(a, b))
        is_last = jnp.concatenate([key[1:] != key[:-1],
                                   jnp.ones((1,), bool)]) & val
        return key, folded, is_last

    keys, folded, lasts = jax.vmap(per_run)(slot_runs, payload_runs,
                                            valid_runs)
    return scatter_combine_dense(keys.reshape(-1),
                                 folded.reshape(R * C, -1),
                                 lasts.reshape(-1), Np, op)
