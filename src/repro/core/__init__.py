"""Pregelix core: the paper's contribution — Pregel semantics as an
iterative dataflow of relational operators (join + group-by + connectors)
with physical plan flexibility."""
from repro.core.driver import (RunResult, default_engine_config, run_host,
                               run_jit)
from repro.core.plan import (DEFAULT_PLAN, SPARSE_PLAN, STORAGES,
                             PhysicalPlan)
from repro.core.program import ComputeOut, VertexProgram
from repro.core.relations import (N_OVERFLOW, OVF_BUCKET, OVF_EDGE,
                                  OVF_FRONTIER, OVF_MUTATION, GlobalState,
                                  MsgRel, VertexRel, empty_msgs,
                                  gather_values, init_gs, load_graph,
                                  out_degrees)
from repro.core.sharded import ExchangeReadiness, run_sharded
from repro.core.superstep import EngineConfig, jit_superstep, make_superstep

__all__ = [
    "RunResult", "default_engine_config", "run_host", "run_jit",
    "run_sharded", "ExchangeReadiness",
    "DEFAULT_PLAN", "SPARSE_PLAN", "STORAGES", "PhysicalPlan", "ComputeOut",
    "VertexProgram", "GlobalState", "MsgRel", "VertexRel", "empty_msgs",
    "gather_values", "init_gs", "load_graph", "out_degrees",
    "N_OVERFLOW", "OVF_BUCKET", "OVF_FRONTIER", "OVF_MUTATION", "OVF_EDGE",
    "EngineConfig", "jit_superstep", "make_superstep",
]
