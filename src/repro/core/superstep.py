"""One Pregel superstep as a single JAX dataflow (paper Figures 3/4/5).

    Msg_i --[receiver group-by + combine]--> combined payloads
    Vertex_i --[join: full-outer dense | left-outer frontier]--> compute in
    compute UDF --> value'/halt'/sends/aggregate/mutations
    sends --[optional sender combine]--[bucket]--[connector]--> Msg_{i+1}
    aggregates --[two-stage reduction]--> GS_{i+1}
    mutations --[bucket + resolve]--> Vertex_{i+1}

The same function runs in two transports: 'emulated' (partitions stacked on
the leading axis, exchange = transpose — single host) and 'shard_map'
(jax.lax.all_to_all over mesh axes — the production multi-pod path).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import connector, groupby
from repro.core.plan import PhysicalPlan
from repro.core.program import ComputeOut, VertexProgram
from repro.core.relations import GlobalState, MsgRel, VertexRel
from repro.kernels import backend as kbackend


@dataclass(frozen=True)
class EngineConfig:
    n_parts: int                 # total partitions (= mesh size in prod)
    bucket_cap: int              # per (src,dst)-partition bucket capacity
    mutation_cap: int = 64       # insert-proposal bucket capacity
    frontier_cap: int = 0        # left-outer frontier capacity (0 = Np/2)
    axis_name: Optional[tuple] = None   # shard_map axes, None = emulated
    # out-of-core: return the (P_local, n_parts, C) sender buckets to the
    # host instead of exchanging — the OOC driver performs the exchange as
    # a host-side transpose into its run-structured inbox (core/ooc.py)
    ooc_collect: bool = False
    # sharded driver: keep the MESSAGE leg collected (the superstep's
    # ``new_msg`` carries the pre-exchange (P_local, n_parts, C) buckets)
    # so the driver can run the all_to_all as a SEPARATE jitted stage —
    # timed as an ``exchange`` span that feeds the planner's network
    # axis. Mutations still exchange in-device (core/sharded.py).
    exchange_apart: bool = False


def _combine_fns(program: VertexProgram):
    if program.combine_op == "custom":
        return program.combine, program.combine_identity()
    fn, ident = groupby.MONOIDS[program.combine_op]
    return fn, jnp.full((program.msg_dims,), ident, jnp.float32)


def compact_combined(dst, payload, valid, capc: int):
    """Fused combine -> exchange-pack leg: compact each partition's
    combined survivors (one row per distinct destination, dst still
    ascending) down to the ``capc`` rows the buckets can actually accept,
    so the bucket build never re-materializes (or re-sorts) the full
    (P, Ep, C) edge-payload relation. Order-preserving, so the
    ``presorted`` bucket contract holds on the compacted stream; rows
    beyond capc are counted as bucket overflow (``capc >= n_parts *
    bucket_cap``, so any such row would have overflowed its bucket
    anyway — the drivers' regrow protocol fires identically with or
    without the fusion)."""
    def per_part(d, p, v):
        idx, _, ovf = groupby.compact(v, capc)
        ok = idx >= 0
        take = idx.clip(0)
        return (jnp.where(ok, d[take], -1),
                jnp.where(ok[:, None], p[take], 0.0),
                ok, ovf)
    d2, p2, v2, ovf = jax.vmap(per_part)(dst, payload, valid)
    return d2, p2, v2, jnp.sum(ovf)


def make_superstep(program: VertexProgram, plan: PhysicalPlan,
                   ec: EngineConfig):
    plan.validate(program.combine_op)
    n_parts = ec.n_parts
    comb_fn, comb_ident = _combine_fns(program)

    # ---- hot-path kernel dispatch (kernels/backend.py)
    impl_r = kbackend.resolve(plan.kernel_impl)
    named_comb = program.combine_op != "custom"
    # csr_spmv gather: full_outer only — left_outer compacts the edge
    # stream data-dependently, which the host-planned tiling can't follow
    kernel_gather = impl_r != "ref" and plan.join == "full_outer"
    # fuse combine -> exchange-pack on the kernel path (clean ref/pallas
    # HLO A/B: the ref path keeps the seed's unfused lowering)
    fuse_pack = impl_r != "ref" and plan.sender_combine and named_comb

    # ---- transport-dependent reductions
    if ec.axis_name is None:
        red_sum = lambda x: jnp.sum(x)
        red_all = lambda x: jnp.all(x)
        exchange = connector.exchange_emulated
    else:
        red_sum = lambda x: jax.lax.psum(jnp.sum(x), ec.axis_name)
        red_all = lambda x: jnp.logical_not(
            jax.lax.pmax(jnp.logical_not(jnp.all(x)).astype(jnp.int32),
                         ec.axis_name) > 0)
        exchange = partial(connector.exchange_shard_map,
                           axis_name=ec.axis_name)

    def _slot_of(dst, valid, Np):
        if plan.partition == "range":
            owner = jnp.minimum(dst // Np, n_parts - 1)
            return jnp.where(valid, dst - owner * Np, Np)
        return jnp.where(valid, dst // n_parts, Np)

    def receiver_groupby(msg: MsgRel, Np: int):
        # run-capacity assumption: msg.capacity = n_parts equal-width
        # sender runs. Both the in-memory exchange (fixed C buckets) and
        # the out-of-core inbox (trimmed host runs) deliver this layout.
        slot = _slot_of(msg.dst, msg.valid, Np)

        if plan.connector == "partitioning_merging":
            # buckets arrived dst-sorted per source run: one-pass combine
            C = msg.capacity // n_parts
            f = lambda s, p, v: groupby.run_combine_dense(
                s.reshape(n_parts, C), p.reshape(n_parts, C, -1),
                v.reshape(n_parts, C), Np, program.combine_op
                if program.combine_op != "custom" else "sum")
            if program.combine_op == "custom":
                f = lambda s, p, v: groupby.sort_combine_dense(
                    s, p, v, Np, comb_fn, comb_ident)
        elif plan.groupby == "sort":
            f = lambda s, p, v: groupby.sort_combine_dense(
                s, p, v, Np, comb_fn, comb_ident)
        else:
            f = lambda s, p, v: groupby.scatter_combine_dense(
                s, p, v, Np, program.combine_op)
        return jax.vmap(f)(slot, msg.payload, msg.valid)

    def _part_ids(P_local: int, part0=None):
        if ec.axis_name is None:
            ids = jnp.arange(P_local, dtype=jnp.int32)
            if part0 is not None:
                # out-of-core: the resident block holds GLOBAL partitions
                # part0..part0+P_local-1, not 0..P_local-1
                ids = ids + part0
            return ids[:, None]
        # shard_map: worker w owns the CONTIGUOUS global partitions
        # [w * (n_parts // n_shards), ...) — the tiled all_to_all
        # chunking of the bucket axis (connector.exchange_shard_map).
        # ``part0`` (OOC sharded) offsets into the worker's own block:
        # the resident rows are global partitions w*P_w + part0 + p.
        idx = jnp.zeros((), jnp.int32)
        n_shards = 1
        for a in ec.axis_name:
            # psum of a static 1 folds to the static axis size (0.4.x
            # has no jax.lax.axis_size)
            sz = jax.lax.psum(1, a)
            idx = idx * sz + jax.lax.axis_index(a)
            n_shards *= sz
        ids = idx * (n_parts // n_shards) + \
            jnp.arange(P_local, dtype=jnp.int32)
        if part0 is not None:
            ids = ids + part0
        return ids[:, None]

    def resurrect(vert: VertexRel, has_msg, part0):
        """Paper Fig. 2 left-outer case: a message to a non-existent vid
        CREATES the vertex (fields NULL). Slot s of partition p holds vid
        s * n_parts + p, so the vid is recoverable from the address."""
        P_local, Np = vert.vid.shape
        make = has_msg & (vert.vid < 0)
        if plan.partition == "range":
            slot_vid = (jnp.arange(Np, dtype=jnp.int32)[None, :] +
                        _part_ids(P_local, part0) * Np)
        else:
            slot_vid = (jnp.arange(Np, dtype=jnp.int32)[None, :] * n_parts +
                        _part_ids(P_local, part0))
        vid = jnp.where(make, slot_vid, vert.vid)
        halt = jnp.where(make, False, vert.halt)
        value = jnp.where(make[..., None], 0.0, vert.value)
        return dataclasses.replace(vert, vid=vid, halt=halt, value=value)

    def run_compute(vert: VertexRel, combined, has_msg, gs):
        P, Np = vert.vid.shape
        active = ((~vert.halt) | has_msg) & (vert.vid >= 0)
        if plan.join == "full_outer":
            out = program.compute(vert.vid, vert.value, combined, has_msg,
                                  active, gs)
            return out, active, None
        # left-outer: compact the frontier and gather (index probe)
        F = ec.frontier_cap or max(Np // 2, 1)
        idx, cnt, ovf = jax.vmap(lambda m: groupby.compact(m, F))(active)
        take = lambda a: jnp.take_along_axis(
            a, idx.clip(0)[..., None] if a.ndim == 3 else idx.clip(0),
            axis=1)
        fvid = jnp.where(idx >= 0, take(vert.vid), -1)
        fval = take(vert.value)
        fcomb = take(combined)
        fhas = take(has_msg) & (idx >= 0)
        factive = idx >= 0
        out = program.compute(fvid, fval, fcomb, fhas, factive, gs)
        return out, active, (idx, factive, ovf)

    def apply_updates(vert: VertexRel, out: ComputeOut, active, frontier):
        P, Np = vert.vid.shape
        if frontier is None:
            upd = active
            value = jnp.where(upd[..., None], out.value, vert.value)
            halt = jnp.where(upd, out.halt, vert.halt | ~active)
            gate = out.send_gate & upd
            agg = (out.aggregate, upd) if out.aggregate is not None else None
            return value, halt, gate, agg
        idx, factive, _ = frontier
        tgt = jnp.where(factive, idx, Np)

        def scat(dst_full, upd_rows, t):
            return dst_full.at[t].set(upd_rows, mode="drop")

        value = jax.vmap(scat)(vert.value, out.value, tgt)
        halt = jax.vmap(scat)(vert.halt, out.halt, tgt)
        gate = jax.vmap(scat)(jnp.zeros_like(vert.halt), out.send_gate, tgt)
        agg = None
        if out.aggregate is not None:
            agg = (out.aggregate, factive)
        return value, halt, gate & active, agg

    def gen_messages(vert: VertexRel, value_new, gate_dense, gs,
                     layout=None):
        """Edge-parallel send (dataflow D3). Under the left-outer plan the
        edge stream is COMPACTED to the frontier's edges first (cheap
        boolean prepass + cumsum), so payload generation, the sender
        combine and the bucket sort all run at O(|frontier edges|) instead
        of O(|E|) — this is where the paper's per-iteration SSSP win
        comes from."""
        P, Np = vert.vid.shape
        Ep = vert.edge_src.shape[1]
        esl = vert.edge_src.clip(0)
        egate = jnp.take_along_axis(gate_dense, esl, axis=1) & \
            (vert.edge_src >= 0) & (vert.edge_dst >= 0)
        edge_src, edge_dst, edge_val = (vert.edge_src, vert.edge_dst,
                                        vert.edge_val)
        if plan.join == "left_outer":
            EF = min(max(ec.frontier_cap * 8, 64), Ep)
            eidx, _, ovf_e = jax.vmap(
                lambda m: groupby.compact(m, EF))(egate)
            take1 = lambda a: jnp.take_along_axis(a, eidx.clip(0), axis=1)
            edge_src = jnp.where(eidx >= 0, take1(vert.edge_src), -1)
            edge_dst = jnp.where(eidx >= 0, take1(vert.edge_dst), -1)
            edge_val = take1(vert.edge_val)
            egate = eidx >= 0
            esl = edge_src.clip(0)
            ovf_edges = jnp.sum(ovf_e)
        else:
            ovf_edges = jnp.zeros((), jnp.int32)
        src_vid = jnp.take_along_axis(vert.vid, esl, axis=1)
        if kernel_gather and layout is not None:
            # row-blocked csr_spmv Pallas kernel: the gather becomes
            # one-hot MXU matmuls over the host-planned tiling. Invalid
            # lanes read 0.0 where the jnp path reads row 0 — both are
            # masked by egate before anything observable.
            src_val = kbackend.edge_gather_values(
                value_new, edge_src, layout, impl_r=impl_r)
        else:
            src_val = jnp.take_along_axis(value_new, esl[..., None]
                                          .repeat(value_new.shape[-1], -1),
                                          axis=1)
        payload = program.send(src_vid, src_val, edge_val, edge_dst, gs)
        return edge_dst, payload, egate, ovf_edges

    def sender_combine(dst, payload, valid):
        if named_comb:
            # segment_combine kernel path: single-pass blocked segmented
            # fold over the dst-sorted stream. BOTH impls run the same
            # blocked reduction order ("ref" = jnp re-execution of the
            # kernel's tile network) so kernel_impl="ref" and ="pallas"
            # are bit-for-bit identical even for float sums. pallas_call
            # must not be vmapped (the batching rule would regrid the
            # sequential tile carry), so partitions unroll — P_local is
            # small and static.
            big = jnp.iinfo(jnp.int32).max
            outs = []
            for p in range(dst.shape[0]):
                key = jnp.where(valid[p], dst[p], big)
                order = jnp.argsort(key)
                ks, ps, vs = key[order], payload[p][order], valid[p][order]
                folded, is_last = kbackend.sorted_segment_fold(
                    ks, ps, vs, program.combine_op, impl_r=impl_r)
                outs.append((jnp.where(is_last, ks, -1), folded, is_last))
            stack = lambda i: jnp.stack([o[i] for o in outs])
            return stack(0), stack(1), stack(2)

        def per_part(d, p, v):
            ks, folded, is_last = groupby.sort_combine(
                jnp.where(v, d, jnp.iinfo(jnp.int32).max), p, v,
                comb_fn, comb_ident)
            return jnp.where(is_last, ks, -1), folded, is_last
        return jax.vmap(per_part)(dst, payload, valid)

    def route(dst, payload, valid, cap, Np, collect=False, presorted=False):
        f = lambda d, p, v: connector.bucket_by_owner(
            d, p, v, n_parts, cap,
            sort_by_dst=(plan.connector == "partitioning_merging"),
            partition=plan.partition, capacity=Np, presorted=presorted)
        b_dst, b_pay, b_val, ovf = jax.vmap(f)(dst, payload, valid)
        if collect:  # out-of-core: hand buckets back to the host
            return b_dst, b_pay, b_val, jnp.sum(ovf)
        r_dst, r_pay, r_val = exchange(b_dst, b_pay, b_val)
        P_local = dst.shape[0]
        flat = lambda a: a.reshape((P_local, -1) + a.shape[3:])
        return flat(r_dst), flat(r_pay), flat(r_val), jnp.sum(ovf)

    def apply_mutations(vert, value, halt, out: ComputeOut, gs):
        """Dataflow D6 (Figure 5): deletions before insertions, conflicts
        via resolve. Out-of-core (``ec.ooc_collect``) the insert
        proposals are BUCKETED BY OWNER over all n_parts partitions and
        handed back to the host instead of being exchanged: the in-device
        exchange only spans the resident super-partition, so a
        cross-super-partition insert must travel through the HOST
        MUTATION INBOX (core/ooc.py applies the buckets — with the same
        scatter/resolve semantics — at the superstep barrier). Deletions
        and own-edge rewrites stay in-device: they are local to the
        owning partition by construction."""
        P, Np = vert.vid.shape
        vid = vert.vid
        if out.delete_self is not None:
            dele = out.delete_self
            vid = jnp.where(dele, -1, vid)
            halt = jnp.where(dele, True, halt)
        ovf = jnp.zeros((), jnp.int32)
        mut_buckets = None
        if out.insert_vid is not None and ec.ooc_collect:
            ins_dst = out.insert_vid.reshape(P, -1)
            ins_val = out.insert_value.reshape(P, Np, -1)
            mb_dst, mb_val, mb_ok, ovf = route(
                ins_dst, ins_val, ins_dst >= 0, ec.mutation_cap, Np,
                collect=True)
            mut_buckets = (mb_dst, mb_val, mb_ok)
        elif out.insert_vid is not None:
            ins_dst = out.insert_vid.reshape(P, -1)
            ins_val = out.insert_value.reshape(P, Np, -1)
            r_dst, r_val, r_valid, ovf = route(
                ins_dst, ins_val, ins_dst >= 0, ec.mutation_cap, Np)

            def per_part(vidp, valp, haltp, d, pv, v):
                slot = _slot_of(d, v, Np)
                summed = jnp.zeros((Np + 1, pv.shape[-1]), jnp.float32) \
                    .at[slot].add(jnp.where(v[:, None], pv, 0.0))
                cnt = jnp.zeros((Np + 1,), jnp.int32).at[slot].add(v)
                newvid = jnp.full((Np + 1,), -1, jnp.int32) \
                    .at[slot].max(jnp.where(v, d, -1))
                resolved = program.resolve(newvid[:Np], summed[:Np],
                                           cnt[:Np])
                take = cnt[:Np] > 0
                vidp = jnp.where(take, newvid[:Np], vidp)
                valp = jnp.where(take[:, None], resolved, valp)
                haltp = jnp.where(take, False, haltp)
                return vidp, valp, haltp

            vid, value, halt = jax.vmap(per_part)(
                vid, value, halt, r_dst, r_val, r_valid)
        edge_dst, edge_val = vert.edge_dst, vert.edge_val
        if out.new_edge_dst is not None:
            edge_dst = jnp.where(out.new_edge_dst >= -1, out.new_edge_dst,
                                 edge_dst)
        if out.new_edge_val is not None:
            edge_val = jnp.where(jnp.isnan(out.new_edge_val), edge_val,
                                 out.new_edge_val)
        return vid, value, halt, edge_dst, edge_val, ovf, mut_buckets

    def superstep(vert: VertexRel, msg: MsgRel, gs: GlobalState,
                  part0=None, layout=None):
        """``part0`` (out-of-core only): global index of the resident
        block's first partition, so resurrect derives correct vids for
        super-partitions past the first. ``layout`` (kernel path only):
        host-planned gather tiling from ``kbackend.plan_edge_layout`` —
        fixed-shape per graph shape, so the OOC driver threads
        per-super-partition layouts through one shared jitted step. Both
        traced — no re-tracing across super-partitions."""
        P, Np = vert.vid.shape
        # 1-2. receiver group-by + join + select (D1)
        combined, has_msg = receiver_groupby(msg, Np)
        if getattr(program, "mutates", False):
            vert = resurrect(vert, has_msg, part0)
        out, active, frontier = run_compute(vert, combined, has_msg, gs)
        # 3. vertex updates (D2)
        value, halt, gate, agg = apply_updates(vert, out, active, frontier)
        # 4. message generation + sender combine + exchange (D3/D7)
        dst, payload, valid, ovf_edges = gen_messages(vert, value, gate, gs,
                                                      layout)
        presorted = False
        ovf_pack = jnp.zeros((), jnp.int32)
        if plan.sender_combine:
            dst, payload, valid = sender_combine(dst, payload, valid)
            presorted = True  # sort_combine leaves dst ascending
            capc = n_parts * ec.bucket_cap
            if fuse_pack and capc < dst.shape[1]:
                dst, payload, valid, ovf_pack = compact_combined(
                    dst, payload, valid, capc)
        collect_msgs = ec.ooc_collect or ec.exchange_apart
        r_dst, r_pay, r_val, ovf = route(dst, payload, valid, ec.bucket_cap,
                                         Np, collect=collect_msgs,
                                         presorted=presorted)
        ovf_f = frontier[2].sum() if frontier is not None else 0
        # 5. mutations (D6)
        m_ovf = jnp.zeros((), jnp.int32)
        mut_buckets = None
        vid, edge_dst, edge_val = vert.vid, vert.edge_dst, vert.edge_val
        if (out.insert_vid is not None or out.delete_self is not None
                or out.new_edge_dst is not None
                or out.new_edge_val is not None):
            (vid, value, halt, edge_dst, edge_val, m_ovf,
             mut_buckets) = apply_mutations(vert, value, halt, out, gs)
        # 6. global state (D4/D5/D8/D9). Overflow is counted PER SOURCE
        # (bucket / frontier / mutation / edge) so the drivers' regrow
        # paths double only the capacity that actually overflowed.
        msg_count = red_sum(r_val).astype(jnp.int32)
        # (order = relations.OVF_BUCKET/FRONTIER/MUTATION/EDGE)
        zero = jnp.zeros((), jnp.int32)
        overflow = jnp.stack([
            red_sum(ovf).astype(jnp.int32) +
            red_sum(ovf_pack).astype(jnp.int32),
            (red_sum(ovf_f).astype(jnp.int32) if frontier is not None
             else zero),
            red_sum(m_ovf).astype(jnp.int32),
            red_sum(ovf_edges).astype(jnp.int32)])
        active_count = red_sum(active).astype(jnp.int32)
        if agg is not None:
            contrib, mask = agg
            local = jnp.where(mask[..., None], contrib, 0.0) \
                .reshape(-1, program.agg_dims).sum(0)
            agg_val = (jax.lax.psum(local, ec.axis_name)
                       if ec.axis_name is not None else local)
        else:
            agg_val = gs.aggregate
        halt_all = red_all(halt | (vid < 0))
        g_halt = halt_all & (msg_count == 0)
        new_vert = VertexRel(vid=vid, halt=halt, value=value,
                             edge_src=vert.edge_src, edge_dst=edge_dst,
                             edge_val=edge_val)
        # under ooc_collect / exchange_apart new_msg carries the
        # PRE-EXCHANGE (P_local, n_parts, C) buckets — same pytree, one
        # extra axis; the driver runs the exchange itself
        new_msg = MsgRel(dst=r_dst, payload=r_pay, valid=r_val)
        new_gs = GlobalState(
            halt=g_halt | program.is_converged(gs),
            aggregate=jnp.asarray(agg_val, jnp.float32).reshape(
                gs.aggregate.shape),
            superstep=gs.superstep + 1,
            overflow=gs.overflow + overflow,
            active_count=active_count,
            msg_count=msg_count)
        if ec.ooc_collect:
            # extra outputs for the OOC collector: per-(src, dst) bucket
            # occupancy counts (computed on-device so the host never has
            # to scan the bucket tensors for the inbox run-width trim /
            # readiness bookkeeping of the barrier-free pipeline), and
            # the collected insert-proposal buckets (sp, P, Cm) for the
            # host mutation inbox — None when the program never proposes
            # inserts (the pytree stays static per program)
            counts = jnp.sum(r_val, axis=2, dtype=jnp.int32)
            return new_vert, new_msg, new_gs, counts, mut_buckets
        return new_vert, new_msg, new_gs

    return superstep


def jit_superstep(program: VertexProgram, plan: PhysicalPlan,
                  ec: EngineConfig, *, donate_vertex: bool = False):
    """jit the superstep, optionally DONATING the vertex-relation input
    buffers to their updated outputs (the shapes match field-for-field).
    The OOC streaming executor keeps several super-partitions in flight
    at once; donation lets XLA reuse each uploaded vertex block for its
    result instead of doubling the resident footprint per pipeline slot.
    The message and global-state arguments are never donated: the
    streaming dispatcher shares one GlobalState across every in-flight
    super-partition, and the collected bucket outputs do not alias the
    inbox-slice shapes.

    The returned callable participates in ``repro.obs`` tracing: each
    invocation is a ``compute``-category span (and, when the tracer was
    started with jax_annotations, a ``jax.profiler.TraceAnnotation`` —
    the bridge that lines host spans up with device activity under the
    JAX profiler). With tracing off the wrapper is one extra Python call
    around the jitted function."""
    from repro.obs import trace

    fn = make_superstep(program, plan, ec)
    jf = (jax.jit(fn, donate_argnums=(0,)) if donate_vertex
          else jax.jit(fn))

    def traced(*args):
        with trace.annotate("superstep", "compute"):
            return jf(*args)

    return traced
