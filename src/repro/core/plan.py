"""Physical plan choices (the paper's Section 5.3 "sixteen tailored
executions": 2 joins x 4 group-bys x 2 storage).

join:
  full_outer   scan every vertex slot; messages scattered into dense
               vid-aligned buffers (paper: index full outer join — right
               for message-dense algorithms, e.g. PageRank)
  left_outer   compact the frontier (vertices with messages or active) and
               gather only those rows (paper: index left outer join + Vid
               index — right for message-sparse algorithms, e.g. SSSP)

groupby:
  scatter      hash group-by: monoid scatter into dense slots (HashSort
               analogue; named combine ops only)
  sort         sort by dst + segmented combine of sorted runs (sort-based
               group-by; supports arbitrary associative combine UDFs)

connector:
  partitioning          unsorted buckets + fully-pipelined all_to_all;
                        receiver re-groups
  partitioning_merging  sender sorts buckets by dst before the exchange
                        (m-to-n partitioning merging connector; receiver
                        group-by sees presorted runs)

sender_combine: pre-aggregate messages per destination on the sender
  (the paper's combiner applied in dataflow D3) — trades compute for
  exchange bytes.

storage — the vertex-store write-back policy. In-memory drivers keep the
  Vertex relation resident in device memory, so storage only changes the
  plan's label there; OUT-OF-CORE it decides what crosses the device->host
  link (and hits the host store) every superstep, and the planner models
  and switches it mid-run (planner/cost.py "storage_writeback" term):

  inplace   ship and stream the FULL value block back to the host store
            each superstep (B-tree in-place update analogue). Sequential
            host writes, bytes independent of how much actually changed —
            right when most vertices update every superstep (PageRank).
  delta     ship only CHANGED (slot, value) records and scatter-merge them
            into the host store (LSM deferred-merge analogue). Pays a
            per-record slot index and random host writes, but bytes scale
            with the observed change density — right for sparse-update
            workloads (SSSP past the frontier peak). ``merge_every`` is
            the LSM merge cadence knob (kept for the analogue; the dense
            host store merges eagerly, so it does not affect results).
"""
from __future__ import annotations

from dataclasses import dataclass

# the two write-back policies the planner's storage dimension ranges over
STORAGES = ("inplace", "delta")

# hot-path kernel implementations (kernels/backend.resolve): "auto"
# resolves per backend — compiled Pallas on TPU, the jnp reference
# elsewhere; "pallas" forces the kernels (interpret mode off-TPU, the
# bit-for-bit-testable emulator); "pallas_tpu" forces TPU lowering.
KERNEL_IMPLS = ("auto", "ref", "pallas", "pallas_tpu")


@dataclass(frozen=True)
class PhysicalPlan:
    join: str = "full_outer"          # full_outer | left_outer
    groupby: str = "scatter"          # scatter | sort
    connector: str = "partitioning"   # partitioning | partitioning_merging
    sender_combine: bool = True
    storage: str = "inplace"          # inplace | delta
    merge_every: int = 4              # delta storage merge cadence
    # vid partitioning. "hash" is the paper's default (vid % P). "range"
    # (vid // capacity) is a beyond-paper optimization enabled by dense
    # integer vids: owners become CONTIGUOUS in dst order, so one dst-sort
    # serves both the sender combine and the bucket layout — a whole
    # O(E log E) sort pass per superstep disappears. Trade-off: no insert
    # headroom (load uses capacity_factor 1.0) and skew-sensitivity, the
    # classic hash-vs-range dataflow choice (paper Section 8).
    partition: str = "hash"           # hash | range
    # left_outer: initial frontier capacity / Np. Pregel semantics activate
    # EVERY vertex at superstep 0, so the initial capacity covers all; the
    # host driver then adaptively SHRINKS it (recompiling once) when the
    # live set collapses — that is where the paper's left-outer win lives
    # under static shapes.
    frontier_capacity: float = 1.0
    # hot-path kernel dispatch (kernels/backend.py): which implementation
    # of the edge gather (csr_spmv one-hot MXU matmul) and the sender
    # combine (segment_combine single-pass fold) the superstep uses. The
    # planner prices the kernel path per machine model (MXU vs emulated),
    # so "auto" picks it exactly where it wins.
    kernel_impl: str = "auto"         # auto | ref | pallas | pallas_tpu

    def validate(self, combine_op: str):
        if self.groupby == "scatter" and combine_op == "custom":
            raise ValueError(
                "scatter (hash) group-by needs a named monoid combine op; "
                "use groupby='sort' for custom combine UDFs")
        if self.kernel_impl not in KERNEL_IMPLS:
            raise ValueError(
                f"kernel_impl={self.kernel_impl!r}: expected one of "
                f"{KERNEL_IMPLS}")
        return self


DEFAULT_PLAN = PhysicalPlan()
# the paper's Figure 9 hints for SSSP: left-outer join + unmerged connector
SPARSE_PLAN = PhysicalPlan(join="left_outer", groupby="scatter",
                           connector="partitioning")

# left-outer frontier capacities never refit below this floor
FRONTIER_FLOOR = 64


def bucket_capacity(plan: PhysicalPlan, edge_capacity: int,
                    vertex_capacity: int, n_parts: int, *,
                    slack: float = 1.5) -> int:
    """Per-(src,dst)-partition message bucket capacity for `plan`. The
    single capacity policy shared by the drivers (default_engine_config)
    and the planner's cost model — their agreement is what makes modeled
    plan costs realizable at switch time."""
    cap = int((edge_capacity / n_parts + 8) * slack)
    if plan.sender_combine:
        # after sender-side combining, <= Np distinct receivers per bucket
        cap = min(cap, vertex_capacity + 8)
    return max(cap, 8)
