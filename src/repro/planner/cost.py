"""Analytical per-superstep cost model over the physical plan space.

The engine executes STATIC shapes, so cost scales with the capacities a
plan implies, not with live tuple counts: a full-outer join always touches
every vertex slot; a left-outer join touches the (adaptively refitted)
frontier capacity, which tracks observed frontier density. The model
mirrors the capacity policies in ``core/driver.py`` (``default_engine_config``
bucket caps, the frontier-refit rule) and the operator structure of
``core/superstep.py``, then converts flops / HBM bytes / exchange bytes to
seconds with the dry-run machine model (``launch/dryrun.py`` roofline
constants). ``hlo_calibrate`` cross-checks the capacity terms against the
trip-count-aware HLO analyzer (``launch/hlo_cost.py``) on a lowered
superstep.

Out-of-core runs add a STORAGE dimension: each streamed super-partition
writes its vertex updates back over the device<->host link, and the
``storage_writeback`` term prices the ``inplace`` (full-block stream) vs
``delta`` (changed-records scatter-merge) policies from the measured
change density (``Observation.change_density`` = delta_bytes/full_bytes
from the OOC statistics stream).

Only RANKING between plans matters for the optimizer; absolute seconds are
the single-chip roofline bound, a lower bound on real wall time.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field

from repro.core.plan import FRONTIER_FLOOR, PhysicalPlan, bucket_capacity

WORD = 4          # bytes per int32/float32 element

# ---- analytic constants (units in comments; hand-tuned against
# ``hlo_calibrate``, which lowers a real superstep and measures it with the
# trip-count-aware HLO analyzer). These are the DEFAULTS: ``MachineModel``
# carries a per-instance copy, and ``calibrate_machine`` refits them per
# backend from lowered probe supersteps at startup when a driver opts in
# (``AdaptiveConfig.calibrate``); the periodic re-calibration loop is
# still a ROADMAP item.

# K_COMPUTE [flops/element]: arithmetic intensity of one fused elementwise
# UDF stage (compute/send/combine bodies lower to a handful of fused ops
# per element; 8 flops/element matches the HLO flop counts of the built-in
# algorithm library within ~2x, which is enough for ranking).
K_COMPUTE = 8.0
# K_SCATTER [dimensionless bytes multiplier]: random gather/scatter
# amplification — each randomly-addressed access moves a cache line /
# memory transaction, not one element, so scattered traffic is charged
# K_SCATTER times the payload bytes (sequential/streamed traffic is
# charged 1x).
K_SCATTER = 4.0
# SORT_PASS_FRAC [dimensionless]: sorts are memory-bound; one argsort +
# permute over n rows is modeled as SORT_PASS_FRAC * log2(n) full
# read+write passes over the keyed payload (cache-resident merge passes
# cost well under a full memory round-trip each, hence the fraction < 1).
SORT_PASS_FRAC = 0.25
FRONTIER_SLACK = 2.0   # refit keeps 2x headroom over the live frontier
MIN_FRONTIER = FRONTIER_FLOOR   # the driver's refit floor
# INTERPRET_PENALTY [dimensionless]: Pallas interpret mode executes the
# kernel's tile program through the host backend — every block move is a
# real HBM/DRAM round trip and the MXU matmul degenerates to scalar code.
# Charged on the kernel path's streamed bytes when the resolved impl is
# "pallas" (interpret) so plan="auto" never picks the emulator over the
# jnp reference off-TPU.
INTERPRET_PENALTY = 8.0


@dataclass(frozen=True)
class MachineModel:
    """Roofline constants (defaults: TPU v5e, as in launch/dryrun.py) plus
    the analytic cost constants, so ``calibrate_machine`` can refit the
    latter per backend without touching module globals."""
    peak_flops: float = 197e12   # bf16 flops/s per chip
    hbm_bw: float = 819e9        # bytes/s per chip
    link_bw: float = 50e9        # bytes/s per ICI link
    host_bw: float = 32e9        # bytes/s device<->host (PCIe-class); the
                                 # OOC streaming traffic and storage
                                 # write-back cross this link
    disk_bw: float = 3e9         # bytes/s host DRAM<->local SSD (NVMe,
                                 # sequential); the spill tier's page
                                 # faults and dirty write-backs cross it
                                 # when the buffer cache overflows its
                                 # memory_budget_bytes
    host_mem_bw: float = 100e9   # bytes/s host DRAM (DDR-class); the
                                 # serial inter-superstep inbox restack
                                 # is a host-memory pass, not a PCIe or
                                 # HBM one, and must be priced at host
                                 # memory speed
    net_bw: float = 25e9         # bytes/s BISECTION bandwidth per worker
                                 # for the sharded all_to_all exchange
                                 # (network axis; ethernet/DCN-class
                                 # default — the ICI link_bw stays the
                                 # on-device exchange price)
    net_latency_s: float = 10e-6  # per-exchange dispatch latency: one
                                  # all_to_all STAGE pays it once per
                                  # superstep regardless of plan, but it
                                  # keeps the modeled exchange seconds in
                                  # the measured span's regime when the
                                  # payload is latency-dominated
    k_compute: float = K_COMPUTE
    k_scatter: float = K_SCATTER
    sort_pass_frac: float = SORT_PASS_FRAC
    # does this machine have a matrix unit the Pallas kernels compile to?
    # `estimate` resolves plan.kernel_impl="auto"/"pallas" against THIS
    # flag (not the host process's backend): the planner prices plans for
    # the machine model it is told about, which is what lets one process
    # rank TPU and emulated plans side by side.
    mxu: bool = True


DEFAULT_MACHINE = MachineModel()
# emulated transport (single host): the "exchange" is a transpose through
# memory and the "host link" is a memcpy, not an ICI/PCIe hop — the host
# drivers plan with this model (the delta-vs-inplace distinction survives:
# scatter amplification vs streaming is a memory-system property). The
# DISK is a real disk either way, so disk_bw keeps its default; "host
# memory" is the same memory system as everything else here.
EMULATED_MACHINE = MachineModel(link_bw=DEFAULT_MACHINE.hbm_bw,
                                host_bw=DEFAULT_MACHINE.hbm_bw,
                                host_mem_bw=DEFAULT_MACHINE.hbm_bw,
                                # fake host devices: the all_to_all is a
                                # memcpy (memory-class bandwidth) but each
                                # exchange STAGE pays a real dispatch
                                # latency (ms-class on the CPU client) —
                                # this is what keeps the modeled exchange
                                # within the clamp of the measured-span
                                # calibration (Observation.net_scale)
                                net_bw=DEFAULT_MACHINE.hbm_bw,
                                net_latency_s=1e-3,
                                mxu=False)


@dataclass(frozen=True)
class GraphStats:
    """Static per-job facts the cost model needs (paper Table 1 shapes)."""
    n_vertices: int
    n_edges: int
    n_partitions: int
    vertex_capacity: int   # Np: slots per partition
    edge_capacity: int     # Ep: edge slots per partition
    value_dims: int = 1
    msg_dims: int = 1

    @classmethod
    def from_vertex(cls, vert, program) -> "GraphStats":
        import numpy as np
        P, Np = vert.vid.shape
        n_v = int(np.asarray(vert.vid >= 0).sum())
        n_e = int(np.asarray(vert.edge_src >= 0).sum())
        return cls(n_vertices=n_v, n_edges=n_e, n_partitions=P,
                   vertex_capacity=Np,
                   edge_capacity=vert.edge_src.shape[1],
                   value_dims=program.value_dims,
                   msg_dims=program.msg_dims)


@dataclass(frozen=True)
class Observation:
    """Runtime statistics the model conditions on (from planner.stats)."""
    frontier_density: float = 1.0   # active fraction of LIVE vertices
    messages: int = 0               # live messages last superstep (total)
    superstep: int = 0
    # live per-(src,dst) bucket capacity (0 = unknown/initial): running
    # drivers only GROW buckets, so a candidate plan cannot realize a
    # smaller message capacity than the engine already carries
    bucket_cap: int = 0
    # fraction of vertex-value bytes that changed last superstep — the OOC
    # driver measures it as delta_bytes / full_bytes per superstep; drives
    # the storage (write-back) dimension. 1.0 = everything changed.
    change_density: float = 1.0
    # True when the job streams super-partitions through the device (OOC):
    # only then does the storage write-back cross the host link and enter
    # the cost; in-memory drivers keep the Vertex relation resident.
    ooc: bool = False
    # True when the OOC executor PIPELINES the super-partition stream
    # (core/ooc.py stream=True): host-link transfers then overlap device
    # compute, so the model prices the superstep as max(step, transfer)
    # instead of step + transfer (PlanCost.overlap_host).
    streaming: bool = False
    # True when the executor runs the BARRIER-FREE superstep pipeline
    # (core/ooc.py barrier_free=True): the inter-superstep inbox rebuild
    # and mutation apply run per destination, overlapped with the next
    # superstep's compute, so only 1/super_partitions of that work stays
    # on the serial critical path (the first destination's prepare) —
    # the barrier executor pays all of it serially.
    barrier_free: bool = False
    # super-partitions the OOC stream cycles through (P / budget): sets
    # the serial share of the rebuild under barrier-free execution.
    super_partitions: int = 1
    # observed device-idle gap between supersteps (seconds) and the I/O
    # engine's queue depth — surfaced for diagnostics/benchmarks; the
    # model prices the rebuild analytically (plan-dependent), not from
    # the raw observed stall, which mixes in compile and fold noise.
    readiness_stall_s: float = 0.0
    io_queue_depth: float = 0.0
    # measurement loop closure (ROADMAP "Measurement-driven planning"):
    # the controller EWMAs the measured readiness stall across steady
    # (non-recompile) supersteps and divides it by the analytic serial
    # leg of the CURRENT plan to get `serial_scale` — a plan-independent
    # calibration multiplier applied to every candidate's serial leg, so
    # ranking stays plan-relative but the serial-vs-overlapped tradeoff
    # is priced at the stall the hardware actually delivers.
    # `stall_ewma_s` rides along for diagnostics; < 0 = no measurement.
    stall_ewma_s: float = -1.0
    serial_scale: float = 1.0
    # messages per DISTINCT destination, measured from the run-structured
    # host inbox (>= 1). High combinability means a sender combine
    # collapses the inbox that crosses the host link; ~1 means the
    # sort+fold buys nothing — this is what makes the sender_combine
    # dimension replannable from observed statistics.
    combinability: float = 1.0
    # insert proposals per live vertex last superstep: the host mutation
    # inbox's device->host + scatter-merge traffic.
    mutation_rate: float = 0.0
    # ---- network axis (sharded driver) -------------------------------
    # True when the run executes on a multi-device mesh with the
    # all_to_all exchange stage (core/sharded.py): the exchange then
    # crosses the NETWORK (machine.net_bw), not device memory, and the
    # model prices it per worker over the bisection.
    sharded: bool = False
    n_workers: int = 1
    # measured per-superstep exchange wire bytes / stage stall (seconds),
    # lifted from the driver's ``exchange`` span — diagnostics plus the
    # raw inputs of the net calibration below.
    exchange_bytes: float = 0.0
    exchange_stall_s: float = 0.0
    # measurement loop closure for the network axis, mirroring
    # serial_scale: the controller EWMAs the measured exchange stall and
    # divides it by the CURRENT plan's analytic net leg; every
    # candidate's net price shifts by the clamped ratio, so connector
    # choice trades against OBSERVED interconnect pressure.
    # exchange_ewma_s < 0 = no measurement yet.
    exchange_ewma_s: float = -1.0
    net_scale: float = 1.0
    # True when the OOC store runs the DISK TIER (a memory_budget_bytes
    # smaller than the working set, spilling through storage/pager): page
    # faults and dirty write-backs then cross the disk axis.
    spilling: bool = False
    # pager hit rate (fraction of page lookups served from DRAM) from the
    # statistics stream; 1 - hit_rate of the streamed bytes fault from
    # disk.
    hit_rate: float = 1.0


@dataclass
class PlanCost:
    flops: float = 0.0
    bytes: float = 0.0            # HBM traffic per partition
    exchange_bytes: float = 0.0   # cross-partition link bytes
    host_bytes: float = 0.0       # device<->host link bytes (OOC only)
    disk_bytes: float = 0.0       # DRAM<->disk spill-tier bytes (OOC
                                  # under a memory budget only)
    net_bytes: float = 0.0        # all_to_all wire bytes per worker
                                  # (sharded runs only)
    # seconds of the all_to_all exchange STAGE: the sharded driver runs
    # it as its own blocking dispatch between supersteps, so it is
    # ADDITIVE on the critical path (never hidden by the overlap max),
    # like the serial leg but priced at net_bw + a per-stage latency.
    net_seconds: float = 0.0
    terms: dict = field(default_factory=dict)   # per-operator seconds
    # pipelined OOC streaming: the host link and the disk both run
    # concurrently with the device, so total seconds =
    # max(device, host, disk) instead of their sum
    overlap_host: bool = False
    # SERIAL leg of the critical path: inter-superstep work no pipeline
    # overlaps (the barrier executor's whole inbox rebuild; barrier-free
    # keeps only the first destination's share). Added on top of the
    # overlap max — this is what turns the streamed ``max(device, host,
    # disk)`` formula into a critical-path estimate.
    serial_seconds: float = 0.0
    # per-term raw components (flops / bytes per axis) — what the
    # roofline benchmark plots against the machine ceilings; `terms`
    # above only keeps the converted seconds
    detail: dict = field(default_factory=dict)

    def _detail(self, term: str) -> dict:
        return self.detail.setdefault(term, {
            "flops": 0.0, "hbm_bytes": 0.0, "exchange_bytes": 0.0,
            "host_bytes": 0.0, "disk_bytes": 0.0, "serial_bytes": 0.0,
            "net_bytes": 0.0})

    def add(self, term: str, machine: MachineModel, *, flops: float = 0.0,
            bytes: float = 0.0, exchange_bytes: float = 0.0,
            host_bytes: float = 0.0, disk_bytes: float = 0.0):
        self.flops += flops
        self.bytes += bytes
        self.exchange_bytes += exchange_bytes
        self.host_bytes += host_bytes
        self.disk_bytes += disk_bytes
        self.terms[term] = self.terms.get(term, 0.0) + (
            flops / machine.peak_flops + bytes / machine.hbm_bw +
            exchange_bytes / machine.link_bw +
            host_bytes / machine.host_bw +
            disk_bytes / machine.disk_bw)
        d = self._detail(term)
        d["flops"] += flops
        d["hbm_bytes"] += bytes
        d["exchange_bytes"] += exchange_bytes
        d["host_bytes"] += host_bytes
        d["disk_bytes"] += disk_bytes

    def add_serial(self, term: str, machine: MachineModel, *,
                   bytes: float = 0.0):
        """Host-memory traffic on the SERIAL inter-superstep path (the
        readiness leg): charged at host DRAM bandwidth
        (``machine.host_mem_bw`` — not device HBM, which would
        underprice the leg ~8x on the default machine) and excluded
        from the overlap max — the device is idle while it runs."""
        s = bytes / machine.host_mem_bw
        self.serial_seconds += s
        self.terms[term] = self.terms.get(term, 0.0) + s
        self._detail(term)["serial_bytes"] += bytes

    def scale_serial(self, factor: float, term: str = "inbox_rebuild"):
        """Apply a measured calibration multiplier to the serial leg
        (the Observation.serial_scale closure): scales both the total
        and the named term so reports stay consistent."""
        self.serial_seconds *= factor
        if term in self.terms:
            self.terms[term] *= factor

    def add_net(self, term: str, machine: MachineModel, *,
                net_bytes: float = 0.0, latency_s: float = 0.0):
        """All_to_all wire traffic of the sharded exchange stage: priced
        at the machine's bisection bandwidth plus a per-stage dispatch
        latency, and kept out of the overlap max — the stage blocks
        between the superstep dispatch and the next prepare."""
        s = net_bytes / machine.net_bw + latency_s
        self.net_bytes += net_bytes
        self.net_seconds += s
        self.terms[term] = self.terms.get(term, 0.0) + s
        self._detail(term)["net_bytes"] += net_bytes

    def scale_net(self, factor: float, term: str = "exchange_net"):
        """Measured calibration multiplier for the network leg (the
        Observation.net_scale closure), mirroring ``scale_serial``."""
        self.net_seconds *= factor
        if term in self.terms:
            self.terms[term] *= factor

    def device_seconds(self, machine: MachineModel = DEFAULT_MACHINE) \
            -> float:
        return (self.flops / machine.peak_flops +
                self.bytes / machine.hbm_bw +
                self.exchange_bytes / machine.link_bw)

    def host_seconds(self, machine: MachineModel = DEFAULT_MACHINE) \
            -> float:
        return self.host_bytes / machine.host_bw

    def disk_seconds(self, machine: MachineModel = DEFAULT_MACHINE) \
            -> float:
        return self.disk_bytes / machine.disk_bw

    def seconds(self, machine: MachineModel = DEFAULT_MACHINE) -> float:
        dev = self.device_seconds(machine)
        hst = self.host_seconds(machine)
        dsk = self.disk_seconds(machine)
        if self.overlap_host:
            # CRITICAL-PATH estimate: the streaming executor hides the
            # slower legs behind the slowest — steady state settles at
            # max(device, host_link, disk) — plus the serial readiness
            # leg nothing overlaps (the inter-superstep rebuild share).
            # The small residual breaks ties among transfer-bound plans
            # toward the one doing less total work (overlap is never
            # quite perfect, and less hidden work frees the pipeline
            # sooner).
            return (max(dev, hst, dsk) + self.serial_seconds
                    + self.net_seconds + 1e-3 * (dev + hst + dsk))
        return dev + hst + dsk + self.serial_seconds + self.net_seconds


def bucket_cap(plan: PhysicalPlan, g: GraphStats, slack: float = 1.5) -> int:
    """The drivers' per-bucket capacity policy (core.plan.bucket_capacity)
    at this graph's shapes."""
    return bucket_capacity(plan, g.edge_capacity, g.vertex_capacity,
                           g.n_partitions, slack=slack)


def refit_frontier_cap(g: GraphStats, density: float) -> int:
    """Frontier capacity the driver's adaptive refit converges to.
    `density` is the active fraction of LIVE vertices."""
    live_pp = density * g.n_vertices / max(g.n_partitions, 1)
    return int(min(g.vertex_capacity,
                   max(MIN_FRONTIER, FRONTIER_SLACK * live_pp)))


def _sort_bytes(n: float, width: float, frac: float) -> float:
    """Memory traffic of one argsort+permute over n keyed rows of `width`
    bytes (log-pass model; `frac` = the machine's sort_pass_frac)."""
    n = max(n, 2.0)
    return frac * math.log2(n) * n * width


def estimate(plan: PhysicalPlan, g: GraphStats, obs: Observation,
             machine: MachineModel = DEFAULT_MACHINE) -> PlanCost:
    """Per-superstep, per-partition cost of running `plan` at the observed
    statistics. Follows superstep.py's operator order D1..D3."""
    P, Np, Ep = g.n_partitions, g.vertex_capacity, g.edge_capacity
    D, V = g.msg_dims, g.value_dims
    kc, ks = machine.k_compute, machine.k_scatter
    sort_b = lambda n, w: _sort_bytes(n, w, machine.sort_pass_frac)
    f = min(max(obs.frontier_density, 1.0 / max(Np, 1)), 1.0)
    c = PlanCost()
    cap = max(bucket_cap(plan, g), obs.bucket_cap)
    M = P * cap                       # received message capacity
    msg_w = (1 + D) * WORD + 1        # dst + payload + valid per slot

    # hot-path kernel dispatch, resolved against the MACHINE MODEL (not
    # the host backend): "auto" prices as pallas_tpu when the machine has
    # an MXU, as the jnp reference otherwise — which is exactly how the
    # engine will resolve it there, so plan="auto" picks the kernel path
    # per backend. Interpret mode ("pallas" off-MXU) is an emulator and
    # carries INTERPRET_PENALTY on its streamed bytes.
    from repro.kernels import backend as _kbackend
    kern = _kbackend.resolve(plan.kernel_impl, tpu=machine.mxu)
    pen = INTERPRET_PENALTY if kern == "pallas" else 1.0
    kern_gather = kern != "ref" and plan.join == "full_outer"
    # (the engine only folds named monoids through the kernel; the model
    # can't see combine_op here, so custom-combine programs are mildly
    # mispriced on the kernel path — acceptable: ranking is plan-relative
    # and every candidate shares the same kernel_impl by default)
    kern_combine = kern != "ref" and plan.sender_combine

    # D1: receiver group-by over the full message capacity
    if plan.connector == "partitioning_merging":
        # presorted runs: one segmented scan, then a scatter of the <=1
        # surviving partial per (run, dst) — run_combine_dense
        c.add("recv_groupby", machine, flops=kc * M * D,
              bytes=(1 + ks) * M * msg_w)
    elif plan.groupby == "sort":
        c.add("recv_groupby", machine, flops=kc * M * D,
              bytes=sort_b(M, msg_w) + M * msg_w)
    else:  # scatter (hash)
        c.add("recv_groupby", machine, flops=kc * M * D,
              bytes=ks * M * msg_w)

    # D1/D2: join + compute + write-back
    if plan.join == "full_outer":
        c.add("join_compute", machine, flops=kc * Np * (V + D),
              bytes=Np * (2 * V + D + 1) * WORD)
        e_work = Ep
    else:
        F = refit_frontier_cap(g, f)
        # mask scan + cumsum over all slots, edge-gate prepass over all
        # edges, then gather/compute/scatter-back only F rows
        c.add("join_compute", machine,
              flops=kc * F * (V + D),
              bytes=(Np + Ep) * WORD +
              ks * F * (2 * V + D + 1) * WORD)
        # gen_messages compacts the edge stream to EF = min(8F, Ep); when
        # the live frontier's edges (~f*Ep) outgrow that, the driver's
        # overflow-regrow doubles the capacity until they fit, so the
        # effective edge work is bounded below by the live edge count
        e_work = min(max(8 * F, MIN_FRONTIER, f * Ep), Ep)

    # D3: edge-parallel payload generation
    if kern_gather:
        # csr_spmv kernel: the value gather becomes row-blocked one-hot
        # MXU matmuls ((BM x BR) @ (BR x 2V) per tile — 2V: the value
        # channel plus the non-finite class channel), so the random HBM
        # gather's scatter amplification disappears: the value block and
        # edge stream are READ ONCE, sequentially, and the matmul flops
        # buy the addressing. Off-MXU interpret mode streams the same
        # bytes through the emulator at INTERPRET_PENALTY.
        from repro.kernels.backend import GATHER_BLOCK_R
        c.add("send", machine,
              flops=kc * e_work * D +
              2.0 * e_work * GATHER_BLOCK_R * 2 * V,
              bytes=pen * e_work * (V + D + 2) * WORD)
    else:
        c.add("send", machine, flops=kc * e_work * D,
              bytes=ks * e_work * (V + D + 2) * WORD)

    # D3/D7: sender combine = sort + segmented fold over the edge stream
    if plan.sender_combine:
        if kern == "pallas_tpu":
            # segment_combine kernel: the fold runs VMEM-resident inside
            # ONE streamed pass over the sorted run (the jnp fold's
            # multi-pass scan through HBM disappears); the dst argsort
            # remains either way
            c.add("sender_combine", machine, flops=kc * e_work * D,
                  bytes=sort_b(e_work, msg_w) + 0.5 * e_work * msg_w)
        else:
            c.add("sender_combine", machine, flops=kc * e_work * D,
                  bytes=sort_b(e_work, msg_w) + pen * e_work * msg_w)

    # connector bucket build (bucket_by_owner): the merging connector
    # with hash partitioning sorts twice (by dst, then stably by owner);
    # range partitioning needs one dst sort — or none when the sender
    # combine already left the stream dst-ascending (owners contiguous);
    # the plain hash connector sorts once by owner
    if plan.partition == "range":
        n_sorts = 0 if plan.sender_combine else 1
    elif plan.connector == "partitioning_merging":
        n_sorts = 2
    else:
        n_sorts = 1
    # with the kernel fold in play the scatter->combine->pack leg is fused:
    # combined survivors are compacted to the bucket capacity (M) BEFORE
    # routing, so the connector never sees more than M rows and the
    # intermediate (P, Ep, C) payload relation is never materialized
    e_pack = min(e_work, float(M)) if kern_combine else e_work
    c.add("connector", machine, flops=kc * e_pack,
          bytes=n_sorts * sort_b(e_pack, msg_w) +
          ks * e_pack * msg_w)

    # exchange: fixed-capacity buckets cross the links whole. On a
    # sharded mesh the cross-WORKER share crosses the network instead
    # (all_to_all over the bisection, plus one per-stage dispatch
    # latency — plan-independent, so it shifts every candidate equally
    # and only matters for matching the measured span's magnitude);
    # the intra-worker share stays a link/memory move. net_scale is the
    # controller's measured-exchange calibration multiplier.
    if obs.sharded and obs.n_workers > 1:
        W = obs.n_workers
        P_l = max(P // W, 1)
        c.add("exchange", machine,
              exchange_bytes=M * msg_w * (P_l - 1) / max(P, 1))
        c.add_net("exchange_net", machine,
                  net_bytes=M * msg_w * (P - P_l) / max(P, 1),
                  latency_s=machine.net_latency_s)
        if obs.net_scale != 1.0:
            c.scale_net(obs.net_scale)
    else:
        c.add("exchange", machine,
              exchange_bytes=M * msg_w * (P - 1) / max(P, 1))

    if obs.ooc:
        # super-partition streaming I/O: every superstep the vertex block
        # (vid/halt/value/edges) and its inbox runs go H2D, and the
        # vid/halt/edge updates plus collected sender buckets come back
        # D2H (the value write-back is priced separately below, by
        # storage policy). The inbox that goes UP is run-trimmed to its
        # occupancy, so it is priced from live messages — and a sender
        # combine divides it by the measured COMBINABILITY (messages per
        # distinct destination): that is the term that lets observed
        # combinability drive the sender_combine replan dimension. The
        # collected buckets coming DOWN are capacity-sized (M).
        if obs.messages > 0:
            mpp = obs.messages / max(P, 1)
            if plan.sender_combine:
                mpp = mpp / max(obs.combinability, 1.0)
            inbox_up = min(float(M), mpp + P) * msg_w
        else:
            inbox_up = M * msg_w    # superstep 0: no measurement yet
        up = Np * ((1 + V) * WORD + 1) + 3 * Ep * WORD + inbox_up
        down = Np * (WORD + 1) + 2 * Ep * WORD + M * msg_w
        c.add("stream_io", machine, host_bytes=up + down)
        # storage write-back: a streamed super-partition must push its
        # vertex VALUE updates back over the device<->host link and into
        # the host store every superstep. `change_density` is the
        # measured delta_bytes/full_bytes ratio from the OOC statistics
        # stream.
        vblock = Np * V * WORD
        cd = min(max(obs.change_density, 0.0), 1.0)
        if plan.storage == "delta":
            # changed (slot, value) records cross the link; the compare
            # streams the store once and the merge scatters the survivors
            c.add("storage_writeback", machine,
                  host_bytes=cd * Np * (1 + V) * WORD,
                  bytes=vblock + ks * cd * vblock)
        else:
            # the full value block streams across the link and the store
            c.add("storage_writeback", machine,
                  host_bytes=vblock, bytes=vblock)
        # host mutation inbox: insert proposals cross the link D2H and
        # scatter-merge into the host store at the barrier
        if obs.mutation_rate > 0.0:
            mut = obs.mutation_rate * Np
            c.add("mutation_io", machine,
                  host_bytes=mut * ((1 + V) * WORD + 1),
                  bytes=ks * mut * (1 + V) * WORD)
        # DISK TIER: when the buffer cache spills (memory budget smaller
        # than the working set), the missed fraction of every streamed
        # page faults in from disk and the dirty write-back goes out to
        # it. Reads miss at (1 - hit_rate); writes are storage-policy
        # shaped — `inplace` rewrites the value pages every superstep,
        # `delta` only dirties pages with changed rows (≈ change
        # density), and the inbox generation is rewritten either way.
        if obs.spilling:
            miss = min(max(1.0 - obs.hit_rate, 0.0), 1.0)
            rel_pages = Np * ((1 + V) * WORD + 1) + 3 * Ep * WORD
            reads = miss * (rel_pages + inbox_up)
            writes = inbox_up + (cd * vblock if plan.storage == "delta"
                                 else vblock)
            c.add("disk_io", machine, disk_bytes=reads + writes)
        # INTER-SUPERSTEP READINESS LEG: the run-structured inbox
        # restack (source-major stack -> destination-major transpose ->
        # trim) streams the inbox through host memory twice. Under the
        # barrier executor it all runs serially between supersteps (the
        # device idles); barrier-free keeps only the FIRST destination's
        # share on the critical path — the rest overlaps the next
        # superstep's compute. Plan-dependent through the inbox
        # occupancy (a sender combine shrinks what must be restacked),
        # which is what lets the optimizer trade rebuild time against
        # combine cost under either schedule.
        rebuild = 2.0 * inbox_up
        if obs.barrier_free:
            rebuild /= max(obs.super_partitions, 1)
        c.add_serial("inbox_rebuild", machine, bytes=rebuild)
        if obs.serial_scale != 1.0:
            c.scale_serial(obs.serial_scale)
        # the pipelined executor overlaps the host link and the disk
        # with compute: rank plans by max(device, host, disk) (plus the
        # serial readiness leg) instead of their sum
        c.overlap_host = bool(obs.streaming)
    return c


def hlo_calibrate(program, plan: PhysicalPlan, g: GraphStats,
                  obs: Observation = Observation()) -> "object":
    """Lower one emulated superstep at the capacities `estimate` assumes
    and measure it with the trip-count-aware HLO analyzer — the ground
    truth the analytic constants are calibrated against. Returns a
    ``launch.hlo_cost.Cost``. Compile-time heavy; used by benchmarks and
    calibration tests, not by the per-superstep optimizer loop."""
    import jax
    import jax.numpy as jnp

    from repro.core.relations import (N_OVERFLOW, GlobalState, MsgRel,
                                      VertexRel)
    from repro.core.superstep import EngineConfig, make_superstep
    from repro.launch import hlo_cost

    cap = bucket_cap(plan, g)
    ec = EngineConfig(n_parts=g.n_partitions, bucket_cap=cap,
                      frontier_cap=refit_frontier_cap(
                          g, obs.frontier_density))
    step = make_superstep(program, plan, ec)
    P, Np, Ep = g.n_partitions, g.vertex_capacity, g.edge_capacity
    sds = jax.ShapeDtypeStruct
    vert = VertexRel(vid=sds((P, Np), jnp.int32),
                     halt=sds((P, Np), jnp.bool_),
                     value=sds((P, Np, g.value_dims), jnp.float32),
                     edge_src=sds((P, Ep), jnp.int32),
                     edge_dst=sds((P, Ep), jnp.int32),
                     edge_val=sds((P, Ep), jnp.float32))
    msg = MsgRel(dst=sds((P, P * cap), jnp.int32),
                 payload=sds((P, P * cap, g.msg_dims), jnp.float32),
                 valid=sds((P, P * cap), jnp.bool_))
    gs = GlobalState(halt=sds((), jnp.bool_),
                     aggregate=sds((program.agg_dims,), jnp.float32),
                     superstep=sds((), jnp.int32),
                     overflow=sds((N_OVERFLOW,), jnp.int32),
                     active_count=sds((), jnp.int32),
                     msg_count=sds((), jnp.int32))
    compiled = jax.jit(step).lower(vert, msg, gs).compile()
    return hlo_cost.analyze(compiled.as_text())


# (backend name, combine_op) -> fitted (k_compute, k_scatter,
# sort_pass_frac); the one-shot startup calibration
# (AdaptiveConfig.calibrate) fills this once per process — the constants
# are compiler/backend properties, but the probe plans legal for a custom
# combine UDF differ from the monoid ones, so the fit is cached per
# combine class too. The periodic refresh loop stays future work.
_CALIBRATED: dict = {}


def _fit_constants(program, g: GraphStats, machine: MachineModel):
    """Refit (k_compute, k_scatter, sort_pass_frac) against the HLO
    analyzer. Two probe plans (a scatter-heavy and a sort-heavy group-by;
    sort-only for custom combine UDFs) are lowered at the capacities
    ``estimate`` assumes and measured with ``hlo_calibrate``. The model's
    flops are linear in k_compute and its bytes are affine in
    (k_scatter, sort_pass_frac), so unit-coefficient estimates turn the
    fit into one ratio and one 2x2 least-squares solve. Fitted values are
    clamped to sane ranges; a degenerate system keeps the defaults."""
    import numpy as np
    obs = Observation(frontier_density=1.0)
    # probes pin kernel_impl="ref": hlo_calibrate lowers on the host CPU
    # where the reference path runs, so the fit must price the same path
    # it measures (the kernel path's constants ride along unfitted)
    if program.combine_op == "custom":
        probes = [PhysicalPlan(join="full_outer", groupby="sort",
                               connector="partitioning",
                               sender_combine=False, kernel_impl="ref"),
                  PhysicalPlan(join="full_outer", groupby="sort",
                               connector="partitioning",
                               sender_combine=True, kernel_impl="ref")]
    else:
        probes = [PhysicalPlan(join="full_outer", groupby="scatter",
                               connector="partitioning",
                               sender_combine=False, kernel_impl="ref"),
                  PhysicalPlan(join="full_outer", groupby="sort",
                               connector="partitioning",
                               sender_combine=False, kernel_impl="ref")]
    P = max(g.n_partitions, 1)   # hlo measures all partitions; the model
    unit = lambda kc, ks, sp: dataclasses.replace(   # is per-partition
        machine, k_compute=kc, k_scatter=ks, sort_pass_frac=sp)
    kcs, rows, rhs = [], [], []
    for p in probes:
        meas = hlo_calibrate(program, p, g, obs)
        f_unit = estimate(p, g, obs, unit(1.0, 0.0, 0.0)).flops
        if f_unit > 0 and meas.flops > 0:
            kcs.append(meas.flops / P / f_unit)
        base = estimate(p, g, obs, unit(0.0, 0.0, 0.0)).bytes
        scat = estimate(p, g, obs, unit(0.0, 1.0, 0.0)).bytes - base
        srt = estimate(p, g, obs, unit(0.0, 0.0, 1.0)).bytes - base
        rows.append([scat, srt])
        rhs.append(meas.bytes / P - base)
    kc = (float(np.clip(np.mean(kcs), 0.5, 128.0)) if kcs
          else machine.k_compute)
    ks, sp = machine.k_scatter, machine.sort_pass_frac
    try:
        sol, *_ = np.linalg.lstsq(np.asarray(rows, float),
                                  np.asarray(rhs, float), rcond=None)
        if np.isfinite(sol).all():
            ks = float(np.clip(sol[0], 1.0, 64.0))
            sp = float(np.clip(sol[1], 0.02, 4.0))
    except np.linalg.LinAlgError:
        pass
    return kc, ks, sp


def calibrate_machine(program, g: GraphStats,
                      machine: MachineModel = DEFAULT_MACHINE,
                      *, refresh: bool = False) -> MachineModel:
    """Startup calibration (opt-in via ``AdaptiveConfig.calibrate``):
    lower probe supersteps on the CURRENT backend, measure them with the
    trip-count-aware HLO analyzer and return a MachineModel whose
    analytic constants are refit to what this backend's compiler
    actually emits, instead of the hand-tuned K_COMPUTE / K_SCATTER /
    SORT_PASS_FRAC. Compile-time heavy, so the fit is cached per backend
    for the life of the process; ``refresh=True`` bypasses the cache and
    refits in place — the periodic re-calibration path
    (``AdaptiveConfig.recalibrate_every``) uses it after a regrow /
    refit / plan switch changes the lowered shapes."""
    import jax
    key = (jax.default_backend(), program.combine_op)
    if refresh or key not in _CALIBRATED:
        _CALIBRATED[key] = _fit_constants(program, g, machine)
    kc, ks, sp = _CALIBRATED[key]
    return dataclasses.replace(machine, k_compute=kc, k_scatter=ks,
                               sort_pass_frac=sp)
