"""Mid-run replanning (the "runtime picks the right execution" story).

At each superstep boundary the host driver feeds the latest
``SuperstepStats`` record to an ``AdaptiveController``. When the observed
frontier density pushes a different plan below the current one in the cost
model — by a hysteresis margin, for ``patience`` consecutive supersteps,
and outside a post-switch ``cooldown`` — the controller proposes the
switch. The driver then migrates the in-flight ``MsgRel`` to the layout
the new plan's receiver expects (``migrate_msgs``, the connector analogue
of ``driver._regrow_msgs``'s capacity migration) and recompiles the
superstep. Hysteresis keeps recompiles amortized: a switch only pays off
over many supersteps, so we never thrash on noisy density estimates.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

from repro.core.plan import PhysicalPlan
from repro.core.relations import MsgRel
from repro.planner.cost import (DEFAULT_MACHINE, GraphStats, MachineModel,
                                Observation, estimate)
from repro.obs import explain
from repro.planner.optimizer import choose, rank
from repro.planner.stats import SuperstepStats


@dataclass(frozen=True)
class AdaptiveConfig:
    margin: float = 0.2      # candidate must model >=20% faster to switch
    patience: int = 2        # consecutive supersteps preferring it
    cooldown: int = 3        # min supersteps between switches
    min_superstep: int = 1   # never switch before this superstep
    # one-shot startup calibration: lower probe supersteps on the live
    # backend and refit the cost model's analytic constants against the
    # HLO analyzer before picking the initial plan (cost.calibrate_machine;
    # compile-time heavy, cached per backend)
    calibrate: bool = False
    # periodic re-calibration (requires calibrate=True): after a regrow /
    # frontier refit / plan switch changed the lowered shapes
    # (drivers call note_shape_change), refit K_COMPUTE / K_SCATTER /
    # SORT_PASS_FRAC against freshly lowered probes — at most once per
    # this many supersteps, so the probe compiles amortize. 0 = off.
    recalibrate_every: int = 0
    # EWMA smoothing factor for the measured readiness stall (the serial
    # inter-superstep leg): closes the measurement loop by calibrating
    # the cost model's analytic serial price to the stall the run
    # actually observes (Observation.serial_scale). 0 = loop open.
    stall_alpha: float = 0.3


# the measured-stall calibration multiplier is clamped: the stall also
# absorbs fold/GC noise (up) and warm-cache supersteps (down), and an
# unbounded ratio would let one outlier superstep flip plan ranking
_SCALE_MIN, _SCALE_MAX = 0.125, 8.0


class AdaptiveController:
    """Tracks the current plan and decides switches from observed stats."""

    def __init__(self, program, g: GraphStats, plan: PhysicalPlan,
                 config: AdaptiveConfig = AdaptiveConfig(), *,
                 machine: MachineModel = DEFAULT_MACHINE,
                 space_kw: Optional[dict] = None):
        self.program = program
        self.g = g
        self.plan = plan
        self.config = config
        self.machine = machine
        self.space_kw = space_kw or {}
        self.switches: list = []     # (superstep, old_plan, new_plan)
        self._want: Optional[PhysicalPlan] = None
        self._streak = 0
        self._last_switch = -10 ** 9
        self._shapes_dirty = False   # a regrow/refit/switch re-lowered
        self._last_recal = -10 ** 9  # superstep of the last refit
        self._stall_ewma: Optional[float] = None  # measured serial leg
        self._exchange_ewma: Optional[float] = None  # measured net leg

    # ---- hysteresis persistence (OOC checkpoint meta.json) -----------
    def state_dict(self) -> dict:
        """The mutable decision state a checkpoint must carry so a
        resume right before a pending switch does not re-pay the
        patience window: the candidate plan under consideration, its
        consecutive-superstep streak, and the cooldown clock."""
        return {
            "want": dataclasses.asdict(self._want)
            if self._want is not None else None,
            "streak": int(self._streak),
            "last_switch": int(self._last_switch),
            "last_recal": int(self._last_recal),
            "shapes_dirty": bool(self._shapes_dirty),
            "stall_ewma": (float(self._stall_ewma)
                           if self._stall_ewma is not None else None),
            "exchange_ewma": (float(self._exchange_ewma)
                              if self._exchange_ewma is not None
                              else None),
        }

    def load_state(self, state: dict):
        if not state:
            return
        want = state.get("want")
        self._want = PhysicalPlan(**want) if want else None
        self._streak = int(state.get("streak", 0))
        self._last_switch = int(state.get("last_switch", -10 ** 9))
        self._last_recal = int(state.get("last_recal", -10 ** 9))
        # a pending recalibration (shapes changed, window not yet
        # elapsed at checkpoint time) must survive the resume, or the
        # controller prices plans with stale constants forever
        self._shapes_dirty = bool(state.get("shapes_dirty", False))
        ewma = state.get("stall_ewma")
        self._stall_ewma = float(ewma) if ewma is not None else None
        xe = state.get("exchange_ewma")
        self._exchange_ewma = float(xe) if xe is not None else None

    # ---- periodic re-calibration -------------------------------------
    def note_shape_change(self):
        """Drivers call this on regrow / frontier refit / plan switch:
        the lowered superstep's shapes changed, so the fitted analytic
        constants may be stale."""
        self._shapes_dirty = True

    def maybe_recalibrate(self, program, superstep: int):
        """Re-run ``cost.calibrate_machine`` when (a) calibration is on,
        (b) ``recalibrate_every`` is set, (c) a shape change was noted
        since the last fit, and (d) at least ``recalibrate_every``
        supersteps passed since then — amortizing the probe compiles.
        Updates ``self.machine`` in place and returns the refit
        constants (for the drivers' event stream), else None."""
        cfg = self.config
        if not (cfg.calibrate and cfg.recalibrate_every > 0
                and self._shapes_dirty
                and superstep - self._last_recal >= cfg.recalibrate_every):
            return None
        from repro.planner.cost import calibrate_machine
        self.machine = calibrate_machine(program, self.g, self.machine,
                                         refresh=True)
        self._shapes_dirty = False
        self._last_recal = superstep
        constants = {"k_compute": self.machine.k_compute,
                     "k_scatter": self.machine.k_scatter,
                     "sort_pass_frac": self.machine.sort_pass_frac}
        if explain.enabled():
            explain.decision(superstep, "recalibrate", **constants)
        return constants

    def _update_stall_ewma(self, rec: SuperstepStats):
        """Fold a steady superstep's measured readiness stall into the
        EWMA. Recompile supersteps are skipped (their stall includes jit
        compile time, which would poison the calibration); so are
        records that never measured a stall (in-memory / barrier runs)."""
        if rec.recompiled or "readiness_stall_s" not in rec.extra:
            return
        stall = float(rec.extra["readiness_stall_s"])
        a = self.config.stall_alpha
        if a <= 0.0:
            return
        if self._stall_ewma is None:
            self._stall_ewma = stall
        else:
            self._stall_ewma = a * stall + (1.0 - a) * self._stall_ewma

    def _update_exchange_ewma(self, rec: SuperstepStats):
        """Network-axis mirror of ``_update_stall_ewma``: fold a steady
        superstep's measured all_to_all stage stall (the sharded
        driver's ``exchange_stall_s``) into the EWMA that calibrates the
        cost model's net leg. Recompile supersteps are skipped for the
        same reason."""
        if rec.recompiled or "exchange_stall_s" not in rec.extra:
            return
        a = self.config.stall_alpha
        if a <= 0.0:
            return
        stall = float(rec.extra["exchange_stall_s"])
        if self._exchange_ewma is None:
            self._exchange_ewma = stall
        else:
            self._exchange_ewma = (a * stall +
                                   (1.0 - a) * self._exchange_ewma)

    def _make_observation(self, rec: SuperstepStats, *,
                          bucket_cap: int = 0) -> Observation:
        """Lift a stats record into the cost model's ``Observation``.
        OOC drivers annotate their records with ooc=True plus the
        measured per-superstep change density (delta/full write-back
        byte ratio — prices the storage dimension), message
        COMBINABILITY (messages per distinct destination — prices the
        sender_combine dimension), mutation rate (host mutation-inbox
        traffic) and the disk tier's hit rate / spill flag (prices the
        disk-bandwidth axis). When a stall EWMA has accumulated, the
        serial inbox-rebuild leg gets a measured calibration multiplier:
        ``serial_scale`` = EWMA stall / analytic serial leg of the
        CURRENT plan, clamped — every candidate's serial price shifts by
        the same factor, so ranking stays plan-relative but the
        serial-vs-overlap tradeoff is priced at observed magnitude."""
        obs = Observation(frontier_density=rec.frontier_density,
                          messages=rec.messages, superstep=rec.superstep,
                          bucket_cap=bucket_cap,
                          change_density=rec.extra.get(
                              "change_density", 1.0),
                          ooc=bool(rec.extra.get("ooc", False)),
                          streaming=bool(rec.extra.get("streaming",
                                                       False)),
                          barrier_free=bool(rec.extra.get("barrier_free",
                                                          False)),
                          super_partitions=int(rec.extra.get(
                              "super_partitions", 1)),
                          readiness_stall_s=float(rec.extra.get(
                              "readiness_stall_s", 0.0)),
                          io_queue_depth=float(rec.extra.get(
                              "io_queue_depth", 0.0)),
                          combinability=max(
                              float(rec.extra.get("combinability", 1.0)),
                              1.0),
                          mutation_rate=float(
                              rec.extra.get("mutation_rate", 0.0)),
                          spilling=bool(rec.extra.get("spill", False)),
                          hit_rate=float(rec.extra.get("cache_hit_rate",
                                                       1.0)),
                          sharded=bool(rec.extra.get("sharded", False)),
                          n_workers=int(rec.extra.get("n_workers", 1)),
                          exchange_bytes=float(rec.extra.get(
                              "exchange_bytes", 0.0)),
                          exchange_stall_s=float(rec.extra.get(
                              "exchange_stall_s", 0.0)))
        if self._exchange_ewma is not None and obs.sharded:
            # net-axis closure: scale every candidate's exchange leg by
            # measured-stage-EWMA / the CURRENT plan's analytic net leg
            # (plan-relative ranking survives; magnitude tracks the
            # interconnect the run actually observes)
            cur_net = estimate(self.plan, self.g, obs,
                               self.machine).net_seconds
            if cur_net > 0.0:
                scale = self._exchange_ewma / cur_net
                scale = min(max(scale, _SCALE_MIN), _SCALE_MAX)
                obs = dataclasses.replace(
                    obs, net_scale=scale,
                    exchange_ewma_s=self._exchange_ewma)
        if self._stall_ewma is not None and obs.ooc:
            cur_serial = estimate(self.plan, self.g, obs,
                                  self.machine).serial_seconds
            if cur_serial > 0.0:
                scale = self._stall_ewma / cur_serial
                scale = min(max(scale, _SCALE_MIN), _SCALE_MAX)
                obs = dataclasses.replace(obs, serial_scale=scale,
                                          stall_ewma_s=self._stall_ewma)
        return obs

    def observe(self, rec: SuperstepStats, *,
                bucket_cap: int = 0) -> Optional[PhysicalPlan]:
        """Returns the new plan when a switch is warranted, else None.
        On a switch the controller's own `plan` is already updated.
        `bucket_cap` = the engine's live bucket capacity, flooring every
        candidate's modeled message capacity (buckets only grow)."""
        cfg = self.config
        self._update_stall_ewma(rec)
        self._update_exchange_ewma(rec)
        obs = self._make_observation(rec, bucket_cap=bucket_cap)
        ranked = rank(self.program, self.g, obs,
                      base=self.plan, machine=self.machine,
                      **self.space_kw)
        best, best_cost = ranked[0]
        cur_s = estimate(self.plan, self.g, obs,
                         self.machine).seconds(self.machine)
        if best == self.plan or \
                cur_s <= best_cost.seconds(self.machine) * (1 + cfg.margin):
            self._want, self._streak = None, 0
            return None
        if best != self._want:
            self._want, self._streak = best, 1
        else:
            self._streak += 1
        if (self._streak >= cfg.patience
                and rec.superstep >= cfg.min_superstep
                and rec.superstep - self._last_switch >= cfg.cooldown):
            old = self.plan
            self.plan = best
            self._last_switch = rec.superstep
            self._want, self._streak = None, 0
            self.switches.append((rec.superstep, old, best))
            if explain.enabled():
                # the losing candidates' prices: the full table the
                # controller just ranked, under the same observation
                from repro.obs.progress import fmt_plan
                explain.decision(
                    rec.superstep, "replan",
                    **{"from": fmt_plan(old)}, to=fmt_plan(best),
                    current_s=float(cur_s),
                    candidates=[{"plan": fmt_plan(p),
                                 "seconds": float(c.seconds(self.machine))}
                                for p, c in ranked])
            return best
        return None


def migrate_msgs(msg: MsgRel, old_plan: PhysicalPlan,
                 new_plan: PhysicalPlan, n_parts: int) -> MsgRel:
    """Migrate in-flight messages between connector layouts.

    The merging connector's receiver treats the message relation as
    n_parts presorted runs; messages produced under the plain partitioning
    connector (without a sender combine, which also leaves dst ascending)
    are unsorted within each run. Sorting each run once here is the
    one-off cost of the switch — every later superstep produces the new
    layout natively. No-op when the new receiver has no order assumption
    or the capacity is not run-structured (then the switch is vetoed by
    the caller anyway)."""
    import jax.numpy as jnp

    needs_runs = new_plan.connector == "partitioning_merging"
    already = (old_plan.connector == "partitioning_merging"
               or old_plan.sender_combine)
    if not needs_runs or already or msg.capacity % n_parts:
        return msg
    P, cap = msg.dst.shape
    C = cap // n_parts
    key = jnp.where(msg.valid, msg.dst,
                    jnp.iinfo(jnp.int32).max).reshape(P, n_parts, C)
    order = jnp.argsort(key, axis=-1, stable=True)
    take = lambda a: jnp.take_along_axis(
        a.reshape((P, n_parts, C) + a.shape[2:]),
        order[..., None] if a.ndim == 3 else order, axis=2
    ).reshape((P, cap) + a.shape[2:])
    return MsgRel(dst=take(msg.dst), payload=take(msg.payload),
                  valid=take(msg.valid))


def resolve_auto_plan(vert, program, *,
                      base: Optional[PhysicalPlan] = None,
                      adaptive: bool = True,
                      config: AdaptiveConfig = AdaptiveConfig(),
                      machine: MachineModel = DEFAULT_MACHINE,
                      space_kw: Optional[dict] = None,
                      g: Optional[GraphStats] = None,
                      obs0: Optional[Observation] = None,
                      ) -> Tuple[PhysicalPlan, Optional[AdaptiveController]]:
    """Entry point for drivers' ``plan="auto"``: pick the initial plan for
    superstep 0 (Pregel activates EVERY vertex, so density starts at 1.0)
    and, when `adaptive`, the controller that re-chooses mid-run.
    ``g`` supplies pre-computed graph statistics when no VertexRel exists
    (the OOC resume-from-spill-directory path). ``obs0`` overrides the
    superstep-0 observation — the sharded driver passes sharded=True /
    n_workers so the INITIAL pick already prices the network axis."""
    if base is not None and base.frontier_capacity != 1.0:
        # superstep 0 must cover all vertices under left-outer
        base = dataclasses.replace(base, frontier_capacity=1.0)
    if g is None:
        g = GraphStats.from_vertex(vert, program)
    plan, _ = choose(program, g,
                     obs0 or Observation(frontier_density=1.0),
                     base=base, machine=machine, **(space_kw or {}))
    if not adaptive:
        return plan, None
    return plan, AdaptiveController(program, g, plan, config,
                                    machine=machine, space_kw=space_kw)
