"""Plan-space enumeration + min-cost selection (the paper hand-tunes its
Section 5.3 plan choices per algorithm in Figure 9; this module derives
them from statistics instead).

The space is join x group-by x connector x sender_combine x storage from
``core/plan.py``, pruned by ``PhysicalPlan.validate`` (e.g. the scatter /
hash group-by cannot run a custom combine UDF). Storage defaults to the
base plan's policy — in-memory drivers never pay a write-back, so varying
it would only produce cost ties; the OOC driver passes
``storages=STORAGES`` to search both policies (its write-back is measured
and modeled). Partitioning and merge cadence stay inherited: they are
load-time choices, not per-superstep ones.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Tuple

from repro.core.plan import DEFAULT_PLAN, STORAGES, PhysicalPlan
from repro.planner.cost import (DEFAULT_MACHINE, GraphStats, MachineModel,
                                Observation, PlanCost, estimate)

JOINS = ("full_outer", "left_outer")
GROUPBYS = ("scatter", "sort")
CONNECTORS = ("partitioning", "partitioning_merging")


def plan_space(program, base: Optional[PhysicalPlan] = None, *,
               joins: Tuple[str, ...] = JOINS,
               groupbys: Tuple[str, ...] = GROUPBYS,
               connectors: Tuple[str, ...] = CONNECTORS,
               sender_combines: Tuple[bool, ...] = (True, False),
               storages: Optional[Tuple[str, ...]] = None,
               kernel_impls: Optional[Tuple[str, ...]] = None,
               ) -> Iterator[PhysicalPlan]:
    """Valid plans for `program`, varying the per-superstep dimensions of
    `base`. Invalid combinations are pruned via PhysicalPlan.validate.
    ``storages=None`` inherits the base plan's storage policy; the OOC
    driver passes ``core.plan.STORAGES`` to search both.
    ``kernel_impls=None`` inherits the base plan's kernel dispatch —
    "auto" already resolves per machine inside ``estimate``, so the extra
    dimension is only worth searching when a caller pins competing
    implementations explicitly (e.g. ("ref", "pallas"))."""
    base = base if base is not None else DEFAULT_PLAN
    storages = storages if storages is not None else (base.storage,)
    kernel_impls = (kernel_impls if kernel_impls is not None
                    else (base.kernel_impl,))
    for join in joins:
        for groupby in groupbys:
            for connector in connectors:
                for sc in sender_combines:
                    for storage in storages:
                        for kern in kernel_impls:
                            plan = dataclasses.replace(
                                base, join=join, groupby=groupby,
                                connector=connector, sender_combine=sc,
                                storage=storage, kernel_impl=kern)
                            try:
                                plan.validate(program.combine_op)
                            except ValueError:
                                continue
                            yield plan


def rank(program, g: GraphStats, obs: Observation, *,
         base: Optional[PhysicalPlan] = None,
         machine: MachineModel = DEFAULT_MACHINE,
         **space_kw) -> List[Tuple[PhysicalPlan, PlanCost]]:
    """All valid plans, cheapest first, with their modeled costs."""
    scored = [(p, estimate(p, g, obs, machine))
              for p in plan_space(program, base, **space_kw)]
    if not scored:
        raise ValueError(
            f"no valid physical plan for combine_op="
            f"{program.combine_op!r} in the restricted space {space_kw!r}")
    return sorted(scored, key=lambda pc: pc[1].seconds(machine))


def choose(program, g: GraphStats, obs: Observation, *,
           base: Optional[PhysicalPlan] = None,
           machine: MachineModel = DEFAULT_MACHINE,
           **space_kw) -> Tuple[PhysicalPlan, PlanCost]:
    """Min-cost plan for the given graph/program statistics."""
    return rank(program, g, obs, base=base, machine=machine, **space_kw)[0]
