"""Per-superstep statistics collection (paper Section 5.7).

The paper's statistics collector feeds two consumers: the user (progress
reporting) and the runtime (plan selection). The seed drivers grew ad-hoc
``stats`` dicts in ``driver.py`` and ``ooc.py``; this module replaces them
with one typed record so the adaptive optimizer (``planner.adaptive``) can
consume the same stream the drivers expose to callers.

``RunResult.stats`` stays a list of plain dicts (``SuperstepStats.as_dict``)
for backward compatibility with benchmarks and tests that index by key.

Driver-specific observables travel in ``extra``: the out-of-core driver
annotates every record with ``ooc=True``, cumulative ``delta_bytes`` /
``full_bytes`` (what the delta vs full write-back policies ship
device->host), ``change_density`` (their per-superstep ratio — the signal
behind the planner's storage dimension), the active ``storage`` policy,
the executor mode (``streaming``) and the pipeline's wall-time split:
``dispatch_s`` (H2D upload + step enqueue), ``collect_wait_s`` (blocked
on device results — the compute-bound share) and ``commit_s`` (host-side
write-back), so benchmarks can report how close a superstep runs to the
``max(compute, transfer)`` streaming bound (``benchmarks/out_of_core.py``
aggregates them into ``BENCH_ooc.json``).

The disk tier (storage/ buffer cache) adds ``spill`` (True when a memory
budget forces paging), the pager ``cache_hit_rate`` and
``spill_read_bytes`` / ``spill_write_bytes`` (the disk-bandwidth axis of
the cost model, archived per run in ``BENCH_storage.json``), plus
``pager_resident_bytes`` / ``pager_peak_bytes`` (what the budget test
asserts against). All pager counters are PER-SUPERSTEP interval
counters (``BufferPool.take_interval`` resets them at every record), so
the planner conditions on current — not cumulative — paging behavior.
``combinability`` (messages per distinct destination, measured from the
collected bucket blocks at commit time) and ``mutation_rate`` (host
mutation-inbox proposals per live vertex) close the remaining replan
loops: they price the sender_combine dimension and the mutation traffic.

The barrier-free superstep pipeline adds ``barrier_free``,
``super_partitions``, ``readiness_stall_s`` (the device-idle gap between
a superstep's last collect and the next superstep's first dispatch — the
serial leg the rolling frontier minimizes; ``BENCH_pipeline.json``
reports it per executor) and the background I/O engine's
``io_queue_depth`` / ``io_queue_depth_mean``.
``AdaptiveController.observe`` lifts all of these into the cost model's
``Observation``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

# message wire format: int32 dst + float32 payload per dim + bool valid
_DST_BYTES = 4
_PAYLOAD_BYTES = 4
_VALID_BYTES = 1


def msg_bytes(messages: int, msg_dims: int) -> int:
    """Live bytes crossing the exchange for `messages` messages."""
    return messages * (_DST_BYTES + _PAYLOAD_BYTES * msg_dims + _VALID_BYTES)


@dataclass
class SuperstepStats:
    """One superstep (or one driver event: regrow / frontier-refit /
    plan-switch) of a run. Event records carry ``event`` + ``extra`` only."""
    superstep: int
    active: int = 0
    messages: int = 0
    frontier_density: float = 0.0   # active / LIVE vertices (not slots)
    bytes_exchanged: int = 0        # live message bytes, all partitions
    wall_s: float = 0.0
    recompiled: bool = False        # wall time includes a jit compile
    event: Optional[str] = None
    extra: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        if self.event is not None:
            d = {"superstep": self.superstep, "event": self.event}
            d.update(self.extra)
            return d
        d = {"superstep": self.superstep, "active": self.active,
             "messages": self.messages, "wall_s": self.wall_s,
             "recompiled": self.recompiled,
             "frontier_density": self.frontier_density,
             "bytes_exchanged": self.bytes_exchanged}
        d.update(self.extra)
        return d


class StatsCollector:
    """Builds ``SuperstepStats`` records from driver observables and keeps
    the run history the adaptive controller windows over."""

    def __init__(self, *, n_partitions: int, vertex_capacity: int,
                 msg_dims: int, n_vertices: Optional[int] = None,
                 metrics=None):
        """n_vertices = LIVE vertex count; densities are fractions of it
        (slot capacities carry slack, so slot fractions would understate
        liveness). Falls back to total slots when unknown.

        ``metrics`` is an optional ``repro.obs.metrics.MetricsRegistry``;
        when set, every ``record`` merges the registry's per-superstep
        interval snapshot into ``extra["metrics"]`` so the counters the
        runtime and storage layers maintain travel on the same stream as
        the driver observables."""
        self.n_partitions = n_partitions
        self.vertex_capacity = vertex_capacity
        self.msg_dims = msg_dims
        self.n_vertices = n_vertices
        self.metrics = metrics
        self.records: List[SuperstepStats] = []
        from repro.runtime.failure import StragglerMonitor
        self.stragglers = StragglerMonitor()

    @property
    def total_vertices(self) -> int:
        if self.n_vertices:
            return self.n_vertices
        return max(self.n_partitions * self.vertex_capacity, 1)

    def record(self, superstep: int, *, active: int, messages: int,
               wall_s: float, recompiled: bool = False,
               **extra) -> SuperstepStats:
        if self.metrics is not None:
            m = self.metrics.interval()
            if m:
                extra["metrics"] = m
        if not recompiled:
            # straggler detection sees only steady-state supersteps — a
            # jit compile would always look like a 10x straggler
            flag = self.stragglers.observe(superstep, wall_s)
            if flag is not None:
                extra["straggler"] = flag
        rec = SuperstepStats(
            superstep=superstep, active=active, messages=messages,
            frontier_density=min(active / self.total_vertices, 1.0),
            bytes_exchanged=msg_bytes(messages, self.msg_dims),
            wall_s=wall_s, recompiled=recompiled, extra=extra)
        self.records.append(rec)
        return rec

    def event(self, superstep: int, event: str, **extra) -> SuperstepStats:
        rec = SuperstepStats(superstep=superstep, event=event, extra=extra)
        self.records.append(rec)
        return rec

    def supersteps(self) -> List[SuperstepStats]:
        return [r for r in self.records if r.event is None]

    def window(self, k: int) -> List[SuperstepStats]:
        """Last k non-event records."""
        return self.supersteps()[-k:]

    def dicts(self) -> List[dict]:
        return [r.as_dict() for r in self.records]
