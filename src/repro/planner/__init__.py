"""Adaptive cost-based plan optimizer.

The paper's engine exposes interchangeable physical plans (Section 5.3's
joins x group-bys x connectors) but leaves the choice to the user; this
subsystem makes the runtime pick — and mid-run re-pick — the plan:

* ``stats``     one typed per-superstep record + collector (Section 5.7's
                statistics collector, generalized from the drivers' ad-hoc
                dicts)
* ``cost``      analytical per-superstep cost model over the plan space,
                tied to the dry-run machine model and HLO-calibratable
* ``optimizer`` enumerate + prune + min-cost plan for given statistics
* ``adaptive``  mid-run replanning with hysteresis at superstep boundaries

Entry points: ``run_host(..., plan="auto")``, ``run_jit(..., plan="auto")``,
``run_out_of_core(..., plan="auto")`` and ``launch/pregel_run.py
--auto-plan``.
"""
from repro.planner.adaptive import (AdaptiveConfig, AdaptiveController,
                                    migrate_msgs, resolve_auto_plan)
from repro.planner.cost import (DEFAULT_MACHINE, EMULATED_MACHINE,
                                GraphStats, MachineModel, Observation,
                                PlanCost, bucket_cap, calibrate_machine,
                                estimate, hlo_calibrate,
                                refit_frontier_cap)
from repro.planner.optimizer import choose, plan_space, rank
from repro.planner.stats import StatsCollector, SuperstepStats, msg_bytes

__all__ = [
    "AdaptiveConfig", "AdaptiveController", "migrate_msgs",
    "resolve_auto_plan", "DEFAULT_MACHINE", "EMULATED_MACHINE",
    "GraphStats", "MachineModel",
    "Observation", "PlanCost", "bucket_cap", "calibrate_machine",
    "estimate", "hlo_calibrate",
    "refit_frontier_cap", "choose", "plan_space", "rank", "StatsCollector",
    "SuperstepStats", "msg_bytes",
]
