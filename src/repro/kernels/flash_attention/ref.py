"""jnp oracle: plain (masked) softmax attention for one (batch*head)
slice batch. q: (B, Sq, hd), k/v: (B, Sk, hd)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] + (Sk - Sq) >= jnp.arange(Sk)[None]
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", w, v.astype(jnp.float32)
                      ).astype(q.dtype)
