"""Jit'd wrapper over (B, S, H, hd) tensors with GQA head grouping."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "impl"))
def flash_attention(q, k, v, *, causal: bool = True, impl: str = "auto"):
    """q: (B, S, H, hd); k/v: (B, S, KV, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1) \
        .reshape(B * H, S, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1) \
        .reshape(B * H, S, hd)
    impl_r = backend.resolve(impl)
    if impl_r == "ref":
        of = attention_ref(qf, kf, vf, causal=causal)
    else:
        of = flash_attention_pallas(qf, kf, vf, causal=causal,
                                    interpret=(impl_r != "pallas_tpu"))
    return of.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
