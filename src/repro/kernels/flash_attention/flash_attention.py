"""Pallas TPU flash attention (forward).

Grid (BH, nQ, nK) with the K axis innermost (sequential on TPU): online-
softmax state (m, l, acc) lives in VMEM scratch and is carried across K
tiles; the output tile is finalized when the last K tile has been folded.
Causal tiles above the diagonal are skipped with @pl.when (no FLOPs — this
is the kernel-level answer to the XLA path's masked-out waste).

BlockSpecs: q (1, BQ, hd), k/v (1, BK, hd), out (1, BQ, hd) — hd stays
whole (128/256-lane aligned for the MXU); BQ/BK default 512 keeps
q/k/v/acc tiles ~(512x128)x4B within VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, n_k: int, block_q: int, block_k: int,
            sk_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[:] = jnp.full_like(m_scr, NEG_INF)
        l_scr[:] = jnp.zeros_like(l_scr)
        acc_scr[:] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # tile fully above the diagonal -> skip entirely
        run = (ki * block_k) <= (qi * block_q + block_q - 1 + sk_offset)

    @pl.when(run)
    def _attend():
        q = q_ref[0].astype(jnp.float32)            # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)            # (BK, hd)
        v = v_ref[0].astype(jnp.float32)
        scale = q.shape[-1] ** -0.5
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (BQ, BK)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0) + sk_offset
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[:]                            # (BQ, 1)
        l_prev = l_scr[:]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[:] = acc_scr[:] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[:] = m_new
        l_scr[:] = l_new

    @pl.when(ki == n_k - 1)
    def _fini():
        o_ref[0] = (acc_scr[:] /
                    jnp.maximum(l_scr[:], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = True):
    """q: (B, Sq, hd); k/v: (B, Sk, hd) — B is batch*heads flattened.
    Sq <= Sk supported (decode-suffix layout: query positions are the LAST
    Sq positions of the key range)."""
    B, Sq, hd = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    n_q = pl.cdiv(Sq, bq)
    n_k = pl.cdiv(Sk, bk)
    kern = functools.partial(_kernel, causal=causal, n_k=n_k, block_q=bq,
                             block_k=bk, sk_offset=Sk - Sq)
    return pl.pallas_call(
        kern,
        grid=(B, n_q, n_k),
        in_specs=[pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
                  pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0))],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
