"""jnp oracle: edge-parallel message generation (gather + scale).

payload[e] = values[edge_src[e]] * edge_val[e]  (masked for pad edges)

This is the Pregelix send hot loop — for PageRank it is exactly the SpMV
contribution push.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def edge_gather_ref(values: jax.Array, edge_src: jax.Array,
                    edge_val: jax.Array) -> jax.Array:
    """values: (N, V); edge_src: (E,) int32 (-1 pad); edge_val: (E,).
    -> (E, V)."""
    ok = edge_src >= 0
    g = values[edge_src.clip(0)]
    return jnp.where(ok[:, None], g * edge_val[:, None], 0.0)
