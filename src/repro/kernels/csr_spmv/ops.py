"""Jit-compatible wrapper: lays out src-sorted edges into row-block-aligned
tiles (host-side, cached per graph) and runs the Pallas gather."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import backend
from repro.kernels.csr_spmv.csr_spmv import edge_gather_pallas
from repro.kernels.csr_spmv.ref import edge_gather_ref


def plan_layout(edge_src: np.ndarray, n_rows: int, *, block_m: int = 512,
                block_r: int = 256):
    """Host-side layout plan (one-off per graph): pad each row-block's edge
    range to a BM multiple. Returns (perm (Ep,), tile_row (n_tiles,),
    inverse scatter (E,))."""
    edge_src = np.asarray(edge_src)
    E = len(edge_src)
    order = np.argsort(np.where(edge_src >= 0, edge_src, n_rows),
                       kind="stable")
    src_sorted = edge_src[order]
    n_blocks = (n_rows + block_r - 1) // block_r
    blk_ids = np.where(src_sorted >= 0, src_sorted // block_r, n_blocks)
    counts = np.bincount(blk_ids, minlength=n_blocks + 1)[:n_blocks]
    padded = ((counts + block_m - 1) // block_m) * block_m
    padded = np.maximum(padded, 0)
    p_starts = np.concatenate([[0], np.cumsum(padded)[:-1]])
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    Ep = int(np.sum(padded)) or block_m
    perm = np.full(Ep, -1, np.int64)          # padded slot -> orig edge
    valid_e = src_sorted >= 0
    blk = np.minimum(blk_ids, n_blocks - 1)
    pos = np.arange(E) - starts[blk] + p_starts[blk]
    perm[pos[valid_e]] = order[valid_e]
    tile_row = np.repeat(np.arange(n_blocks), padded // block_m) \
        .astype(np.int32)
    if len(tile_row) == 0:
        tile_row = np.zeros(Ep // block_m, np.int32)
    return perm, tile_row


def layout_capacity(n_edge_slots: int, n_rows: int, *, block_m: int = 512,
                    block_r: int = 256) -> int:
    """Worst-case padded edge-slot count of ``plan_layout``: each non-empty
    row block wastes < block_m slots, so E rounded up plus one block per
    row block always fits. A function of SHAPES only — no edge data."""
    n_blocks = (n_rows + block_r - 1) // block_r
    cap = ((n_edge_slots + block_m - 1) // block_m + n_blocks) * block_m
    return max(cap, block_m)


def plan_layout_fixed(edge_src: np.ndarray, n_rows: int, *,
                      block_m: int = 512, block_r: int = 256):
    """``plan_layout`` padded to shapes that depend ONLY on
    (len(edge_src), n_rows, block_m, block_r) — never on where the edges
    actually point. Equal-shape edge blocks therefore produce equal-shape
    layouts, which is what lets a layout be a TRACED argument of one
    shared jitted superstep (the out-of-core driver reuses a single
    compiled step across super-partitions, each with its own layout).
    Pad slots carry perm = -1 (dropped by the scatter-back) and
    tile_row = 0 (the pad tiles gather nothing: their src rows are -1).
    perm is int32 (the int64 of plan_layout would be silently downcast
    under jit with x64 disabled)."""
    perm, tile_row = plan_layout(edge_src, n_rows, block_m=block_m,
                                 block_r=block_r)
    cap = layout_capacity(len(edge_src), n_rows, block_m=block_m,
                          block_r=block_r)
    perm_f = np.full(cap, -1, np.int32)
    perm_f[:len(perm)] = perm
    tile_f = np.zeros(cap // block_m, np.int32)
    tile_f[:len(tile_row)] = tile_row
    return perm_f, tile_f


def edge_gather(values, edge_src, edge_val, *, layout=None,
                impl: str = "auto", block_m: int = 512,
                block_r: int = 256):
    """values: (N, V); edge_src: (E,); edge_val: (E,) -> (E, V)."""
    impl_r = backend.resolve(impl)
    if impl_r == "ref" or layout is None:
        return edge_gather_ref(values, edge_src, edge_val)
    perm, tile_row = layout
    N, V = values.shape
    n_pad = (-N) % block_r
    vals = jnp.pad(values, ((0, n_pad), (0, 0)))
    es = jnp.where(perm >= 0, edge_src[perm.clip(0)], -1).astype(jnp.int32)
    ev = jnp.where(perm >= 0, edge_val[perm.clip(0)], 0.0)
    out_p = edge_gather_pallas(vals, es, ev, jnp.asarray(tile_row),
                               block_m=block_m, block_r=block_r,
                               interpret=(impl_r != "pallas_tpu"))
    # scatter back to original edge order
    out = jnp.zeros((edge_src.shape[0], V), jnp.float32)
    ok = perm >= 0
    return out.at[jnp.where(ok, perm, 0)].add(
        jnp.where(ok[:, None], out_p, 0.0))
