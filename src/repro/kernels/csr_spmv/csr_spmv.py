"""Pallas TPU kernel: row-blocked edge gather (CSR message generation).

Edges are src-sorted; the host (ops.py) pads each BR-row block's edge range
to a BM multiple so every edge tile touches exactly one row block. The
tile -> row-block map arrives via scalar prefetch and selects the vertex
value block in the BlockSpec index_map. Inside the kernel the gather is a
ONE-HOT MATMUL — (BM x BR) @ (BR x V) on the MXU — the TPU-native answer
to random access (no scalar gathers in the inner loop).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(tile_row_ref, src_ref, val_ref, values_ref, out_ref, *,
            block_r: int):
    t = pl.program_id(0)
    r0 = tile_row_ref[t] * block_r
    src = src_ref[:]                       # (BM, 1) int32, -1 pads
    ev = val_ref[:].astype(jnp.float32)    # (BM, 1)
    vals = values_ref[0].astype(jnp.float32)  # (BR, V)
    local = src[:, 0] - r0                 # (BM,)
    ok = (src[:, 0] >= 0)
    onehot = (jax.lax.broadcasted_iota(jnp.int32,
                                       (src.shape[0], block_r), 1)
              == local[:, None]) & ok[:, None]
    g = jax.lax.dot_general(onehot.astype(jnp.float32), vals,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    out_ref[:] = g * ev


def edge_gather_pallas(values: jax.Array, edge_src: jax.Array,
                       edge_val: jax.Array, tile_row: jax.Array, *,
                       block_m: int = 512, block_r: int = 256,
                       interpret: bool = True):
    """values: (N, V) (N a multiple of block_r); edge_src: (Ep,) src-sorted,
    padded so tile i only touches rows of block tile_row[i]. -> (Ep, V)."""
    Ep = edge_src.shape[0]
    N, V = values.shape
    BM = min(block_m, Ep)
    n_tiles = pl.cdiv(Ep, BM)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((BM, 1), lambda i, tr: (i, 0)),
                  pl.BlockSpec((BM, 1), lambda i, tr: (i, 0)),
                  pl.BlockSpec((1, block_r, V), lambda i, tr: (tr[i], 0, 0))],
        out_specs=pl.BlockSpec((BM, V), lambda i, tr: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_r=block_r),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Ep, V), jnp.float32),
        interpret=interpret,
    )(tile_row, edge_src[:, None], edge_val[:, None],
      values.reshape(N // block_r, block_r, V))
