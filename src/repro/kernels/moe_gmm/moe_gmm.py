"""Pallas TPU kernel: expert-grouped matmul (MegaBlocks-style).

The MoE dispatch is the paper's sort-based group-by: tokens arrive sorted
by expert id. ops.py pads each expert's group to a BM multiple so every
token tile belongs to EXACTLY ONE expert; the tile->expert map is passed
via scalar prefetch (PrefetchScalarGridSpec) and selects the weight block
in the BlockSpec index_map — no gather in the kernel, the MXU sees plain
(BM x d) @ (d x BF) tiles.

Grid: (n_token_tiles, n_f_tiles); f innermost. d is kept whole per tile
(d <= 8192 -> (512 x 8192) bf16 q-tile = 8 MiB VMEM; for larger d drop BM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(tile_eid_ref, x_ref, w_ref, o_ref):
    x = x_ref[:].astype(jnp.float32)       # (BM, d)
    w = w_ref[0].astype(jnp.float32)       # (d, BF)
    o_ref[:] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def grouped_matmul_pallas(tokens: jax.Array, w: jax.Array,
                          tile_eid: jax.Array, *, block_m: int = 512,
                          block_f: int = 512, interpret: bool = True):
    """tokens: (Tp, d) expert-sorted AND group-padded so tile i belongs
    entirely to expert tile_eid[i]; w: (E, d, f). -> (Tp, f)."""
    Tp, d = tokens.shape
    E, _, f = w.shape
    BM = min(block_m, Tp)
    BF = min(block_f, f)
    n_m = pl.cdiv(Tp, BM)
    n_f = pl.cdiv(f, BF)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_m, n_f),
        in_specs=[pl.BlockSpec((BM, d), lambda i, j, eid: (i, 0)),
                  pl.BlockSpec((1, d, BF),
                               lambda i, j, eid: (eid[i], 0, j))],
        out_specs=pl.BlockSpec((BM, BF), lambda i, j, eid: (i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tp, f), tokens.dtype),
        interpret=interpret,
    )(tile_eid, tokens, w)
