"""Jit'd wrapper: group-pads expert-sorted tokens to tile multiples and
dispatches to the Pallas grouped matmul (or the jnp oracle)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels.moe_gmm.moe_gmm import grouped_matmul_pallas
from repro.kernels.moe_gmm.ref import grouped_matmul_ref


def _group_pad(tokens, group_sizes, block_m: int):
    """Scatter expert-sorted tokens into per-expert BM-aligned slabs.
    Returns (padded (Tp,d), tile_eid (Tp/BM,), gather_idx (T,))."""
    T, d = tokens.shape
    E = group_sizes.shape[0]
    padded_sizes = ((group_sizes + block_m - 1) // block_m) * block_m
    p_starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                jnp.cumsum(padded_sizes)[:-1]])
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(group_sizes)[:-1]])
    Tp = T + E * (block_m - 1) + block_m  # static upper bound
    Tp = ((Tp + block_m - 1) // block_m) * block_m
    eid = jnp.searchsorted(jnp.cumsum(group_sizes),
                           jnp.arange(T), side="right").clip(0, E - 1)
    pos = jnp.arange(T) - starts[eid] + p_starts[eid]
    padded = jnp.zeros((Tp, d), tokens.dtype).at[pos].set(tokens)
    n_tiles = Tp // block_m
    tile_starts = jnp.arange(n_tiles) * block_m
    tile_eid = jnp.searchsorted(jnp.cumsum(padded_sizes), tile_starts,
                                side="right").clip(0, E - 1)
    return padded, tile_eid.astype(jnp.int32), pos


@functools.partial(jax.jit, static_argnames=("impl", "block_m"))
def grouped_matmul(tokens, w, group_sizes, *, impl: str = "auto",
                   block_m: int = 512):
    """tokens: (T, d) expert-sorted; w: (E, d, f); group_sizes: (E,)."""
    impl_r = backend.resolve(impl)
    if impl_r == "ref":
        return grouped_matmul_ref(tokens, w, group_sizes)
    block_m = min(block_m, max(tokens.shape[0], 8))
    padded, tile_eid, pos = _group_pad(tokens, group_sizes, block_m)
    out_p = grouped_matmul_pallas(padded, w, tile_eid, block_m=block_m,
                                  interpret=(impl_r != "pallas_tpu"))
    return out_p[pos]
