"""jnp oracle: grouped matmul over expert-sorted tokens.

tokens: (T, d) sorted by expert id; w: (E, d, f); group_sizes: (E,).
out[t] = tokens[t] @ w[expert_of(t)].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def expert_of_tokens(group_sizes: jax.Array, T: int) -> jax.Array:
    ends = jnp.cumsum(group_sizes)
    return jnp.searchsorted(ends, jnp.arange(T), side="right")


def grouped_matmul_ref(tokens: jax.Array, w: jax.Array,
                       group_sizes: jax.Array) -> jax.Array:
    T, d = tokens.shape
    E = w.shape[0]
    eid = expert_of_tokens(group_sizes, T).clip(0, E - 1)
    wt = w[eid]                                    # (T, d, f)
    return jnp.einsum("td,tdf->tf", tokens.astype(jnp.float32),
                      wt.astype(jnp.float32)).astype(tokens.dtype)
