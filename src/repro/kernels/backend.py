"""Kernel backend selection + the engine-facing kernel entry points.

``resolve`` maps the ``PhysicalPlan.kernel_impl`` knob (auto | ref |
pallas | pallas_tpu) to a concrete implementation, honouring the
``REPRO_KERNEL_IMPL`` env override so CI can force a path without code
changes. The rest of this module is the thin layer the superstep engine
calls: a fixed-shape gather layout planner, the partition-flattened edge
gather, and the blocked segmented fold — each shaped so that
``kernel_impl="ref"`` and ``"pallas"`` are bit-for-bit identical.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

VALID_IMPLS = ("auto", "ref", "pallas", "pallas_tpu")
ENV_VAR = "REPRO_KERNEL_IMPL"

# Engine block sizes, shared with the planner's cost model. BM is the
# edge-stream tile; BR is the gather's row-block (the one-hot matmul
# contraction width).
GATHER_BLOCK_M = 512
GATHER_BLOCK_R = 256
COMBINE_BLOCK_M = 512


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve(impl: str, *, tpu: Optional[bool] = None) -> str:
    """Map a kernel_impl knob to a concrete impl in {ref, pallas,
    pallas_tpu}.

    - ``auto``: pallas_tpu on TPU, ref elsewhere (interpret mode is an
      emulator, not a fast path — see the cost model's INTERPRET_PENALTY).
    - ``pallas``: compiled on TPU, interpret mode elsewhere.
    - ``pallas_tpu``: forced TPU lowering (fails off-TPU; debugging knob).
    - ``tpu``: overrides backend detection — the planner resolves per
      MACHINE MODEL (``MachineModel.mxu``), not per host process.
    - ``$REPRO_KERNEL_IMPL`` overrides ``impl`` itself, including "auto".
    """
    env = os.environ.get(ENV_VAR)
    if env:
        if env not in VALID_IMPLS:
            raise ValueError(
                f"{ENV_VAR}={env!r}: expected one of {VALID_IMPLS}")
        impl = env
    if impl not in VALID_IMPLS:
        raise ValueError(
            f"kernel_impl={impl!r}: expected one of {VALID_IMPLS}")
    if tpu is None:
        tpu = on_tpu()
    if impl == "auto":
        return "pallas_tpu" if tpu else "ref"
    if impl == "pallas" and tpu:
        return "pallas_tpu"
    return impl


def wants_edge_layout(plan) -> bool:
    """True when the resolved kernel path consumes a gather layout.
    full_outer only: left_outer compacts the edge stream data-dependently
    each superstep, which the host-planned fixed tiling cannot express —
    there the gather stays on the jnp path (the segmented fold and the
    fused pack still kick in)."""
    return resolve(plan.kernel_impl) != "ref" and plan.join == "full_outer"


def plan_edge_layout(edge_src, n_rows: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side gather layout for a (P, Ep) edge_src block over (P, n_rows)
    value rows. Partitions are flattened into ONE (P*Ep,) edge stream over
    P*n_rows rows — ``pallas_call`` must not be vmapped (the batching rule
    would regrid the kernel and break its sequential-carry assumption), so
    a single kernel invocation serves the whole block. Uses
    ``plan_layout_fixed``: the result shape depends only on the block's
    shape, so every equal-shape super-partition yields an equal-shape
    layout and the OOC driver can pass per-super-partition layouts through
    one shared jitted superstep as traced arguments."""
    from repro.kernels.csr_spmv.ops import plan_layout_fixed
    edge_src = np.asarray(edge_src)
    P, Ep = edge_src.shape
    off = (np.arange(P, dtype=np.int64) * n_rows)[:, None]
    flat = np.where(edge_src >= 0, edge_src + off, -1).reshape(-1)
    return plan_layout_fixed(flat, P * n_rows, block_m=GATHER_BLOCK_M,
                             block_r=GATHER_BLOCK_R)


def edge_gather_values(values, edge_src, layout, *, impl_r: str):
    """Gather ``values[p, edge_src[p, e]]`` per edge via the csr_spmv
    one-hot-MXU-matmul kernel. values: (P, Np, V); edge_src: (P, Ep),
    -1 = invalid; layout from ``plan_edge_layout``. Returns (P, Ep, V);
    invalid lanes read 0.0 (masked downstream by the edge gate, exactly
    like the clip-gather's arbitrary row-0 reads on the jnp path).

    Bit-for-bit discipline: a finite value survives the one-hot matmul
    exactly (one 1.0*x product plus exact 0.0 additions; -0.0 may
    normalize to +0.0, which still compares equal). Non-finite values
    would be destroyed by the 0*x products (0*inf = nan), so they ride a
    side "class" channel (0 finite / 1 +inf / 2 -inf / 3 nan) and are
    re-materialized after the gather."""
    from repro.kernels.csr_spmv import ops as csr_ops
    P, Np, V = values.shape
    Ep = edge_src.shape[1]
    vals = values.reshape(P * Np, V)
    finite = jnp.isfinite(vals)
    cls = jnp.where(finite, 0.0,
                    jnp.where(jnp.isnan(vals), 3.0,
                              jnp.where(vals > 0, 1.0, 2.0)))
    packed = jnp.concatenate([jnp.where(finite, vals, 0.0), cls], axis=-1)
    off = (jnp.arange(P, dtype=jnp.int32) * Np)[:, None]
    flat_src = jnp.where(edge_src >= 0, edge_src + off, -1).reshape(-1)
    ones = jnp.ones(flat_src.shape, jnp.float32)
    out = csr_ops.edge_gather(packed, flat_src, ones, layout=layout,
                              impl=impl_r, block_m=GATHER_BLOCK_M,
                              block_r=GATHER_BLOCK_R)
    g, c = out[:, :V], out[:, V:]
    g = jnp.where(c == 1.0, jnp.inf,
                  jnp.where(c == 2.0, -jnp.inf,
                            jnp.where(c == 3.0, jnp.nan, g)))
    return g.reshape(P, Ep, V)


def sorted_segment_fold(keys, payload, valid, op: str, *, impl_r: str):
    """Inclusive segmented fold over a key-sorted stream — the engine's
    sender-combine reduction. keys: (M,) ascending, invalid rows keyed
    int32.max at the tail; payload: (M, D). Returns (folded (M, D),
    is_last (M,) — already masked by valid).

    Both impls execute the SAME blocked reduction order (per-tile
    Hillis-Steele doubling + sequential tile carry): "ref" through
    ``segment_combine_blocked`` jnp, "pallas" through the Pallas kernel
    (interpret mode off-TPU). M is padded to a tile multiple here so the
    kernel never sees a ragged tile — one code path, bit-for-bit parity
    for float sums included."""
    from repro.kernels.segment_combine.ref import segment_combine_blocked
    from repro.kernels.segment_combine.segment_combine import \
        segment_combine_pallas
    M, D = payload.shape
    BM = min(COMBINE_BLOCK_M, M)
    pad = (-M) % BM
    if pad:
        big = jnp.iinfo(jnp.int32).max
        keys = jnp.concatenate([keys, jnp.full((pad,), big, keys.dtype)])
        payload = jnp.concatenate(
            [payload, jnp.zeros((pad, D), payload.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
    if impl_r == "ref":
        folded, is_last = segment_combine_blocked(keys, payload, valid, op,
                                                  block_m=BM)
    else:
        folded, is_last = segment_combine_pallas(
            keys, payload, valid, op, block_m=BM,
            interpret=(impl_r != "pallas_tpu"))
    return folded[:M], is_last[:M]
