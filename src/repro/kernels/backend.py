"""Kernel backend selection: Pallas compiled on TPU, interpret-mode
elsewhere, or the jnp reference."""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve(impl: str) -> str:
    """impl in {auto, ref, pallas, pallas_tpu}."""
    if impl == "auto":
        return "pallas_tpu" if on_tpu() else "ref"
    if impl == "pallas" and on_tpu():
        return "pallas_tpu"
    return impl
