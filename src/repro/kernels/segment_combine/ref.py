"""Pure-jnp oracle for the segmented combine (sorted-run group-by fold).

Given payloads sorted by segment id, computes the inclusive segmented fold
and marks the last row of each segment (the group's aggregate). This is the
receiver-side group-by inner loop of the Pregelix dataflow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

OPS = {
    "sum": (lambda a, b: a + b, 0.0),
    "min": (jnp.minimum, jnp.inf),
    "max": (jnp.maximum, -jnp.inf),
}


def segment_combine_ref(seg_ids: jax.Array, payload: jax.Array,
                        valid: jax.Array, op: str = "sum"):
    """seg_ids: (M,) int32 sorted; payload: (M, D); valid: (M,).
    -> (folded (M, D), is_last (M,)) where folded[i] is the running
    aggregate of payload over seg_ids == seg_ids[i] up to i."""
    fn, ident = OPS[op]
    M, D = payload.shape
    x = jnp.where(valid[:, None], payload, ident).astype(jnp.float32)
    starts = jnp.concatenate([jnp.ones((1,), bool),
                              seg_ids[1:] != seg_ids[:-1]])

    def comb(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb[:, None], vb, fn(va, vb))

    _, folded = jax.lax.associative_scan(comb, (starts, x))
    is_last = jnp.concatenate([seg_ids[1:] != seg_ids[:-1],
                               jnp.ones((1,), bool)]) & valid
    return folded, is_last


def segment_combine_blocked(seg_ids: jax.Array, payload: jax.Array,
                            valid: jax.Array, op: str = "sum", *,
                            block_m: int = 512):
    """Plain-jnp re-execution of the Pallas kernel's EXACT computation
    order: per-tile Hillis-Steele doubling scan + sequential carry splice
    across tiles (`segment_combine.py:_kernel`).

    `segment_combine_ref` above is the readable oracle, but its
    `associative_scan` brackets float sums differently, so its low bits
    can differ from the kernel's. The engine's ``kernel_impl="ref"``
    sender-combine path folds through THIS function so that "ref" and
    "pallas" runs stay bit-for-bit identical even for ``op="sum"``
    (min/max are reduction-order-insensitive either way).

    A ragged final tile is padded with (int32.max, IDENT); the in-tile
    scan is causal (row i only reads rows < i), so pad rows at the tail
    cannot perturb real rows.

    The inter-tile carry is a `lax.scan` (NOT a Python loop): the trace
    stays O(1) in n_tiles, matching the kernel's sequential grid — an
    unrolled loop makes XLA compile time explode at real graph sizes
    (webmap-tiny already has ~270 tiles per partition)."""
    from repro.kernels.segment_combine.segment_combine import (
        IDENT, _fn, _segmented_scan_tile)
    M, D = payload.shape
    BM = min(block_m, M)
    big = jnp.iinfo(jnp.int32).max
    seg2 = jnp.where(valid, seg_ids, big)[:, None]
    pay = jnp.where(valid[:, None], payload, IDENT[op]).astype(jnp.float32)
    n_tiles = -(-M // BM)
    pad = n_tiles * BM - M
    segp = jnp.concatenate([seg2, jnp.full((pad, 1), big, seg2.dtype)])
    payp = jnp.concatenate([pay, jnp.full((pad, D), IDENT[op], pay.dtype)])
    fn = _fn(op)

    def tile(carry, sp):
        prev_seg, prev_val = carry
        seg, payt = sp
        v, boundary = _segmented_scan_tile(seg, payt, op)
        first = jnp.cumsum(boundary.astype(jnp.int32), axis=0) == 1
        cont = (seg == prev_seg) & first
        v = jnp.where(cont, fn(prev_val, v), v)
        return (seg[-1, 0], v[-1:, :]), v

    carry0 = (jnp.int32(-2), jnp.full((1, D), IDENT[op], jnp.float32))
    _, outs = jax.lax.scan(tile, carry0,
                           (segp.reshape(n_tiles, BM, 1),
                            payp.reshape(n_tiles, BM, D)))
    folded = outs.reshape(n_tiles * BM, D)[:M]
    s = seg2[:, 0]
    is_last = jnp.concatenate([s[1:] != s[:-1],
                               jnp.ones((1,), bool)]) & valid
    return folded, is_last
