"""Pure-jnp oracle for the segmented combine (sorted-run group-by fold).

Given payloads sorted by segment id, computes the inclusive segmented fold
and marks the last row of each segment (the group's aggregate). This is the
receiver-side group-by inner loop of the Pregelix dataflow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

OPS = {
    "sum": (lambda a, b: a + b, 0.0),
    "min": (jnp.minimum, jnp.inf),
    "max": (jnp.maximum, -jnp.inf),
}


def segment_combine_ref(seg_ids: jax.Array, payload: jax.Array,
                        valid: jax.Array, op: str = "sum"):
    """seg_ids: (M,) int32 sorted; payload: (M, D); valid: (M,).
    -> (folded (M, D), is_last (M,)) where folded[i] is the running
    aggregate of payload over seg_ids == seg_ids[i] up to i."""
    fn, ident = OPS[op]
    M, D = payload.shape
    x = jnp.where(valid[:, None], payload, ident).astype(jnp.float32)
    starts = jnp.concatenate([jnp.ones((1,), bool),
                              seg_ids[1:] != seg_ids[:-1]])

    def comb(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb[:, None], vb, fn(va, vb))

    _, folded = jax.lax.associative_scan(comb, (starts, x))
    is_last = jnp.concatenate([seg_ids[1:] != seg_ids[:-1],
                               jnp.ones((1,), bool)]) & valid
    return folded, is_last
