"""Pallas TPU kernel: segmented combine over sorted runs.

Tiling: 1-D grid over row tiles of BM message rows (the payload minor dim D
stays whole in VMEM — message payloads are narrow). The segmented inclusive
fold INSIDE a tile is a Hillis-Steele log-step scan (elementwise ops +
static shifts only — Mosaic-friendly, no gathers). A VMEM scratch carries
(last segment id, running aggregate) across tiles; TPU grid iteration is
sequential over the last grid axis, which makes the carry legal.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

IDENT = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}


def _fn(op):
    return {"sum": lambda a, b: a + b, "min": jnp.minimum,
            "max": jnp.maximum}[op]


def _segmented_scan_tile(seg, x, op):
    """In-tile segmented inclusive scan, log-step network. seg: (BM, 1)
    int32, x: (BM, D) f32."""
    fn = _fn(op)
    BM = x.shape[0]
    boundary = jnp.concatenate(
        [jnp.ones((1, 1), jnp.bool_), seg[1:] != seg[:-1]], axis=0)
    f = boundary
    v = x
    steps = int(math.ceil(math.log2(max(BM, 2))))
    for k in range(steps):
        sh = 1 << k
        pv = jnp.concatenate([jnp.full((sh, v.shape[1]), IDENT[op],
                                       v.dtype), v[:-sh]], axis=0)
        pf = jnp.concatenate([jnp.ones((sh, 1), jnp.bool_), f[:-sh]],
                             axis=0)
        v = jnp.where(f, v, fn(pv, v))
        f = f | pf
    return v, boundary


def _kernel(seg_ref, pay_ref, out_ref, last_ref, carry_seg, carry_val, *,
            op: str, n_tiles: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        carry_seg[0] = jnp.int32(-2)
        carry_val[:] = jnp.full_like(carry_val, IDENT[op])

    seg = seg_ref[:]                      # (BM, 1) int32
    x = pay_ref[:].astype(jnp.float32)    # (BM, D)
    v, boundary = _segmented_scan_tile(seg, x, op)
    # splice the carry into the first segment of this tile
    prev_seg = carry_seg[0]
    prev_val = carry_val[:]               # (1, D)
    first_seg_len_mask = jnp.cumsum(boundary.astype(jnp.int32), axis=0) == 1
    cont = (seg == prev_seg) & first_seg_len_mask
    v = jnp.where(cont, _fn(op)(prev_val, v), v)
    # last row of each segment within the tile
    nxt = jnp.concatenate([seg[1:] != seg[:-1],
                           jnp.ones((1, 1), jnp.bool_)], axis=0)
    out_ref[:] = v
    last_ref[:] = nxt.astype(jnp.int32)
    carry_seg[0] = seg[-1, 0]
    carry_val[:] = v[-1:, :]

    @pl.when(t == n_tiles - 1)
    def _fini():
        pass


def segment_combine_pallas(seg_ids: jax.Array, payload: jax.Array,
                           valid: jax.Array, op: str = "sum", *,
                           block_m: int = 512, interpret: bool = True):
    """seg_ids: (M,) sorted int32; payload: (M, D); -> (folded (M, D),
    is_last (M,)). Rows with valid=False must be sorted to the tail with
    seg_id == int32.max (ops.py guarantees this)."""
    M, D = payload.shape
    BM = min(block_m, M)
    n_tiles = pl.cdiv(M, BM)
    seg2 = jnp.where(valid, seg_ids,
                     jnp.iinfo(jnp.int32).max)[:, None]  # (M,1)
    pay = jnp.where(valid[:, None], payload,
                    IDENT[op]).astype(jnp.float32)
    folded, _ = pl.pallas_call(
        functools.partial(_kernel, op=op, n_tiles=n_tiles),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((BM, 1), lambda t: (t, 0)),
                  pl.BlockSpec((BM, D), lambda t: (t, 0))],
        out_specs=[pl.BlockSpec((BM, D), lambda t: (t, 0)),
                   pl.BlockSpec((BM, 1), lambda t: (t, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, D), jnp.float32),
                   jax.ShapeDtypeStruct((M, 1), jnp.int32)],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32),
                        pltpu.VMEM((1, D), jnp.float32)],
        interpret=interpret,
    )(seg2, pay)
    # segment-last markers are GLOBAL (a segment may span tiles — the
    # carry gives the true last row the full fold); computed elementwise
    # here, not in the kernel
    s = seg2[:, 0]
    is_last = jnp.concatenate([s[1:] != s[:-1],
                               jnp.ones((1,), bool)]) & valid
    return folded, is_last
