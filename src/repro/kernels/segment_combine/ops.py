"""Jit'd wrapper: sorts-to-tail invalid rows and dispatches to the Pallas
kernel on TPU (interpret-mode on CPU) or the jnp oracle."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels.segment_combine.ref import segment_combine_ref
from repro.kernels.segment_combine.segment_combine import \
    segment_combine_pallas


@functools.partial(jax.jit, static_argnames=("op", "impl", "block_m"))
def segment_combine(seg_ids, payload, valid, op: str = "sum",
                    impl: str = "auto", block_m: int = 512):
    impl = backend.resolve(impl)
    if impl == "ref":
        return segment_combine_ref(seg_ids, payload, valid, op)
    return segment_combine_pallas(seg_ids, payload, valid, op,
                                  block_m=block_m,
                                  interpret=(impl != "pallas_tpu"))
