"""Model assembly: stage-planned scan-over-layers.

Depth is organized into *stages*; each stage is a ``lax.scan`` over `repeats`
copies of a *period* of heterogeneous sublayers (so HLO size is O(period),
not O(depth)):

* uniform archs              -> one stage, period = 1 sublayer
* gemma3 (5 local : 1 global)-> period of 6 attention sublayers, 8 repeats
* llama4 (MoE every 2nd)     -> period of (dense, moe), 24 repeats
* zamba2 (shared attn / 6)   -> period of 6 mamba sublayers + the weight-
                                SHARED attention block applied after each
                                period (one param copy, closure-captured)

Three execution paths share the parameter tree: ``forward_train`` (full
sequence), ``forward_prefill`` (full sequence, emits KV/SSM caches), and
``forward_decode`` (single token against caches; ring buffers for local
attention; optional int8-quantized KV).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (apply_attention, apply_attention_decode,
                                    attn_specs)
from repro.models.layers import (apply_mlp, apply_norm, embed, embed_specs,
                                 mlp_specs, norm_specs, unembed)
from repro.models.moe import apply_moe, moe_specs
from repro.models.param import (Spec, abstract, materialize, pspecs,
                                sanitize, stack)

# ---------------------------------------------------------------------------
# Stage plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SubLayer:
    kind: str                 # "attn_global" | "attn_local" | "ssm"
    moe: bool = False
    shared_after: bool = False


def stage_plan(cfg: ModelConfig):
    """-> list of (period: tuple[SubLayer], repeats: int)."""
    L = cfg.num_layers
    kinds = [cfg.layer_kind(i) for i in range(L)]
    moes = [cfg.is_moe_layer(i) for i in range(L)]
    period = len(cfg.attn.pattern)
    if cfg.moe is not None:
        period = max(period, cfg.moe.every_k_layers)
    if cfg.shared_attn_every:
        period = max(period, cfg.shared_attn_every)
    stages = []
    n_full = L // period
    if n_full:
        subs = tuple(
            SubLayer(kinds[i], moes[i],
                     shared_after=(cfg.shared_attn_every > 0
                                   and (i + 1) % cfg.shared_attn_every == 0))
            for i in range(period))
        stages.append((subs, n_full))
    rem = L - n_full * period
    if rem:
        tail = tuple(SubLayer(kinds[n_full * period + i],
                              moes[n_full * period + i])
                     for i in range(rem))
        stages.append((tail, 1))
    return stages


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

FSDP_THRESHOLD_BYTES = 2 << 30  # params/TP16 above this -> FSDP over "data"


def use_fsdp(cfg: ModelConfig) -> bool:
    return cfg.param_count() * 2 / 16 > FSDP_THRESHOLD_BYTES


def resolve_profile(cfg: ModelConfig, profile: str = "auto") -> str:
    """Sharding profile:
    * "zero": pure ZeRO-3 data parallelism over the flattened (data, model)
      axes — params/grads/moments 256-way sharded on their largest dim, no
      tensor parallelism. Right for small/mid archs at global_batch=256
      (1 sequence per chip; no TP collectives on the critical path).
    * "tp": tensor parallelism on "model" (+ FSDP over "data" for archs
      whose params/16 exceed ~2 GiB). Right for the big archs and for
      serving (ZeRO's per-layer weight all-gather is wrong for decode).
    """
    if profile != "auto":
        return profile
    # NOTE: "zero" is kept as an experimental profile. Measured on the
    # dry-run, GSPMD hoists the whole-tree all-gather out of the layer scan
    # (152 GiB/dev for h2o-danube) instead of gathering per-layer inside the
    # loop, so the production default is TP(+FSDP) with gradient
    # accumulation. Recorded in EXPERIMENTS.md §Perf (refuted hypothesis).
    return "tp"


def _zero_transform(tree):
    """Replace every Spec's sharding with ZeRO-3: largest dim sharded over
    ("data","model") when divisible by 256, else ("data",) / ("model",),
    else replicated."""
    import numpy as np

    def f(s: Spec):
        spec = [None] * len(s.shape)
        if int(np.prod(s.shape)) >= 4096:
            for axes, n in ((("data", "model"), 256), (("data",), 16)):
                placed = False
                for j in sorted(range(len(s.shape)),
                                key=lambda k: -s.shape[k]):
                    if s.shape[j] % n == 0 and s.shape[j] > 1:
                        spec[j] = axes if len(axes) > 1 else axes[0]
                        placed = True
                        break
                if placed:
                    break
        return Spec(s.shape, P(*spec), s.init, s.fan_in, s.dtype)

    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, Spec))


def _add_fsdp(tree):
    """ZeRO-3/FSDP: insert "data" into the largest unsharded dim of big
    matrices (weights are all-gathered per scan step; grads reduce-scatter)."""
    def f(s: Spec):
        import numpy as np
        if int(np.prod(s.shape)) * 2 < (1 << 20) or "data" in jax.tree.leaves(tuple(s.pspec)):
            return s
        dims = sorted(range(len(s.shape)), key=lambda i: -s.shape[i])
        spec = list(s.pspec) + [None] * (len(s.shape) - len(s.pspec))
        for i in dims:
            if spec[i] is None and s.shape[i] % 16 == 0:
                spec[i] = "data"
                return Spec(s.shape, P(*spec), s.init, s.fan_in, s.dtype)
        return s
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, Spec))


def _sublayer_specs(cfg: ModelConfig, sub: SubLayer) -> dict:
    d = cfg.d_model
    if sub.kind == "ssm":
        s = {"norm1": norm_specs(d, cfg.norm)}
        s["ssm"] = (ssm_mod.mamba1_specs(cfg) if cfg.ssm.kind == "mamba1"
                    else ssm_mod.mamba2_specs(cfg))
        return s
    s = {"norm1": norm_specs(d, cfg.norm), "attn": attn_specs(cfg)}
    if sub.moe:
        s["norm2"] = norm_specs(d, cfg.norm)
        s["moe"] = moe_specs(cfg)
    elif cfg.d_ff:
        s["norm2"] = norm_specs(d, cfg.norm)
        s["mlp"] = mlp_specs(d, cfg.d_ff)
    return s


def _shared_block_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {"norm1": norm_specs(d, cfg.norm), "attn": attn_specs(cfg),
            "norm2": norm_specs(d, cfg.norm), "mlp": mlp_specs(d, cfg.d_ff)}


def model_specs(cfg: ModelConfig, profile: str = "auto") -> dict:
    profile = resolve_profile(cfg, profile)
    fsdp = profile == "tp" and use_fsdp(cfg)
    zero = profile == "zero"
    tr = _zero_transform if zero else (_add_fsdp if fsdp else (lambda t: t))
    specs: dict = {"embed": embed_specs(cfg),
                   "final_norm": norm_specs(cfg.d_model, cfg.norm)}
    if cfg.frontend == "audio":
        # frontend is a stub: inputs are precomputed frame embeddings
        specs["embed"] = ({"unembed": Spec((cfg.d_model, cfg.vocab_size),
                                           P(None, "model"),
                                           fan_in=cfg.d_model)})
    if cfg.frontend == "vision":
        specs["vision_proj"] = {"w": Spec((cfg.d_model, cfg.d_model),
                                          P(None, None),
                                          fan_in=cfg.d_model)}
    stages = []
    for subs, repeats in stage_plan(cfg):
        period = {f"sub{i}": _sublayer_specs(cfg, s)
                  for i, s in enumerate(subs)}
        stages.append(stack(sanitize(tr(period)), repeats))
    specs["stages"] = stages
    if cfg.shared_attn_every:
        specs["shared_block"] = tr(_shared_block_specs(cfg))
    specs["embed"] = tr(specs["embed"])
    return sanitize(specs)


def init_params(cfg: ModelConfig, rng, profile: str = "auto") -> Any:
    return materialize(model_specs(cfg, profile), rng, jnp.dtype(cfg.dtype))


def abstract_params(cfg: ModelConfig, profile: str = "auto") -> Any:
    return abstract(model_specs(cfg, profile), jnp.dtype(cfg.dtype))


def param_pspecs(cfg: ModelConfig, profile: str = "auto") -> Any:
    return pspecs(model_specs(cfg, profile))


# ---------------------------------------------------------------------------
# Forward: train
# ---------------------------------------------------------------------------


def _apply_sub(p, x, sub: SubLayer, cfg: ModelConfig, positions, *,
               causal_mode, dp_spec, qkv_blocks=(512, 512)):
    aux = jnp.zeros((), jnp.float32)
    if sub.kind == "ssm":
        h = apply_norm(p["norm1"], x, cfg.norm)
        f = (ssm_mod.apply_mamba1 if cfg.ssm.kind == "mamba1"
             else ssm_mod.apply_mamba2)
        return x + f(p["ssm"], h, cfg), aux
    h = apply_norm(p["norm1"], x, cfg.norm)
    a, _ = apply_attention(p["attn"], h, cfg, local=(sub.kind == "attn_local"),
                           positions=positions, causal_mode=causal_mode,
                           q_block=qkv_blocks[0], kv_block=qkv_blocks[1],
                           dp_spec=dp_spec)
    x = x + a
    if sub.moe:
        h = apply_norm(p["norm2"], x, cfg.norm)
        mo, aux = apply_moe(p["moe"], h, cfg, dp_spec=dp_spec)
        x = x + mo
    elif cfg.d_ff:
        h = apply_norm(p["norm2"], x, cfg.norm)
        x = x + apply_mlp(p["mlp"], h)
    return x, aux


def _apply_shared(shared_p, x, cfg: ModelConfig, positions, *, causal_mode,
                  dp_spec=P("data")):
    h = apply_norm(shared_p["norm1"], x, cfg.norm)
    a, _ = apply_attention(shared_p["attn"], h, cfg, local=False,
                           positions=positions, causal_mode=causal_mode,
                           dp_spec=dp_spec)
    x = x + a
    h = apply_norm(shared_p["norm2"], x, cfg.norm)
    return x + apply_mlp(shared_p["mlp"], h)


def _embed_inputs(params, batch, cfg: ModelConfig):
    if cfg.frontend == "audio":
        return batch["frames"].astype(jnp.dtype(cfg.dtype))
    x = embed(params["embed"], batch["tokens"])
    if cfg.frontend == "vision":
        pe = jnp.einsum("bfd,de->bfe",
                        batch["patch_embeds"].astype(x.dtype),
                        params["vision_proj"]["w"])
        F = pe.shape[1]
        x = jnp.concatenate([pe, x[:, F:]], axis=1)
    return x


def forward_train(params, batch, cfg: ModelConfig, *,
                  causal_mode: str = "masked_full", remat: bool = True,
                  dp_spec=P("data")):
    """-> (hidden (B,S,d), aux_loss)."""
    x = _embed_inputs(params, batch, cfg)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    aux_total = jnp.zeros((), jnp.float32)
    for (subs, repeats), stage_p in zip(stage_plan(cfg), params["stages"]):
        def body(carry, layer_p, subs=subs):
            x, aux = carry
            for i, sub in enumerate(subs):
                x, a = _apply_sub(layer_p[f"sub{i}"], x, sub, cfg, positions,
                                  causal_mode=causal_mode, dp_spec=dp_spec)
                aux = aux + a
                if sub.shared_after:
                    x = _apply_shared(params["shared_block"], x, cfg,
                                      positions, causal_mode=causal_mode,
                                      dp_spec=dp_spec)
            return (x, aux), None

        f = jax.checkpoint(body) if remat else body
        (x, aux_total), _ = jax.lax.scan(f, (x, aux_total), stage_p)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux_total


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def _cache_len_for(cfg: ModelConfig, sub: SubLayer, max_len: int) -> int:
    if sub.kind == "attn_local":
        return min(cfg.attn.window, max_len)  # ring buffer
    return max_len


def init_caches(cfg: ModelConfig, batch: int, max_len: int, *,
                quantize: bool = False):
    """Abstract-friendly cache init (pure shape math)."""
    dt = jnp.dtype(cfg.dtype)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    stages = []
    for subs, repeats in stage_plan(cfg):
        period = {}
        for i, sub in enumerate(subs):
            if sub.kind == "ssm":
                st = (ssm_mod.mamba1_init_state(cfg, batch, dt)
                      if cfg.ssm.kind == "mamba1"
                      else ssm_mod.mamba2_init_state(cfg, batch, dt))
            else:
                sl = _cache_len_for(cfg, sub, max_len)
                if quantize:
                    st = {"k8": jnp.zeros((batch, sl, kv, hd), jnp.int8),
                          "v8": jnp.zeros((batch, sl, kv, hd), jnp.int8),
                          "ks": jnp.zeros((batch, sl, kv), jnp.float32),
                          "vs": jnp.zeros((batch, sl, kv), jnp.float32)}
                else:
                    st = {"k": jnp.zeros((batch, sl, kv, hd), dt),
                          "v": jnp.zeros((batch, sl, kv, hd), dt)}
            period[f"sub{i}"] = st
            if sub.shared_after:
                period[f"shared{i}"] = {
                    "k": jnp.zeros((batch, max_len, kv, hd), dt),
                    "v": jnp.zeros((batch, max_len, kv, hd), dt)}
        stages.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (repeats,) + a.shape), period))
    return stages


def _quantize_kv(k):
    s = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1) / 127.0
    s = jnp.maximum(s, 1e-8)
    return jnp.round(k.astype(jnp.float32) / s[..., None]).astype(jnp.int8), s


def _dequantize_kv(k8, s, dt):
    return (k8.astype(jnp.float32) * s[..., None]).astype(dt)


def _attn_decode_cached(p, x, cache, cache_len, cfg, *, local):
    if "k8" in cache:
        dt = jnp.dtype(cfg.dtype)
        k = _dequantize_kv(cache["k8"], cache["ks"], dt)
        v = _dequantize_kv(cache["v8"], cache["vs"], dt)
        out, k, v = apply_attention_decode(p, x, k, v, cache_len, cfg,
                                           local=local)
        k8, ks = _quantize_kv(k)
        v8, vs = _quantize_kv(v)
        return out, {"k8": k8, "v8": v8, "ks": ks, "vs": vs}
    out, k, v = apply_attention_decode(p, x, cache["k"], cache["v"],
                                       cache_len, cfg, local=local)
    return out, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Forward: prefill
# ---------------------------------------------------------------------------


def forward_prefill(params, batch, cfg: ModelConfig, *,
                    causal_mode: str = "masked_full", dp_spec=P("data")):
    """Full-sequence forward emitting caches. -> (last_hidden (B,1,d),
    caches). Emitted KV caches are sequence-sharded on "model" (context-
    parallel cache layout, matching the decode-side input shardings)."""
    from repro.models.moe import _maybe_constrain

    def _kv(t):
        sl = t.shape[1]
        return _maybe_constrain(
            t, P(dp_spec[0], "model" if sl % 16 == 0 else None, None, None))

    x = _embed_inputs(params, batch, cfg)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    caches = []
    for (subs, repeats), stage_p in zip(stage_plan(cfg), params["stages"]):
        def body(x, layer_p, subs=subs):
            out_caches = {}
            for i, sub in enumerate(subs):
                p = layer_p[f"sub{i}"]
                if sub.kind == "ssm":
                    x, st = _prefill_ssm(p["ssm"], apply_norm(
                        p["norm1"], x, cfg.norm), x, cfg)
                    out_caches[f"sub{i}"] = st
                else:
                    h = apply_norm(p["norm1"], x, cfg.norm)
                    local = sub.kind == "attn_local"
                    a, (k, v) = apply_attention(
                        p["attn"], h, cfg, local=local, positions=positions,
                        causal_mode=causal_mode)
                    x = x + a
                    sl = _cache_len_for(cfg, sub, S)
                    out_caches[f"sub{i}"] = {"k": _kv(k[:, -sl:]),
                                             "v": _kv(v[:, -sl:])}
                    if sub.moe:
                        h = apply_norm(p["norm2"], x, cfg.norm)
                        mo, _ = apply_moe(p["moe"], h, cfg)
                        x = x + mo
                    elif cfg.d_ff:
                        h = apply_norm(p["norm2"], x, cfg.norm)
                        x = x + apply_mlp(p["mlp"], h)
                if sub.shared_after:
                    sp = params["shared_block"]
                    h = apply_norm(sp["norm1"], x, cfg.norm)
                    a, (k, v) = apply_attention(sp["attn"], h, cfg,
                                                local=False,
                                                positions=positions,
                                                causal_mode=causal_mode)
                    x = x + a
                    h = apply_norm(sp["norm2"], x, cfg.norm)
                    x = x + apply_mlp(sp["mlp"], h)
                    out_caches[f"shared{i}"] = {"k": _kv(k), "v": _kv(v)}
            return x, out_caches

        x, stage_caches = jax.lax.scan(body, x, stage_p)
        caches.append(stage_caches)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x[:, -1:], caches


def _prefill_ssm(p, h, x, cfg):
    """Run an SSM sublayer over the full sequence and return its decode
    state (conv tail + final ssm state)."""
    s = cfg.ssm
    if s.kind == "mamba1":
        y, st = ssm_mod.apply_mamba1_with_state(p, h, cfg)
    else:
        y, st = ssm_mod.apply_mamba2_with_state(p, h, cfg)
    return x + y, st


# ---------------------------------------------------------------------------
# Forward: decode (single token)
# ---------------------------------------------------------------------------


def forward_decode(params, tokens, caches, cache_len, cfg: ModelConfig):
    """tokens: (B,1) int32. -> (logits (B,1,V), new_caches)."""
    x = embed(params["embed"], tokens)
    new_caches = []
    for si, ((subs, repeats), stage_p) in enumerate(
            zip(stage_plan(cfg), params["stages"])):
        def body(x, inp, subs=subs):
            layer_p, layer_c = inp
            new_c = {}
            for i, sub in enumerate(subs):
                p = layer_p[f"sub{i}"]
                c = layer_c[f"sub{i}"]
                if sub.kind == "ssm":
                    h = apply_norm(p["norm1"], x, cfg.norm)
                    f = (ssm_mod.apply_mamba1_decode if cfg.ssm.kind ==
                         "mamba1" else ssm_mod.apply_mamba2_decode)
                    y, st = f(p["ssm"], h, c, cfg)
                    x = x + y
                    new_c[f"sub{i}"] = st
                else:
                    h = apply_norm(p["norm1"], x, cfg.norm)
                    local = sub.kind == "attn_local"
                    a, st = _attn_decode_cached(p["attn"], h, c, cache_len,
                                                cfg, local=local)
                    x = x + a
                    new_c[f"sub{i}"] = st
                    if sub.moe:
                        h = apply_norm(p["norm2"], x, cfg.norm)
                        mo, _ = apply_moe(p["moe"], h, cfg)
                        x = x + mo
                    elif cfg.d_ff:
                        h = apply_norm(p["norm2"], x, cfg.norm)
                        x = x + apply_mlp(p["mlp"], h)
                if sub.shared_after:
                    sp = params["shared_block"]
                    h = apply_norm(sp["norm1"], x, cfg.norm)
                    a, st = _attn_decode_cached(
                        sp["attn"], h, layer_c[f"shared{i}"], cache_len, cfg,
                        local=False)
                    x = x + a
                    h = apply_norm(sp["norm2"], x, cfg.norm)
                    x = x + apply_mlp(sp["mlp"], h)
                    new_c[f"shared{i}"] = st
            return x, new_c

        x, new_stage_c = jax.lax.scan(body, x, (stage_p, caches[si]))
        new_caches.append(new_stage_c)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x)
    return logits, new_caches
