from repro.models.model import (abstract_params, forward_decode,
                                forward_prefill, forward_train, init_caches,
                                init_params, model_specs, param_pspecs,
                                stage_plan, use_fsdp)
from repro.models.steps import (chunked_xent, loss_fn, make_decode_step,
                                make_prefill_step, make_train_step)

__all__ = [
    "abstract_params", "forward_decode", "forward_prefill", "forward_train",
    "init_caches", "init_params", "model_specs", "param_pspecs",
    "stage_plan", "use_fsdp", "chunked_xent", "loss_fn", "make_decode_step",
    "make_prefill_step", "make_train_step",
]
