"""Training / serving step functions (the units the dry-run lowers).

* ``train_step``   — fwd (remat scan) + chunked-vocab CE + bwd + AdamW.
* ``prefill_step`` — full-sequence forward emitting KV/SSM caches + first
                     sampled token.
* ``decode_step``  — one token against the caches (greedy).

The vocab-chunked cross entropy bounds the logits working set to
(B, chunk, V) instead of (B, S, V) — required for the 262k-vocab archs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import unembed
from repro.models.model import (forward_decode, forward_prefill,
                                forward_train, init_caches)
from repro.optim import adamw_update, clip_by_global_norm, cosine_schedule

AUX_LOSS_WEIGHT = 0.01


def chunked_xent(embed_params, hidden, labels, *, chunk: int = 512):
    """hidden: (B,S,d); labels: (B,S) int32 (-1 = masked).
    Returns (sum_nll, n_tokens)."""
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    nc = S // chunk
    h = hidden.reshape(B, nc, chunk, d).swapaxes(0, 1)
    y = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(carry, inp):
        hs, ys = inp
        logits = unembed(embed_params, hs).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ys, 0)[..., None], axis=-1)[..., 0]
        mask = (ys >= 0).astype(jnp.float32)
        nll = (lse - gold) * mask
        tot, cnt = carry
        return (tot + nll.sum(), cnt + mask.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (h, y))
    return tot, cnt


def loss_fn(params, batch, cfg: ModelConfig, *, causal_mode="masked_full",
            dp_spec=P("data")):
    hidden, aux = forward_train(params, batch, cfg, causal_mode=causal_mode,
                                dp_spec=dp_spec)
    tot, cnt = chunked_xent(params["embed"], hidden, batch["labels"])
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + AUX_LOSS_WEIGHT * aux, (loss, aux)


def make_train_step(cfg: ModelConfig, *, peak_lr=3e-4, warmup=100,
                    total_steps=10000, causal_mode="masked_full",
                    dp_spec=P("data"), microbatches: int = 1):
    """microbatches > 1 = gradient accumulation: the global batch is split
    into M sequential microbatches (bounds activation memory for the big
    archs at global_batch=256)."""

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (_, (ce, aux)), grads = grad_fn(params, batch, cfg,
                                            causal_mode=causal_mode,
                                            dp_spec=dp_spec)
        else:
            from repro.models.moe import _maybe_constrain
            mb = jax.tree.map(
                lambda x: _maybe_constrain(
                    x.reshape((microbatches, x.shape[0] // microbatches)
                              + x.shape[1:]),
                    P(None, dp_spec[0], *([None] * (x.ndim - 2)))),
                batch)

            def accum(carry, microbatch):
                g_acc, ce_acc, aux_acc = carry
                (_, (ce, aux)), g = grad_fn(params, microbatch, cfg,
                                            causal_mode=causal_mode,
                                            dp_spec=dp_spec)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, ce_acc + ce, aux_acc + aux), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, ce, aux), _ = jax.lax.scan(
                accum, (g0, jnp.zeros(()), jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            ce, aux = ce / microbatches, aux / microbatches
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = cosine_schedule(opt_state["step"], peak_lr=peak_lr,
                             warmup=warmup, total=total_steps)
        params, opt_state = adamw_update(grads, opt_state, params, lr=lr)
        metrics = {"loss": ce, "aux": aux, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, causal_mode="masked_full",
                      dp_spec=P("data")):
    if cfg.is_encoder:
        # encoder-only archs have no decode: "prefill" is the full forward
        # (per-position classification), no caches emitted
        def encode_step(params, batch):
            hidden, _ = forward_train(params, batch, cfg, remat=False,
                                      dp_spec=dp_spec)
            tot, cnt = chunked_xent(params["embed"], hidden,
                                    batch["labels"])
            return tot / jnp.maximum(cnt, 1.0)

        return encode_step

    def prefill_step(params, batch):
        last_h, caches = forward_prefill(params, batch, cfg,
                                         causal_mode=causal_mode,
                                         dp_spec=dp_spec)
        logits = unembed(params["embed"], last_h)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, tokens, caches, cache_len):
        logits, caches = forward_decode(params, tokens, caches, cache_len,
                                        cfg)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    return decode_step
