"""Attention: GQA with blocked online-softmax (flash-style, pure JAX),
sliding-window (truly sub-quadratic), and single-token decode vs a KV cache.

The blocked implementations are the jnp oracles for the Pallas
``flash_attention`` kernel; on TPU the kernel substitutes for the inner loop.

Physical-plan notes (paper analogy): the q-block x kv-block schedule is the
dataflow's tiling choice; ``causal_mode`` switches between the baseline
masked-full schedule and the recursive-halving schedule (a §Perf hillclimb
lever that removes ~2x masked-out FLOP waste).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import rope
from repro.models.param import Spec

NEG_INF = -1e30


def attn_specs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    if h % 16:
        # §Perf hc1: heads don't divide the TP axis (yi 56H, llama4 40H).
        # The naive fallback (shard head_dim) makes GSPMD psum ATTENTION
        # SCORES inside every (q-block x kv-block x layer x microbatch)
        # tile — measured 10,977s of collective per step on yi train_4k.
        # Fix: replicate the projections (FSDP still shards storage) and
        # run SEQUENCE-PARALLEL attention (q sharded on S, kv replicated).
        return {
            "wq": Spec((d, h, hd), P(None, None, None), fan_in=d),
            "wk": Spec((d, kv, hd), P(None, None, None), fan_in=d),
            "wv": Spec((d, kv, hd), P(None, None, None), fan_in=d),
            "wo": Spec((h, hd, d), P(None, None, None), fan_in=h * hd),
        }
    return {
        "wq": Spec((d, h, hd), P(None, "model", None), fan_in=d),
        "wk": Spec((d, kv, hd), P(None, "model", None), fan_in=d),
        "wv": Spec((d, kv, hd), P(None, "model", None), fan_in=d),
        "wo": Spec((h, hd, d), P("model", None, None), fan_in=h * hd),
    }


# ---------------------------------------------------------------------------
# Blocked online-softmax core
# ---------------------------------------------------------------------------


def _block_attend(q, k, v, qpos, kpos, *, causal, window, scale):
    """One (q-block, kv-block) tile. q: (B,Qb,KV,G,hd) k,v: (B,Kb,KV,hd).
    Returns unnormalized (acc, m, l) contributions."""
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    # kpos < 0 marks padding blocks (sliding-window left edge)
    mask &= (kpos >= 0)[None, :]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                       # (B,KV,G,Qb)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return acc, m, l


def _online_combine(carry, new):
    acc0, m0, l0 = carry
    acc1, m1, l1 = new
    m = jnp.maximum(m0, m1)
    a0 = jnp.exp(m0 - m)
    a1 = jnp.exp(m1 - m)
    return (acc0 * a0[..., None] + acc1 * a1[..., None],
            m, l0 * a0 + l1 * a1)


def _finalize(acc, l, dtype):
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)


def blocked_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                      q_block: int = 512, kv_block: int = 512,
                      causal_mode: str = "masked_full"):
    """q: (B,S,H,hd), k/v: (B,S,KV,hd) -> (B,S,H,hd).

    causal_mode:
      masked_full       scan all kv blocks per q block, mask (baseline; ~2x
                        FLOP waste for causal)
      recursive         recursive halving: Q2 attends KV1 densely, causality
                        recursed into halves (waste -> 1/2^depth of baseline)
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5

    # on real TPU hardware the Pallas flash kernel replaces the XLA
    # blocked path (same math; tested against it in tests/test_kernels.py)
    from repro.kernels import backend as _kb
    if _kb.on_tpu() and window is None:
        from repro.kernels.flash_attention import ops as _fa
        return _fa.flash_attention(q, k, v, causal=causal, impl="pallas")

    qg = q.reshape(B, S, KV, G, hd)

    if window is not None and causal:
        if window >= S:  # window covers everything: plain causal
            window = None
        else:
            return _sliding_window(qg, k, v, window, q_block,
                                   scale).reshape(B, S, H, hd)
    if causal and causal_mode == "recursive" and S > q_block:
        out = _recursive_causal(qg, k, v, 0, 0, scale, q_block, kv_block,
                                depth=3)
        acc, m, l = out
        return _finalize(acc, l, q.dtype).reshape(B, S, H, hd)
    return _scan_attention(qg, k, v, causal=causal, q_block=q_block,
                           kv_block=kv_block, scale=scale,
                           ).reshape(B, S, H, hd)


def _scan_attention(qg, k, v, *, causal, q_block, kv_block, scale,
                    kpos_base=0):
    B, S, KV, G, hd = qg.shape
    Sk = k.shape[1]
    nq, nk = S // q_block, Sk // kv_block
    qb = qg.reshape(B, nq, q_block, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)

    def per_q(qi, qblk):
        qpos = qi * q_block + jnp.arange(q_block)

        def inner(carry, inp):
            ki, kblk, vblk = inp
            kpos = kpos_base + ki * kv_block + jnp.arange(kv_block)
            new = _block_attend(qblk, kblk, vblk, qpos, kpos, causal=causal,
                                window=None, scale=scale)
            return _online_combine(carry, new), None

        init = (jnp.zeros((B, KV, G, q_block, hd), jnp.float32),
                jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, q_block), jnp.float32))
        (acc, m, l), _ = jax.lax.scan(init=init, f=jax.checkpoint(inner),
                                      xs=(jnp.arange(nk), kb, vb))
        return _finalize(acc, l, qg.dtype)  # (B,KV,G,q_block,hd)

    # checkpoint per tile: backward recomputes the scores (flash-attention
    # memory profile) instead of saving (B,KV,G,qb,kb) residuals per tile
    out = jax.lax.map(jax.checkpoint(lambda t: per_q(t[0], t[1])),
                      (jnp.arange(nq), qb))          # (nq,B,KV,G,qb,hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, KV, G, hd)
    return out


def _sliding_window(qg, k, v, window, q_block, scale):
    """Sub-quadratic local attention: q block i attends kv slice
    [i*qb - window, i*qb + qb)."""
    B, S, KV, G, hd = qg.shape
    nq = S // q_block
    span = min(window + q_block, S)
    qb_ = qg.reshape(B, nq, q_block, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def per_q(qi, qblk):
        qpos = qi * q_block + jnp.arange(q_block)
        start = qi * q_block - window                 # may be negative
        cl = jnp.clip(start, 0, S - span)
        kw = jax.lax.dynamic_slice_in_dim(k, cl, span, axis=1)
        vw = jax.lax.dynamic_slice_in_dim(v, cl, span, axis=1)
        kpos = cl + jnp.arange(span)
        # mark positions before the true window start as padding
        kpos = jnp.where(kpos >= start, kpos, -1)
        acc, m, l = _block_attend(qblk, kw, vw, qpos, kpos, causal=True,
                                  window=window, scale=scale)
        return _finalize(acc, l, qg.dtype)

    out = jax.lax.map(jax.checkpoint(lambda t: per_q(t[0], t[1])),
                      (jnp.arange(nq), qb_))
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, KV, G, hd)
    return out


def _recursive_causal(qg, k, v, qoff, koff, scale, q_block, kv_block, depth):
    """Returns (acc, m, l) for causal attention of qg against k/v where both
    start at the same sequence origin (qoff == koff). Recursive halving:
      [A(Q1,K1)        ]
      [D(Q2,K1) A(Q2,K2)]
    The dense part has no masked-out waste."""
    B, S, KV, G, hd = qg.shape
    if depth == 0 or S <= q_block:
        out_state = _scan_attention_state(qg, k, v, causal=True,
                                          q_block=min(q_block, S),
                                          kv_block=min(kv_block, S),
                                          scale=scale, qoff=qoff, koff=koff)
        return out_state
    h = S // 2
    q1, q2 = qg[:, :h], qg[:, h:]
    k1, k2 = k[:, :h], k[:, h:]
    v1, v2 = v[:, :h], v[:, h:]
    top = _recursive_causal(q1, k1, v1, qoff, koff, scale, q_block,
                            kv_block, depth - 1)
    lo_dense = _scan_attention_state(q2, k1, v1, causal=False,
                                     q_block=min(q_block, h),
                                     kv_block=min(kv_block, h), scale=scale,
                                     qoff=qoff + h, koff=koff)
    lo_diag = _recursive_causal(q2, k2, v2, qoff + h, koff + h, scale,
                                q_block, kv_block, depth - 1)
    lo = _online_combine(lo_dense, lo_diag)
    return tuple(jnp.concatenate([a, b], axis=3)
                 for a, b in zip(top, lo))


def _scan_attention_state(qg, k, v, *, causal, q_block, kv_block, scale,
                          qoff=0, koff=0):
    """Like _scan_attention but returns raw (acc, m, l) with q-block axis
    merged back into (B,KV,G,S,hd) order (axis 3 = S)."""
    B, S, KV, G, hd = qg.shape
    Sk = k.shape[1]
    nq, nk = S // q_block, Sk // kv_block
    qb = qg.reshape(B, nq, q_block, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)

    def per_q(qi, qblk):
        qpos = qoff + qi * q_block + jnp.arange(q_block)

        def inner(carry, inp):
            ki, kblk, vblk = inp
            kpos = koff + ki * kv_block + jnp.arange(kv_block)
            new = _block_attend(qblk, kblk, vblk, qpos, kpos, causal=causal,
                                window=None, scale=scale)
            return _online_combine(carry, new), None

        init = (jnp.zeros((B, KV, G, q_block, hd), jnp.float32),
                jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, q_block), jnp.float32))
        (acc, m, l), _ = jax.lax.scan(init=init, f=jax.checkpoint(inner),
                                      xs=(jnp.arange(nk), kb, vb))
        return acc, m, l

    acc, m, l = jax.lax.map(jax.checkpoint(lambda t: per_q(t[0], t[1])),
                            (jnp.arange(nq), qb))
    # (nq,B,KV,G,qb,*) -> (B,KV,G,S,*)
    acc = acc.transpose(1, 2, 3, 0, 4, 5).reshape(B, KV, G, S, hd)
    m = m.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, S)
    l = l.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, S)
    return acc, m, l


# ---------------------------------------------------------------------------
# Full attention layer (projections + rope + blocked core / decode)
# ---------------------------------------------------------------------------


def _constrain(x, spec):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (RuntimeError, ValueError):
        return x


def apply_attention(p: dict, x: jax.Array, cfg: ModelConfig, *,
                    local: bool, positions: jax.Array,
                    causal_mode: str = "masked_full",
                    q_block: int = 512, kv_block: int = 512,
                    dp_spec=P("data")):
    """Training/prefill path. x: (B,S,d). Returns (out, (k, v))."""
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    k = jnp.einsum("bsd,dkx->bskx", x, p["wk"])
    v = jnp.einsum("bsd,dkx->bskx", x, p["wv"])
    H, S = cfg.num_heads, x.shape[1]
    if H % 16 and S % 16 == 0 and S >= 64:
        # sequence-parallel attention (see attn_specs): q sharded on S over
        # "model", kv replicated — no collectives inside the tile loops
        q = _constrain(q, P(dp_spec[0], "model", None, None))
        k = _constrain(k, P(dp_spec[0], None, None, None))
        v = _constrain(v, P(dp_spec[0], None, None, None))
    elif H % 16 == 0:
        q = _constrain(q, P(dp_spec[0], None, "model", None))
    if cfg.attn.causal:  # decoder archs use RoPE; encoder stub skips it
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    o = blocked_attention(
        q, k, v, causal=cfg.attn.causal,
        window=cfg.attn.window if local else None,
        q_block=min(q_block, x.shape[1]), kv_block=min(kv_block, x.shape[1]),
        causal_mode=causal_mode)
    out = jnp.einsum("bshx,hxd->bsd", o, p["wo"])
    return out, (k, v)


def apply_attention_decode(p: dict, x: jax.Array, cache_k, cache_v,
                           cache_len, cfg: ModelConfig, *, local: bool):
    """One-token decode. x: (B,1,d); cache_k/v: (B,Smax,KV,hd);
    cache_len: scalar int (current valid length). Local layers use a
    ring-buffer cache of size == window (sub-quadratic memory for 500k
    contexts); global layers use a full-length cache. Returns
    (out, new_cache_k, new_cache_v)."""
    B, _, d = x.shape
    KV, hd = cache_k.shape[2], cache_k.shape[3]
    H = cfg.num_heads
    G = H // KV
    Smax = cache_k.shape[1]
    ring = local and Smax == cfg.attn.window
    pos = jnp.full((B, 1), cache_len, dtype=jnp.int32)
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    k = jnp.einsum("bsd,dkx->bskx", x, p["wk"])
    v = jnp.einsum("bsd,dkx->bskx", x, p["wv"])
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)  # rope at absolute pos; ring slot ok
    write_at = cache_len % Smax if ring else cache_len
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, write_at,
                                                  axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, write_at,
                                                  axis=1)
    qg = q.reshape(B, 1, KV, G, hd)
    scale = hd ** -0.5
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, cache_k,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(Smax)
    if ring:
        # once wrapped, every slot is within the window by construction
        valid = jnp.where(cache_len >= Smax, True, kpos <= cache_len)
    else:
        valid = kpos <= cache_len
        if local:
            valid &= kpos > (cache_len - cfg.attn.window)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(cache_v.dtype), cache_v)
    o = o.reshape(B, 1, H, hd)
    out = jnp.einsum("bshx,hxd->bsd", o, p["wo"])
    return out, cache_k, cache_v
