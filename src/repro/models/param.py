"""Parameter-spec system.

Every layer declares its parameters as a pytree of ``Spec`` leaves
(shape + PartitionSpec + initializer). The same tree is used three ways:

* ``materialize``  -> real arrays (smoke tests, examples)
* ``abstract``     -> ShapeDtypeStruct stand-ins (multi-pod dry-run)
* ``pspecs``       -> PartitionSpec tree (in_shardings for pjit)
* ``stack``        -> prepend a layer axis for scan-over-layers
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple
    pspec: P = P()
    init: str = "normal"       # normal|zeros|ones|ssm_a_log|ssm_dt_bias|arange_neg
    fan_in: Optional[int] = None
    dtype: Optional[Any] = None  # override model dtype (e.g. f32 for norms)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _init_leaf(spec: Spec, key, dtype) -> jax.Array:
    dt = spec.dtype or dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "ssm_a_log":
        # mamba: A in [-16, -1) via log; shape (..., N) or (H,)
        n = spec.shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                             spec.shape)
        return jnp.log(a).astype(dt)
    if spec.init == "ssm_dt_bias":
        u = jax.random.uniform(key, spec.shape, jnp.float32,
                               math.log(1e-3), math.log(1e-1))
        dtv = jnp.exp(u)
        # inverse softplus
        return (dtv + jnp.log(-jnp.expm1(-dtv))).astype(dt)
    fan = spec.fan_in or (spec.shape[0] if spec.shape else 1)
    return (jax.random.normal(key, spec.shape, jnp.float32)
            / math.sqrt(max(fan, 1))).astype(dt)


def materialize(tree, rng, dtype) -> Any:
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = [_init_leaf(l, k, dtype) for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract(tree, dtype) -> Any:
    def f(s: Spec):
        return jax.ShapeDtypeStruct(s.shape, s.dtype or dtype)
    return jax.tree.map(f, tree, is_leaf=is_spec)


def pspecs(tree) -> Any:
    return jax.tree.map(lambda s: s.pspec, tree, is_leaf=is_spec)


# production mesh axis sizes (fixed: 16x16 single-pod, 2x16x16 multi-pod).
# jax rejects NamedShardings that don't divide the dimension, so every Spec
# is sanitized against these before use.
AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _axes_size(entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= AXIS_SIZES[a]
        return n
    return AXIS_SIZES[entry]


def sanitize(tree) -> Any:
    """Fix Specs whose sharded dims aren't divisible by the mesh axis: move
    the axis to the largest divisible unsharded dim, else drop it."""
    def fix(s: Spec) -> Spec:
        import numpy as np
        spec = list(s.pspec) + [None] * (len(s.shape) - len(s.pspec))
        changed = False
        big = int(np.prod(s.shape)) * 2 >= (64 << 20)
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            if s.shape[i] % _axes_size(entry) == 0:
                continue
            spec[i] = None
            changed = True
            if not big:
                continue  # small tensor: replicate (avoids psum chatter)
            # large tensor: relocate to the largest unsharded divisible dim
            for j in sorted(range(len(s.shape)), key=lambda k: -s.shape[k]):
                if spec[j] is None and s.shape[j] % _axes_size(entry) == 0 \
                        and s.shape[j] > 1:
                    spec[j] = entry
                    break
        if not changed:
            return s
        return Spec(s.shape, P(*spec), s.init, s.fan_in, s.dtype)

    return jax.tree.map(fix, tree, is_leaf=is_spec)


def stack(tree, n: int) -> Any:
    """Prepend a scan (layer) axis of size n to every Spec."""
    def f(s: Spec):
        return Spec((n,) + tuple(s.shape), P(None, *s.pspec), s.init,
                    s.fan_in, s.dtype)
    return jax.tree.map(f, tree, is_leaf=is_spec)
