"""Common layers: norms, RoPE, SwiGLU MLP, embeddings (all functional)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.param import Spec

# ---------------------------------------------------------------------------
# Norms (params kept in f32 for stability)
# ---------------------------------------------------------------------------


def norm_specs(d: int, kind: str) -> dict:
    out = {"scale": Spec((d,), P(None), "ones", dtype=jnp.float32)}
    if kind == "layernorm":
        out["bias"] = Spec((d,), P(None), "zeros", dtype=jnp.float32)
    return out


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    rest = x[..., 2 * half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype), rest],
                           axis=-1)


# ---------------------------------------------------------------------------
# SwiGLU MLP (TP: d_ff sharded on "model")
# ---------------------------------------------------------------------------


def mlp_specs(d: int, d_ff: int) -> dict:
    return {
        "w_gate": Spec((d, d_ff), P(None, "model"), fan_in=d),
        "w_up": Spec((d, d_ff), P(None, "model"), fan_in=d),
        "w_down": Spec((d_ff, d), P("model", None), fan_in=d_ff),
    }


def apply_mlp(p: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ---------------------------------------------------------------------------
# Embedding / unembedding (vocab sharded on "model")
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig) -> dict:
    out = {"embedding": Spec((cfg.vocab_size, cfg.d_model), P("model", None),
                             fan_in=cfg.d_model)}
    if not cfg.tie_embeddings:
        out["unembed"] = Spec((cfg.d_model, cfg.vocab_size),
                              P(None, "model"), fan_in=cfg.d_model)
    return out


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    if "unembed" in p:
        return jnp.einsum("...d,dv->...v", x, p["unembed"])
    return jnp.einsum("...d,vd->...v", x, p["embedding"])
