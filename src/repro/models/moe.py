"""Mixture-of-Experts with the Pregelix dataflow mapping.

The paper models message passing as a join + group-by with physical plan
choices. Token->expert routing is exactly that dataflow:

* ``Msg``      = (expert_id, token_vector) pairs produced by the router
* group-by     = collecting each expert's tokens (sort-based vs hash/scatter)
* join         = matching token groups with expert weights (vid-indexed)
* m-to-n partitioning connector = the EP all_to_all that GSPMD inserts when
  the dispatch buffer is resharded from batch-sharded to expert-sharded
* combine UDF  = the gate-weighted sum on the return path

Two physical dispatch strategies (the paper's "physical flexibility"):

* ``scatter``  — hash-group-by analogue: tokens scatter-add into per-expert
  capacity slots (HashSort group-by). SPMD-safe; used by the dry-run.
* ``sort``     — sort-based group-by analogue: tokens argsorted by expert id
  and processed with a grouped matmul (kernels/moe_gmm Pallas kernel on TPU,
  jnp oracle elsewhere). This is the paper-faithful plan.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import apply_mlp, mlp_specs
from repro.models.param import Spec


def _maybe_constrain(x, spec):
    """with_sharding_constraint that is a no-op outside a mesh context
    (single-device smoke tests)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (RuntimeError, ValueError):
        return x


def padded_experts(E: int, tp: int = 16) -> int:
    """§Perf hc2: pad the expert count to the EP multiple (qwen2's 60 -> 64;
    pad experts are masked with -inf router logits so they are NEVER
    selected — exact semantics). The naive alternative (TP over d_ff)
    psums the whole (B,E,C,d) dispatch buffer per layer: measured 117s of
    collective + 87 GiB/device on qwen2 prefill_32k."""
    return ((E + tp - 1) // tp) * tp


def _expert_pspec(E: int, tp: int = 16):
    return P("model", None, None)


def moe_specs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, E, f = cfg.d_model, m.num_experts, m.d_expert
    Ep = padded_experts(E)
    ep = _expert_pspec(Ep)
    out = {
        "router": Spec((d, E), P(None, None), fan_in=d,
                       dtype=jnp.float32),
        "w_gate": Spec((Ep, d, f), ep, fan_in=d),
        "w_up": Spec((Ep, d, f), ep, fan_in=d),
        "w_down": Spec((Ep, f, d), P(ep[0], ep[2], ep[1]), fan_in=f),
    }
    if m.d_shared:
        out["shared"] = mlp_specs(d, m.d_shared)
    return out


def _route(p: dict, x: jax.Array, k: int):
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)            # (B,S,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    # pad experts never selected (top_k over REAL logits only), so idx is
    # already in [0, E); the padded weight rows are simply dead capacity
    return gates, idx, aux


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig, *,
              dp_spec=P(None)) -> tuple:
    """x: (B,S,d) -> (out, aux_loss)."""
    m = cfg.moe
    if m.dispatch == "sort":
        return _apply_moe_sort(p, x, cfg)
    return _apply_moe_scatter(p, x, cfg, dp_spec=dp_spec)


# ---------------------------------------------------------------------------
# scatter dispatch (HashSort group-by analogue; SPMD-safe)
# ---------------------------------------------------------------------------


def _apply_moe_scatter(p: dict, x: jax.Array, cfg: ModelConfig, *, dp_spec):
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    Ep = padded_experts(E)
    gates, idx, aux = _route(p, x, k)
    C = max(8, int(round(m.capacity_factor * S * k / E + 7)) // 8 * 8)
    C = min(C, S * k)

    eid = idx.reshape(B, S * k)                       # (B,T) T = S*k
    gat = gates.reshape(B, S * k)
    # position of each token within its expert's group (hash group-by)
    onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)  # (B,T,E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1), eid[..., None],
                              axis=2)[..., 0] - 1     # (B,T)
    keep = pos < C
    slot = jnp.where(keep, eid * C + pos, Ep * C)     # overflow -> drop row
    xe = jnp.repeat(x, k, axis=1)                     # (B,T,d)
    xe = xe * keep[..., None].astype(x.dtype)
    bidx = jnp.arange(B)[:, None]
    # §Perf hc2b: scatter only int32 TOKEN INDICES into the capacity slots
    # (GSPMD lowers wide scatters to replicated compute + full-buffer
    # all-reduces — measured 3.6 TB/step on qwen2 train); the d-wide
    # dispatch itself is then a gather, which shards cleanly.
    T = S * k
    slot_tok = jnp.full((B, Ep * C + 1), T, jnp.int32)
    slot_tok = slot_tok.at[bidx, slot].set(
        jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T)))
    xe_pad = jnp.concatenate([xe, jnp.zeros((B, 1, d), xe.dtype)], axis=1)
    buf = jnp.take_along_axis(xe_pad, slot_tok[:, :Ep * C, None], axis=1)
    buf = buf.reshape(B, Ep, C, d)
    # reshard batch-sharded -> (batch, expert)-sharded: the EP all_to_all
    buf = _maybe_constrain(buf, P(dp_spec[0], "model", None, None))
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("becf,efd->becd", h, p["w_down"])
    y = _maybe_constrain(y, P(dp_spec[0], None, None, None))
    y = y.reshape(B, Ep * C, d)
    y = jnp.concatenate([y, jnp.zeros((B, 1, d), y.dtype)], axis=1)
    y_tok = y[bidx, slot]                             # (B,T,d)
    y_tok = y_tok * (gat * keep)[..., None].astype(y.dtype)
    out = y_tok.reshape(B, S, k, d).sum(axis=2)
    if m.d_shared:
        out = out + apply_mlp(p["shared"], x)
    return out, aux


# ---------------------------------------------------------------------------
# sort dispatch (sort-based group-by; the paper-faithful plan)
# ---------------------------------------------------------------------------


def _apply_moe_sort(p: dict, x: jax.Array, cfg: ModelConfig):
    from repro.kernels.moe_gmm import ops as gmm_ops
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    gates, idx, aux = _route(p, x, k)
    T = B * S * k
    eid = idx.reshape(T)
    gat = gates.reshape(T)
    xe = jnp.repeat(x.reshape(B * S, d), k, axis=0)   # (T,d)
    order = jnp.argsort(eid)                          # sort-based group-by
    xs = xe[order]
    es = eid[order]
    group_sizes = jnp.bincount(es, length=padded_experts(E))
    g = gmm_ops.grouped_matmul(xs, p["w_gate"], group_sizes)
    u = gmm_ops.grouped_matmul(xs, p["w_up"], group_sizes)
    h = jax.nn.silu(g) * u
    ys = gmm_ops.grouped_matmul(h, p["w_down"], group_sizes)
    inv = jnp.argsort(order)
    y_tok = ys[inv] * gat[:, None].astype(ys.dtype)
    out = y_tok.reshape(B, S, k, d).sum(axis=2)
    if m.d_shared:
        out = out + apply_mlp(p["shared"], x)
    return out, aux
