"""State-space layers: Mamba1 (sequential selective scan, faithful) and
Mamba2 (SSD chunked matmul form — MXU-friendly).

Sharding: the inner dimension / heads are sharded on "model"; the recurrent
state then carries no cross-device traffic inside the scan (the only
collectives are the psums where the sharded inner dim is contracted).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.param import Spec

# ---------------------------------------------------------------------------
# Depthwise causal conv1d (k small; implemented as k shifted adds)
# ---------------------------------------------------------------------------


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B,S,C), w: (C,k), b: (C)."""
    k = w.shape[1]
    out = x * w[:, -1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[:, -1 - i]
    return out + b


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                b: jax.Array):
    """Single decode step. x_t: (B,C); conv_state: (B,k-1,C) past inputs."""
    full = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B,k,C)
    y = jnp.einsum("bkc,ck->bc", full, w) + b
    return y, full[:, 1:]


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------


def mamba1_specs(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    r = s.dt_rank or d // 16
    return {
        "in_proj": Spec((d, 2 * d_in), P(None, "model"), fan_in=d),
        "conv_w": Spec((d_in, s.d_conv), P("model", None), init="normal",
                       fan_in=s.d_conv),
        "conv_b": Spec((d_in,), P("model"), "zeros"),
        "x_proj": Spec((d_in, r + 2 * s.d_state), P("model", None),
                       fan_in=d_in),
        "dt_proj": Spec((r, d_in), P(None, "model"), fan_in=r),
        "dt_bias": Spec((d_in,), P("model"), "ssm_dt_bias",
                        dtype=jnp.float32),
        "A_log": Spec((d_in, s.d_state), P("model", None), "ssm_a_log",
                      dtype=jnp.float32),
        "D": Spec((d_in,), P("model"), "ones", dtype=jnp.float32),
        "out_proj": Spec((d_in, d), P("model", None), fan_in=d_in),
    }


def _mamba1_inner(p, xc, z, dt, Bc, Cc):
    y, _ = _mamba1_scan(p, xc, z, dt, Bc, Cc)
    return y


def _mamba1_scan(p, xc, z, dt, Bc, Cc, chunk: int = 128):
    """Sequential selective scan, two-level (chunks x steps) so backward
    saves one recurrent state per CHUNK, not per step (a 4096-step train
    sequence would otherwise pin 4096 copies of (B,d_in,N))."""
    A = -jnp.exp(p["A_log"])                     # (d_in, N) f32

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp               # (B,d_in),(B,d_in),(B,N)x2
        dA = jnp.exp(dt_t[..., None] * A)        # (B,d_in,N)
        dBx = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    B, S, d_in = xc.shape
    N = Bc.shape[-1]
    chunk = min(chunk, S)
    nc = S // chunk
    h0 = jnp.zeros((B, d_in, N), jnp.float32)

    def to_chunks(a):
        return a.astype(jnp.float32).reshape(B, nc, chunk, *a.shape[2:]) \
            .transpose(1, 2, 0, *range(3, a.ndim + 1))

    xs = tuple(to_chunks(a) for a in (xc, dt, Bc, Cc))  # (nc,chunk,B,...)

    @jax.checkpoint
    def chunk_body(h, inp):
        h, ys = jax.lax.scan(step, h, inp)
        return h, ys

    hT, ys = jax.lax.scan(chunk_body, h0, xs)    # ys: (nc,chunk,B,d_in)
    y = ys.transpose(2, 0, 1, 3).reshape(B, S, d_in)
    y = y + xc.astype(jnp.float32) * p["D"]
    return (y * jax.nn.silu(z.astype(jnp.float32))).astype(xc.dtype), hT


def apply_mamba1(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    s = cfg.ssm
    r = s.dt_rank or cfg.d_model // 16
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(causal_conv1d(x_, p["conv_w"], p["conv_b"]))
    proj = jnp.einsum("bse,ef->bsf", xc, p["x_proj"])
    dt_r = proj[..., :r]
    Bc = proj[..., r:r + s.d_state]
    Cc = proj[..., r + s.d_state:]
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"])
    y = _mamba1_inner(p, xc, z, dt, Bc, Cc)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


def apply_mamba1_with_state(p: dict, x: jax.Array, cfg: ModelConfig):
    """Like apply_mamba1 but also returns the decode state (conv tail +
    final recurrent state) for prefill->decode handoff."""
    s = cfg.ssm
    r = s.dt_rank or cfg.d_model // 16
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(causal_conv1d(x_, p["conv_w"], p["conv_b"]))
    proj = jnp.einsum("bse,ef->bsf", xc, p["x_proj"])
    dt_r = proj[..., :r]
    Bc = proj[..., r:r + s.d_state]
    Cc = proj[..., r + s.d_state:]
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"])
    y, h = _mamba1_inner_state(p, xc, z, dt, Bc, Cc)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    k = s.d_conv - 1
    conv_tail = jnp.pad(x_, ((0, 0), (k, 0), (0, 0)))[:, -k:] \
        if x.shape[1] < k else x_[:, -k:]
    return out, {"conv": conv_tail, "ssm": h}


def _mamba1_inner_state(p, xc, z, dt, Bc, Cc):
    return _mamba1_scan(p, xc, z, dt, Bc, Cc)


def mamba1_init_state(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
            "ssm": jnp.zeros((batch, d_in, s.d_state), jnp.float32)}


def apply_mamba1_decode(p: dict, x_t: jax.Array, state: dict,
                        cfg: ModelConfig):
    """x_t: (B,1,d). Returns (y_t, new_state)."""
    s = cfg.ssm
    r = s.dt_rank or cfg.d_model // 16
    xz = jnp.einsum("bsd,de->bse", x_t, p["in_proj"])[:, 0]
    x_, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = conv1d_step(x_, state["conv"], p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    proj = jnp.einsum("be,ef->bf", xc, p["x_proj"])
    dt_r, Bc, Cc = (proj[..., :r], proj[..., r:r + s.d_state],
                    proj[..., r + s.d_state:])
    dt = jax.nn.softplus(
        jnp.einsum("br,re->be", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[:, None, :]
    h = dA * state["ssm"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32))
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None]
    return out, {"conv": conv_state, "ssm": h}


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def mamba2_specs(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    return {
        "wz": Spec((d, d_in), P(None, "model"), fan_in=d),
        "wx": Spec((d, d_in), P(None, "model"), fan_in=d),
        "wB": Spec((d, s.d_state), P(None, None), fan_in=d),
        "wC": Spec((d, s.d_state), P(None, None), fan_in=d),
        "wdt": Spec((d, H), P(None, "model"), fan_in=d),
        "conv_w": Spec((d_in, s.d_conv), P("model", None), fan_in=s.d_conv),
        "conv_b": Spec((d_in,), P("model"), "zeros"),
        "convB_w": Spec((s.d_state, s.d_conv), P(None, None),
                        fan_in=s.d_conv),
        "convB_b": Spec((s.d_state,), P(None), "zeros"),
        "convC_w": Spec((s.d_state, s.d_conv), P(None, None),
                        fan_in=s.d_conv),
        "convC_b": Spec((s.d_state,), P(None), "zeros"),
        "dt_bias": Spec((H,), P("model"), "ssm_dt_bias", dtype=jnp.float32),
        "A_log": Spec((H,), P("model"), "ssm_a_log", dtype=jnp.float32),
        "D": Spec((H,), P("model"), "ones", dtype=jnp.float32),
        "norm_scale": Spec((d_in,), P("model"), "ones", dtype=jnp.float32),
        "out_proj": Spec((d_in, d), P("model", None), fan_in=d_in),
    }


def _segsum(x):
    """x: (..., L). Returns (..., L, L) cumulative sums
    out[t,s] = sum_{r=s+1..t} x[r] for t >= s, -inf otherwise."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, A, Bc, Cc, chunk: int, init_state=None):
    """SSD (mamba2) chunked scan.
    xh: (B,S,H,Ph) head inputs; dt: (B,S,H) (post-softplus, f32);
    A: (H,) negative decay (f32); Bc/Cc: (B,S,N).
    Returns (y: (B,S,H,Ph), final_state: (B,H,Ph,N))."""
    Bsz, S, H, Ph = xh.shape
    N = Bc.shape[-1]
    nc = S // chunk
    L = chunk
    xc = xh.reshape(Bsz, nc, L, H, Ph).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, L, H)
    Bcc = Bc.reshape(Bsz, nc, L, N).astype(jnp.float32)
    Ccc = Cc.reshape(Bsz, nc, L, N).astype(jnp.float32)
    dA = dtc * A                                   # (B,nc,L,H)
    dAh = dA.transpose(0, 1, 3, 2)                  # (B,nc,H,L)
    cum = jnp.cumsum(dAh, axis=-1)                  # (B,nc,H,L)
    # --- intra-chunk (diagonal blocks) ---
    Lmat = jnp.exp(_segsum(dAh))                    # (B,nc,H,L,L)
    scores = jnp.einsum("bcln,bcsn->bcls", Ccc, Bcc)
    G = scores[:, :, None] * Lmat                   # (B,nc,H,L,L)
    xdt = xc * dtc[..., None]                       # (B,nc,L,H,Ph)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", G, xdt)
    # --- per-chunk end states ---
    decay_to_end = jnp.exp(cum[..., -1:] - cum)     # (B,nc,H,L)
    st = jnp.einsum("bchl,bcln,bclhp->bchpn", decay_to_end, Bcc, xdt)
    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(cum[..., -1])             # (B,nc,H)

    def step(carry, inp):
        s_c, dec = inp
        new = dec[..., None, None] * carry + s_c
        return new, carry                           # emit state BEFORE chunk

    s0 = (jnp.zeros((Bsz, H, Ph, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        step, s0, (st.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)        # (B,nc,H,Ph,N)
    # --- off-diagonal contribution from previous chunks ---
    decay_from_start = jnp.exp(cum)                 # (B,nc,H,L)
    y_off = jnp.einsum("bcln,bchpn,bchl->bclhp", Ccc, prev_states,
                       decay_from_start)
    y = (y_diag + y_off).reshape(Bsz, S, H, Ph)
    return y, final


def apply_mamba2(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xi = jnp.einsum("bsd,de->bse", x, p["wx"])
    Bi = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Ci = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dti = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    xc = jax.nn.silu(causal_conv1d(xi, p["conv_w"], p["conv_b"]))
    Bc = jax.nn.silu(causal_conv1d(Bi, p["convB_w"], p["convB_b"]))
    Cc = jax.nn.silu(causal_conv1d(Ci, p["convC_w"], p["convC_b"]))
    dt = jax.nn.softplus(dti.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(*xc.shape[:2], H, s.head_dim)
    y, _ = ssd_chunked(xh, dt, A, Bc, Cc, min(s.chunk, x.shape[1]))
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(*x.shape[:2], d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    # gated RMSNorm
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(ms + 1e-6) * p["norm_scale"]).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


def apply_mamba2_with_state(p: dict, x: jax.Array, cfg: ModelConfig):
    """apply_mamba2 variant returning the decode state."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xi = jnp.einsum("bsd,de->bse", x, p["wx"])
    Bi = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Ci = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dti = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    xc = jax.nn.silu(causal_conv1d(xi, p["conv_w"], p["conv_b"]))
    Bc = jax.nn.silu(causal_conv1d(Bi, p["convB_w"], p["convB_b"]))
    Cc = jax.nn.silu(causal_conv1d(Ci, p["convC_w"], p["convC_b"]))
    dt = jax.nn.softplus(dti.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(*xc.shape[:2], H, s.head_dim)
    y, final = ssd_chunked(xh, dt, A, Bc, Cc, min(s.chunk, x.shape[1]))
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(*x.shape[:2], d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(ms + 1e-6) * p["norm_scale"]).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    k = s.d_conv - 1

    def tail(a):
        return jnp.pad(a, ((0, 0), (k, 0), (0, 0)))[:, -k:] \
            if a.shape[1] < k else a[:, -k:]

    return out, {"conv_x": tail(xi), "conv_B": tail(Bi), "conv_C": tail(Ci),
                 "ssm": final}


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    return {
        "conv_x": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
        "conv_B": jnp.zeros((batch, s.d_conv - 1, s.d_state), dtype),
        "conv_C": jnp.zeros((batch, s.d_conv - 1, s.d_state), dtype),
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    }


def apply_mamba2_decode(p: dict, x_t: jax.Array, state: dict,
                        cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    x0 = x_t[:, 0]
    z = jnp.einsum("bd,de->be", x0, p["wz"])
    xi = jnp.einsum("bd,de->be", x0, p["wx"])
    Bi = jnp.einsum("bd,dn->bn", x0, p["wB"])
    Ci = jnp.einsum("bd,dn->bn", x0, p["wC"])
    dti = jnp.einsum("bd,dh->bh", x0, p["wdt"])
    xc, cx = conv1d_step(xi, state["conv_x"], p["conv_w"], p["conv_b"])
    Bc, cB = conv1d_step(Bi, state["conv_B"], p["convB_w"], p["convB_b"])
    Cc, cC = conv1d_step(Ci, state["conv_C"], p["convC_w"], p["convC_b"])
    xc, Bc, Cc = jax.nn.silu(xc), jax.nn.silu(Bc), jax.nn.silu(Cc)
    dt = jax.nn.softplus(dti.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                             # (B,H)
    xh = xc.reshape(-1, H, s.head_dim).astype(jnp.float32)
    dBx = (dt[..., None] * xh)[..., None] * Bc.astype(jnp.float32)[:, None, None, :]
    h = dA[..., None, None] * state["ssm"] + dBx     # (B,H,Ph,N)
    y = jnp.einsum("bhpn,bn->bhp", h, Cc.astype(jnp.float32))
    y = y + xh * p["D"][:, None]
    y = y.reshape(-1, d_in) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(ms + 1e-6) * p["norm_scale"]).astype(x_t.dtype)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None]
    return out, {"conv_x": cx, "conv_B": cB, "conv_C": cC, "ssm": h}
