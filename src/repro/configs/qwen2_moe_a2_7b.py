"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) d_ff=1408 vocab=151936,
MoE 60 routed top-4 + shared expert (4x merged -> d_shared=5632).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    attn=AttnConfig(pattern=("global",)),
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408, d_shared=5632,
                  every_k_layers=1),
    source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
))
