"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) d_ff=0
vocab=65024, ssm_state=16. Pure mamba1 blocks. [arXiv:2410.05355; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm=SSMConfig(kind="mamba1", d_state=16, d_conv=4, expand=2, dt_rank=256),
    source="[arXiv:2410.05355; unverified]",
))
