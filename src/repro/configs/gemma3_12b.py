"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144. 5:1 local:global attention, 128k context, window=1024.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    attn=AttnConfig(pattern=("local",) * 5 + ("global",), window=1024),
    rope_theta=1000000.0,
    source="[hf:google/gemma-3-1b-pt; unverified]",
))
