"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=32000, ssm_state=64. Mamba2 backbone + ONE shared attention+MLP block
applied every 6 layers (weight-shared, zamba2-style; the LoRA modulation of
the shared block is simplified away — see DESIGN.md). [arXiv:2411.15242; hf]
"""
from repro.configs.base import AttnConfig, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    attn=AttnConfig(pattern=("global",)),
    ssm=SSMConfig(kind="mamba2", d_state=64, head_dim=64, chunk=128),
    shared_attn_every=6,
    source="[arXiv:2411.15242; hf]",
))
