"""Model / run configuration system.

Every assigned architecture pins an exact published shape via ``ModelConfig``.
``reduced()`` produces the same-family tiny config used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    d_shared: int = 0             # shared-expert FFN hidden size (0 = none)
    every_k_layers: int = 1       # MoE layer every k layers (1 = all layers)
    capacity_factor: float = 1.25
    dispatch: str = "einsum"      # "einsum" (GShard-style) | "sort" (group-by)


@dataclass(frozen=True)
class SSMConfig:
    kind: str                     # "mamba1" | "mamba2"
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # mamba2 only
    dt_rank: int = 0              # mamba1 only; 0 -> d_model // 16
    chunk: int = 128              # mamba2 SSD chunk length


@dataclass(frozen=True)
class AttnConfig:
    # layer attention pattern, cycled over depth: "global" | "local"
    pattern: tuple = ("global",)
    window: int = 4096            # sliding window for "local" layers
    causal: bool = True           # False for encoder-only archs


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int                # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int                     # dense FFN hidden (0 = no FFN, e.g. mamba)
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    norm: str = "rmsnorm"
    attn: AttnConfig = field(default_factory=AttnConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): ssm backbone with a shared attn+mlp block
    # applied every `shared_attn_every` layers (0 = never)
    shared_attn_every: int = 0
    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    frontend_len: int = 0         # prepended frontend positions (vision)
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    rope_theta: float = 10000.0
    source: str = ""              # provenance note [source; tier]

    # ---- derived --------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_encoder(self) -> bool:
        return not self.attn.causal

    def layer_kind(self, i: int) -> str:
        """'attn_global' | 'attn_local' | 'ssm' for backbone layer i."""
        if self.ssm is not None and self.family in ("ssm", "hybrid"):
            return "ssm"
        pat = self.attn.pattern
        return "attn_" + pat[i % len(pat)]

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return (i % self.moe.every_k_layers) == (self.moe.every_k_layers - 1)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND rooflines."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embedding (tied output head)
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "ssm":
                n += _ssm_params(self, self.ssm)
            else:
                n += _attn_params(d, self.num_heads, self.num_kv_heads, hd)
            if self.moe is not None and self.is_moe_layer(i):
                m = self.moe
                n += m.num_experts * 3 * d * m.d_expert
                if m.d_shared:
                    n += 3 * d * m.d_shared
                n += d * m.num_experts  # router
            elif self.d_ff:
                n += 3 * d * self.d_ff  # SwiGLU
            n += 2 * d  # norms
        if self.shared_attn_every:
            # one shared attn+mlp block (zamba2-style)
            n += _attn_params(d, self.num_heads, self.num_kv_heads, hd)
            n += 3 * d * self.d_ff + 2 * d
        if self.frontend == "vision":
            n += d * d  # projector
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k); for 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        n_moe_layers = sum(self.is_moe_layer(i) for i in range(self.num_layers))
        inactive = n_moe_layers * (m.num_experts - m.top_k) * 3 * d * m.d_expert
        return self.param_count() - inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, num_experts=min(moe.num_experts, 8),
                top_k=min(moe.top_k, 2), d_expert=64,
                d_shared=64 if moe.d_shared else 0)
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, d_state=min(ssm.d_state, 16),
                                      head_dim=32, chunk=16)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 4) if not self.shared_attn_every
            else 4,
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_heads else 0,
            head_dim=32 if self.num_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            moe=moe, ssm=ssm,
            shared_attn_every=2 if self.shared_attn_every else 0,
            frontend_len=min(self.frontend_len, 8),
            dtype="float32",
        )


def _attn_params(d: int, h: int, kv: int, hd: int) -> int:
    return d * h * hd + 2 * d * kv * hd + h * hd * d


def _ssm_params(cfg: ModelConfig, s: SSMConfig) -> int:
    d = cfg.d_model
    d_in = s.expand * d
    if s.kind == "mamba1":
        dt_rank = s.dt_rank or d // 16
        n = 2 * d * d_in                    # in_proj (x, z)
        n += d_in * s.d_conv                # conv
        n += d_in * (dt_rank + 2 * s.d_state)  # x_proj -> (dt, B, C)
        n += dt_rank * d_in + d_in          # dt_proj
        n += d_in * s.d_state + d_in        # A_log, D
        n += d_in * d                       # out_proj
        return n
    # mamba2
    nheads = d_in // s.head_dim
    n = d * (2 * d_in + 2 * s.d_state + nheads)  # in_proj (z,x,B,C,dt)
    n += (d_in + 2 * s.d_state) * s.d_conv
    n += nheads * 2                          # A_log, D
    n += d_in * d                            # out_proj
    return n


# ---------------------------------------------------------------------------
# Shape cells (assigned input shapes)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def runnable_cells(cfg: ModelConfig) -> dict:
    """Which of the four shape cells run for this arch; value = reason if
    skipped else None."""
    out = {}
    subquadratic = (
        cfg.family in ("ssm", "hybrid")
        or "local" in cfg.attn.pattern
    )
    for name, cell in SHAPES.items():
        reason = None
        if cell.kind == "decode" and cfg.is_encoder:
            reason = "encoder-only arch: no decode step"
        elif name == "long_500k" and not subquadratic:
            reason = "pure full-attention arch: long_500k needs sub-quadratic attention"
        out[name] = reason
    return out


# registry populated by configs/__init__.py
REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        import repro.configs  # noqa: F401  (populate registry)
    return REGISTRY[name]
