"""Architecture registry: importing this package registers all configs."""
from repro.configs.base import (AttnConfig, ModelConfig, MoEConfig, REGISTRY,
                                SHAPES, ShapeCell, SSMConfig, get_config,
                                runnable_cells)

# one module per assigned architecture (+ the paper's own graph configs live
# in repro.graph.generators)
from repro.configs import (falcon_mamba_7b, gemma3_12b, h2o_danube_3_4b,
                           hubert_xlarge, internvl2_76b,
                           llama4_maverick_400b_a17b, qwen2_moe_a2_7b,
                           stablelm_12b, yi_34b, zamba2_1_2b)  # noqa: F401

ALL_ARCHS = tuple(sorted(REGISTRY.keys()))

__all__ = [
    "AttnConfig", "ModelConfig", "MoEConfig", "SSMConfig", "ShapeCell",
    "SHAPES", "REGISTRY", "ALL_ARCHS", "get_config", "runnable_cells",
]
