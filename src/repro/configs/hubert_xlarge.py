"""hubert-xlarge [audio]: 48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504.

Encoder-only transformer (same arch as wav2vec2). The audio conv frontend is
a STUB per the task spec: input_specs() provides precomputed frame embeddings.
[arXiv:2106.07447; unverified]
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    norm="layernorm",
    attn=AttnConfig(pattern=("global",), causal=False),
    frontend="audio",
    tie_embeddings=False,
    source="[arXiv:2106.07447; unverified]",
))
