"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 routed top-1 + shared expert, MoE every other layer.

Early-fusion multimodality and iRoPE chunked attention are NOT reproduced
(treated as full attention; see DESIGN.md §Limitations) so long_500k is
skipped. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    attn=AttnConfig(pattern=("global",)),
    moe=MoEConfig(num_experts=128, top_k=1, d_expert=8192, d_shared=8192,
                  every_k_layers=2),
    rope_theta=500000.0,
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
))
