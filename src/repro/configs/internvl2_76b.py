"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256. InternViT frontend is a STUB: input_specs() provides
precomputed patch embeddings prepended to the text sequence.
[arXiv:2404.16821; unverified]
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    attn=AttnConfig(pattern=("global",)),
    frontend="vision",
    frontend_len=256,
    tie_embeddings=False,
    source="[arXiv:2404.16821; unverified]",
))
