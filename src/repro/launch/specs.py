"""ShapeDtypeStruct stand-ins for every model input of every (arch x shape)
cell — weak-type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.launch.mesh import batch_axis_size, dp_axes
from repro.models import abstract_params, init_caches, param_pspecs
from repro.models.model import model_specs
from repro.models.param import abstract as abstract_tree, pspecs as pspec_tree
from repro.optim import adamw_init

# archs whose serve KV caches are int8-quantized to fit v5e HBM (see
# EXPERIMENTS.md §Dry-run)
QUANTIZED_KV_ARCHS = {"internvl2-76b"}
# archs whose Adam moments are bf16 to fit HBM (llama4-400B on 256 chips)
BF16_MOMENT_ARCHS = {"llama4-maverick-400b-a17b"}
# gradient-accumulation factors at train_4k: chosen so per-microbatch
# layer-boundary activation saves stay under ~4 GiB/device (global_batch=256
# over 16 data shards is 16 sequences x 4096 tokens per chip otherwise)
TRAIN_MICROBATCHES = {
    "hubert-xlarge": 2, "qwen2-moe-a2.7b": 4, "llama4-maverick-400b-a17b": 16,
    "h2o-danube-3-4b": 4, "stablelm-12b": 8, "gemma3-12b": 8, "yi-34b": 16,
        "zamba2-1.2b": 2, "internvl2-76b": 16, "falcon-mamba-7b": 8,
}


def train_profile(cfg: ModelConfig) -> str:
    from repro.models.model import resolve_profile
    return resolve_profile(cfg, "auto")


def microbatches_for(cfg: ModelConfig) -> int:
    if train_profile(cfg) == "zero":
        return 1  # already 1 sequence/chip
    return TRAIN_MICROBATCHES.get(cfg.name, 1)


def _shard(tree, pspecs, mesh):
    def f(sds, spec):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(f, tree, pspecs)


def _dim_axes(size: int, axes: tuple, mesh) -> Any:
    """Shard `size` over as many of `axes` as divide it (prefix)."""
    use = []
    n = 1
    for a in axes:
        if size % (n * mesh.shape[a]) == 0:
            use.append(a)
            n *= mesh.shape[a]
    if not use:
        return None
    return tuple(use) if len(use) > 1 else use[0]


def sharded_params(cfg: ModelConfig, mesh, profile: str = "auto"):
    specs = model_specs(cfg, profile)
    return _shard(abstract_tree(specs, jnp.dtype(cfg.dtype)),
                  pspec_tree(specs), mesh)


def sharded_opt_state(cfg: ModelConfig, params_sds, mesh):
    mdt = jnp.bfloat16 if cfg.name in BF16_MOMENT_ARCHS else jnp.float32
    moments = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, mdt, sharding=p.sharding),
        params_sds)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    return {"step": step, "m": moments, "v": moments}


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh,
                batch_axes=None) -> dict:
    dp = batch_axes if batch_axes is not None else dp_axes(mesh)
    B, S = cell.global_batch, cell.seq_len
    bspec = _dim_axes(B, dp, mesh)
    tok = jax.ShapeDtypeStruct(
        (B, S), jnp.int32, sharding=NamedSharding(mesh, P(bspec, None)))
    batch = {"tokens": tok, "labels": tok}
    if cfg.frontend == "audio":
        frames = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, P(bspec, None, None)))
        batch = {"frames": frames, "labels": tok}
    elif cfg.frontend == "vision":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, P(bspec, None, None)))
    return batch


def _cache_pspec(leaf_path: str, shape, mesh, bspec) -> P:
    """Sharding rule for cache leaves: batch over the data axes; attention
    caches are SEQUENCE-sharded over "model" (flash-decoding-style context
    parallelism — works for any kv-head count, and GSPMD turns the softmax
    over the sharded length into tiny O(B*H) all-reduces); SSM states shard
    their inner dim over "model"."""
    tp = mesh.shape["model"]
    model = lambda s: "model" if (s > 1 and s % tp == 0) else None
    if "conv" in leaf_path:          # (B, k-1, d_in)
        return P(bspec, None, model(shape[2]))
    if "ssm" in leaf_path:
        if len(shape) == 4:          # mamba2 (B, H, P, N)
            return P(bspec, model(shape[1]), None, None)
        return P(bspec, model(shape[1]), None)   # mamba1 (B, d_in, N)
    if "'ks'" in leaf_path or "'vs'" in leaf_path:  # quant scales (B,S,KV)
        return P(bspec, model(shape[1]), None)
    # attention k/v/k8/v8: (B, S, KV, hd) -> shard S
    return P(bspec, model(shape[1]), None, None)


def sharded_caches(cfg: ModelConfig, cell: ShapeCell, mesh):
    dp = dp_axes(mesh)
    bspec = _dim_axes(cell.global_batch, dp, mesh)
    quant = cfg.name in QUANTIZED_KV_ARCHS
    caches = jax.eval_shape(
        lambda: init_caches(cfg, cell.global_batch, cell.seq_len,
                            quantize=quant))

    def f(path, sds):
        # leading layer-stack axis from the stage scan: shape (repeats, ...)
        inner = sds.shape[1:]
        spec = _cache_pspec(path, inner, mesh, bspec)
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=NamedSharding(mesh, P(None, *spec)))

    return jax.tree_util.tree_map_with_path(
        lambda p, x: f(jax.tree_util.keystr(p), x), caches)


def cell_inputs(cfg: ModelConfig, cell: ShapeCell, mesh) -> tuple:
    """-> (kind, args tuple of ShapeDtypeStructs) for the cell's step fn.

    Training uses the per-arch profile ("zero" for small archs = pure
    ZeRO-3 DP over all chips with the batch sharded over both mesh axes;
    "tp" + microbatching for the big ones). Serving always uses "tp"."""
    if cell.kind == "train":
        profile = train_profile(cfg)
        params = sharded_params(cfg, mesh, profile)
        opt = sharded_opt_state(cfg, params, mesh)
        baxes = (dp_axes(mesh) + ("model",) if profile == "zero"
                 else dp_axes(mesh))
        return "train", (params, opt,
                         batch_specs(cfg, cell, mesh, batch_axes=baxes))
    params = sharded_params(cfg, mesh, "tp")
    if cell.kind == "prefill":
        return "prefill", (params, batch_specs(cfg, cell, mesh))
    # decode
    dp = dp_axes(mesh)
    bspec = _dim_axes(cell.global_batch, dp, mesh)
    tok = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32,
                               sharding=NamedSharding(mesh, P(bspec, None)))
    caches = sharded_caches(cfg, cell, mesh)
    clen = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    return "decode", (params, tok, caches, clen)


def step_fn_for(cfg: ModelConfig, kind: str, mesh, *,
                causal_mode: str = "masked_full"):
    """Build the step function matching cell_inputs' sharding decisions."""
    from jax.sharding import PartitionSpec
    from repro.models import (make_decode_step, make_prefill_step,
                              make_train_step)
    dp = dp_axes(mesh)
    if kind == "train":
        profile = train_profile(cfg)
        baxes = dp + ("model",) if profile == "zero" else dp
        dp_spec = PartitionSpec(baxes if len(baxes) > 1 else baxes[0])
        return make_train_step(cfg, causal_mode=causal_mode,
                               dp_spec=dp_spec,
                               microbatches=microbatches_for(cfg))
    dp_spec = PartitionSpec(dp if len(dp) > 1 else dp[0])
    if kind == "prefill":
        return make_prefill_step(cfg, causal_mode=causal_mode,
                                 dp_spec=dp_spec)
    return make_decode_step(cfg)
