"""Production-shaped training driver: config-selected arch, synthetic data
pipeline, AdamW + cosine, checkpoint/resume, failure handling, per-step
stats. At ``--preset smoke`` it trains a reduced config on CPU; on a real
mesh the same driver shards per launch/specs.py.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, TokenStream
from repro.models import init_params, make_train_step
from repro.optim import adamw_init


def save_train_ckpt(path: Path, step: int, params, opt_state, data_state):
    path.mkdir(parents=True, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        {"params": params, "opt": opt_state})
    arrs = {f"a{i}": np.asarray(v) for i, (_, v) in enumerate(flat)}
    np.savez_compressed(path / f"step_{step:07d}.npz", **arrs)
    (path / "meta.json").write_text(json.dumps(
        {"step": step, "data": data_state}))
    (path / "LATEST").write_text(f"step_{step:07d}.npz")


def load_train_ckpt(path: Path, params, opt_state):
    latest = (path / "LATEST").read_text().strip()
    z = np.load(path / latest)
    flat, treedef = jax.tree_util.tree_flatten(
        {"params": params, "opt": opt_state})
    restored = [jnp.asarray(z[f"a{i}"]) for i in range(len(flat))]
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    meta = json.loads((path / "meta.json").read_text())
    return tree["params"], tree["opt"], meta


def train(arch: str, *, steps: int, preset: str = "smoke",
          global_batch: int = 8, seq_len: int = 128,
          ckpt_dir: str | None = None, ckpt_every: int = 50,
          resume: bool = False, log_every: int = 10,
          causal_mode: str = "masked_full"):
    cfg = get_config(arch)
    if preset == "smoke":
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    stream = TokenStream(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=seq_len,
                                    global_batch=global_batch))
    start = 0
    if resume and ckpt_dir and (Path(ckpt_dir) / "LATEST").exists():
        params, opt, meta = load_train_ckpt(Path(ckpt_dir), params, opt)
        stream.restore(meta["data"])
        start = meta["step"]
        print(f"[train] resumed from step {start}")
    step_fn = jax.jit(make_train_step(cfg, total_steps=steps,
                                      warmup=max(steps // 20, 5),
                                      causal_mode=causal_mode))
    hist = []
    t0 = time.time()
    for i in range(start, steps):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if (i + 1) % log_every == 0 or i == start:
            loss = float(metrics["loss"])
            hist.append((i + 1, loss))
            tps = global_batch * seq_len * (i + 1 - start) / \
                max(time.time() - t0, 1e-9)
            print(f"[train] step {i+1}/{steps} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"tok/s={tps:,.0f}", flush=True)
        if ckpt_dir and (i + 1) % ckpt_every == 0:
            save_train_ckpt(Path(ckpt_dir), i + 1, params, opt,
                            stream.state())
    return params, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--causal-mode", default="masked_full")
    args = ap.parse_args()
    _, hist = train(args.arch, steps=args.steps, preset=args.preset,
                    global_batch=args.global_batch, seq_len=args.seq_len,
                    ckpt_dir=args.ckpt_dir, resume=args.resume,
                    causal_mode=args.causal_mode)
    first, last = hist[0][1], hist[-1][1]
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
