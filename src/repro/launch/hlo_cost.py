"""Trip-count-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model is undercounted by its trip count (verified on this
container: an 8-step scanned matmul reports 1/8 the FLOPs of its unrolled
twin). This analyzer walks the post-SPMD optimized HLO text and:

* multiplies every while body by its trip count (parsed from the loop
  condition's comparison constant);
* counts dot/convolution FLOPs from shapes + contracting dims (the
  MXU-relevant FLOPs that the 197 TFLOP/s bf16 peak refers to);
* sums per-device bytes accessed (operands + results of top-level ops in
  each executed computation — post-fusion, a reasonable HBM-traffic proxy);
* sums collective bytes with ring-algorithm per-device link-byte formulas:
    all-gather       out * (g-1)/g
    reduce-scatter   in  * (g-1)/g
    all-reduce       2 * bytes * (g-1)/g
    all-to-all       bytes * (g-1)/g
    collective-permute  bytes

Validated in tests/test_hlo_cost.py against cost_analysis() on while-free
programs and against analytic 6ND on a small unrolled transformer.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

def normalize_cost_analysis(ca) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on older JAX and a
    one-element list of dicts on newer JAX (one per executable). Normalize
    to a plain dict (empty when unavailable)."""
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)


_LAYOUT_RE = re.compile(r"(?<=\])\{[\d,]*\}")
_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_OPCODE_RE = re.compile(r"[\s=]([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id",
             "opt-barrier", "domain", "add-dependency"}


def _arr_bytes(dt: str, dims: str) -> int:
    if dt not in DTYPE_BYTES:
        return 0
    n = DTYPE_BYTES[dt]
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(_arr_bytes(dt, dims) for dt, dims in
               _SHAPE_RE.findall(type_str))


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: dict = field(default_factory=lambda: defaultdict(float))

    def __iadd__(self, o: "Cost") -> "Cost":
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_detail.items():
            self.coll_detail[k] += v
        return self

    def scaled(self, m: float) -> "Cost":
        c = Cost(self.flops * m, self.bytes * m, self.coll_bytes * m)
        c.coll_detail = defaultdict(
            float, {k: v * m for k, v in self.coll_detail.items()})
        return c

    def as_dict(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "collective_bytes": self.coll_bytes,
                "collectives": dict(self.coll_detail)}


@dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    operands: list
    line: str


def _parse(hlo: str):
    """-> (comps: name -> [Op], entry_name)."""
    comps: dict = {}
    cur = None
    entry = None
    for raw in hlo.splitlines():
        s = _LAYOUT_RE.sub("", raw.strip())
        m = re.match(
            r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$", s)
        if m and "=" not in s.split("(")[0]:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is None or "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        name_m = re.search(r"%?([\w.\-]+)\s*$",
                           lhs.replace("ROOT", "").strip())
        if not name_m:
            continue
        opm = _OPCODE_RE.search("=" + rhs)
        opcode = opm.group(1) if opm else ""
        result_type = rhs[:opm.start(1)] if opm else rhs
        after = rhs[opm.end(1):] if opm else ""
        # operands: %names inside the first paren group (before attrs)
        paren = after.split("),")[0] if ")," in after else after
        operands = _OPERAND_RE.findall(paren)
        comps[cur].append(Op(name_m.group(1), opcode, result_type,
                             operands, s))
    return comps, entry


def _attr_comp(line: str, attr: str):
    m = re.search(attr + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')


def _trip_count(cond_ops: list) -> int:
    consts = {o.name: int(re.search(r"constant\((-?\d+)\)", o.line).group(1))
              for o in cond_ops
              if o.opcode == "constant"
              and re.search(r"constant\((-?\d+)\)", o.line)}
    for o in cond_ops:
        if o.opcode == "compare":
            for operand in o.operands:
                if operand in consts:
                    return max(consts[operand], 1)
            m = re.search(r"constant\((-?\d+)\)", o.line)
            if m:
                return max(int(m.group(1)), 1)
    # compare may be wrapped in a fusion; fall back to the largest scalar
    # constant in the condition computation
    if consts:
        return max(max(consts.values()), 1)
    return 1


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    return 2


def _collective_bytes(kind: str, line: str, out_b: int, in_b: int) -> float:
    g = max(_group_size(line), 2)
    frac = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * out_b * frac
    if kind == "all-gather":
        return out_b * frac
    if kind == "reduce-scatter":
        return in_b * frac
    if kind in ("all-to-all", "ragged-all-to-all"):
        return out_b * frac
    return float(out_b)  # collective-permute


def _dot_flops(op: Op, types: dict) -> float:
    res = _SHAPE_RE.findall(op.result_type)
    n = 1
    for dt, dims in res[:1]:
        for d in dims.split(","):
            if d:
                n *= int(d)
    lhs_dims = []
    if op.operands:
        lt = types.get(op.operands[0], "")
        m = _SHAPE_RE.search(lt)
        if m:
            lhs_dims = [int(d) for d in m.group(2).split(",") if d]
    contract = 1
    mc = _CONTRACT_RE.search(op.line)
    if mc and mc.group(1):
        for i in mc.group(1).split(","):
            if i and int(i) < len(lhs_dims):
                contract *= lhs_dims[int(i)]
    return 2.0 * n * contract


def _conv_flops(op: Op, types: dict) -> float:
    res_m = _SHAPE_RE.search(op.result_type)
    if not res_m or len(op.operands) < 2:
        return 0.0
    n = 1
    for d in res_m.group(2).split(","):
        if d:
            n *= int(d)
    km = _SHAPE_RE.search(types.get(op.operands[1], ""))
    if not km:
        return 0.0
    kdims = [int(d) for d in km.group(2).split(",") if d]
    k = 1
    for d in kdims:
        k *= d
    out_feat = max(kdims) if kdims else 1
    return 2.0 * n * max(k // out_feat, 1)


def analyze(hlo_text: str) -> Cost:
    comps, entry = _parse(hlo_text)
    memo: dict = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # cycle guard
        ops = comps.get(name, [])
        types = {o.name: o.result_type for o in ops}
        total = Cost()
        for o in ops:
            total += op_cost(o, types)
        memo[name] = total
        return total

    def op_cost(o: Op, types: dict) -> Cost:
        c = Cost()
        out_b = _type_bytes(o.result_type)
        in_b = sum(_type_bytes(types.get(x, "")) for x in o.operands)
        kind = o.opcode.replace("-start", "")
        if o.opcode in _SKIP_OPS or o.opcode.endswith("-done"):
            return c
        if o.opcode == "dot":
            c.flops += _dot_flops(o, types)
            c.bytes += out_b + in_b
        elif o.opcode == "convolution":
            c.flops += _conv_flops(o, types)
            c.bytes += out_b + in_b
        elif kind in _COLLECTIVES:
            cb = _collective_bytes(kind, o.line, out_b, in_b)
            c.coll_bytes += cb
            c.coll_detail[kind] += cb
            c.bytes += out_b + in_b
        elif o.opcode == "while":
            body = _attr_comp(o.line, "body")
            cond = _attr_comp(o.line, "condition")
            mt = _TRIP_RE.search(o.line)
            if mt:
                trips = max(int(mt.group(1)), 1)
            else:
                trips = _trip_count(comps.get(cond, [])) if cond else 1
            if body:
                c += comp_cost(body).scaled(trips)
        elif o.opcode in ("fusion", "call", "custom-call", "conditional",
                          "async-start", "map", "reduce", "sort",
                          "reduce-window", "select-and-scatter", "scatter"):
            c.bytes += out_b + in_b
            for attr in ("calls", "to_apply", "branch_computations",
                         "called_computations"):
                sub = _attr_comp(o.line, attr)
                if sub and sub in comps:
                    inner = comp_cost(sub)
                    c.flops += inner.flops
                    c.coll_bytes += inner.coll_bytes
                    for k, v in inner.coll_detail.items():
                        c.coll_detail[k] += v
        else:
            c.bytes += out_b + in_b
        return c

    if entry is None:
        entry = next((n for n in comps if n.startswith("main")),
                     list(comps)[-1] if comps else None)
    return comp_cost(entry) if entry else Cost()
