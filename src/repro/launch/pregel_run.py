import os
_argv = __import__("sys").argv
if "--dryrun" in _argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
elif "--devices" in _argv:
    # sharded real-run: fake that many host devices unless the user set
    # their own XLA_FLAGS (or runs on real accelerators)
    try:
        _n = int(_argv[_argv.index("--devices") + 1])
    except (ValueError, IndexError):
        _n = 0
    if _n > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={_n}"

# ^ device count must be set before any jax import.

import argparse      # noqa: E402
import json          # noqa: E402
import math          # noqa: E402
import time          # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import (N_OVERFLOW, EngineConfig, GlobalState, MsgRel,  # noqa: E402
                        PhysicalPlan, VertexRel, make_superstep)
from repro.graph import SSSP, ConnectedComponents, PageRank  # noqa: E402
from repro.launch import hlo_cost  # noqa: E402
from repro.launch.dryrun import HBM_BW, LINK_BW, PEAK_FLOPS  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

ALGOS = {
    "pagerank": lambda n: PageRank(n, iterations=15),
    "sssp": lambda n: SSSP(source=0),
    "cc": lambda n: ConnectedComponents(),
}

# graph scale ladder: 'paper-large' is Webmap-Large (1.4B vertices / 8B
# edges); 'bigger-4x' is 4x that — Big(ger) Graph Analytics on a 512-chip
# multi-pod mesh.
GRAPH_SCALES = {
    "paper-large": (1_413_511_390, 8_050_112_169),
    "bigger-4x": (5_654_045_560, 32_200_448_676),
}


def dryrun_capacities(n_vertices: int, n_edges: int, P_total: int):
    """Per-partition vertex/edge slot capacities the dry-run lowers with
    (the load_graph slack factors applied to uniform partitioning)."""
    Np = int(math.ceil(n_vertices / P_total * 1.3)) + 1
    Ep = int(math.ceil(n_edges / P_total * 1.2)) + 1
    return Np, Ep


def abstract_graph_state(n_vertices: int, n_edges: int, P_total: int,
                         program, plan: PhysicalPlan, mesh):
    Np, Ep = dryrun_capacities(n_vertices, n_edges, P_total)
    if plan.sender_combine:
        cap = min(int((Ep / P_total + 8) * 1.5), Np + 8)
    else:
        cap = int((Ep / P_total + 8) * 1.5)
    ec = EngineConfig(n_parts=P_total, bucket_cap=max(cap, 8),
                      frontier_cap=int(Np * plan.frontier_capacity) + 8,
                      axis_name=tuple(mesh.axis_names))
    V, D = program.value_dims, program.msg_dims
    M = P_total * ec.bucket_cap
    sds = jax.ShapeDtypeStruct
    vert = VertexRel(
        vid=sds((P_total, Np), jnp.int32),
        halt=sds((P_total, Np), jnp.bool_),
        value=sds((P_total, Np, V), jnp.float32),
        edge_src=sds((P_total, Ep), jnp.int32),
        edge_dst=sds((P_total, Ep), jnp.int32),
        edge_val=sds((P_total, Ep), jnp.float32))
    msg = MsgRel(dst=sds((P_total, M), jnp.int32),
                 payload=sds((P_total, M, D), jnp.float32),
                 valid=sds((P_total, M), jnp.bool_))
    gs = GlobalState(halt=sds((), jnp.bool_),
                     aggregate=sds((program.agg_dims,), jnp.float32),
                     superstep=sds((), jnp.int32),
                     overflow=sds((N_OVERFLOW,), jnp.int32),
                     active_count=sds((), jnp.int32),
                     msg_count=sds((), jnp.int32))
    return vert, msg, gs, ec


def pregel_dryrun(algo: str, scale: str, mesh_kind: str,
                  plan) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    P_total = mesh.devices.size
    axes = tuple(mesh.axis_names)
    n_v, n_e = GRAPH_SCALES[scale]
    program = ALGOS[algo](n_v)
    if plan == "auto":
        # static choice at superstep-0 statistics (all vertices active);
        # the host drivers re-choose mid-run, the dry-run cannot
        from repro.planner import GraphStats, Observation, choose
        Np, Ep = dryrun_capacities(n_v, n_e, P_total)
        g = GraphStats(n_vertices=n_v, n_edges=n_e, n_partitions=P_total,
                       vertex_capacity=Np, edge_capacity=Ep,
                       value_dims=program.value_dims,
                       msg_dims=program.msg_dims)
        plan, _ = choose(program, g, Observation(frontier_density=1.0))
        print(f"  auto-plan -> join={plan.join} groupby={plan.groupby} "
              f"connector={plan.connector} "
              f"sender_combine={plan.sender_combine}", flush=True)
    vert, msg, gs, ec = abstract_graph_state(n_v, n_e, P_total, program,
                                             plan, mesh)
    step = make_superstep(program, plan, ec)

    part = P(axes)  # partition axis sharded over the whole (multi-pod) mesh
    spec_of = lambda sds_tree, leading: jax.tree.map(
        lambda x: P(*( [leading] + [None] * (len(x.shape) - 1))), sds_tree)
    in_specs = (spec_of(vert, axes), spec_of(msg, axes),
                jax.tree.map(lambda x: P(), gs))
    out_specs = in_specs
    try:
        from jax import shard_map
    except ImportError:   # JAX < 0.6 keeps shard_map in experimental
        from jax.experimental.shard_map import shard_map
    try:
        fn = shard_map(step, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    except TypeError:     # older shard_map spells check_vma check_rep
        fn = shard_map(step, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn).lower(vert, msg, gs)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = hlo_cost.analyze(compiled.as_text())
    terms = {"compute_s": cost.flops / PEAK_FLOPS,
             "memory_s": cost.bytes / HBM_BW,
             "collective_s": cost.coll_bytes / LINK_BW}
    return {
        "arch": f"pregelix-{algo}", "shape": scale, "mesh": mesh_kind,
        "status": "ok", "kind": "superstep", "chips": P_total,
        "plan": dataclass_dict(plan),
        "compile_s": round(time.time() - t0, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "total_per_device_bytes": (mem.argument_size_in_bytes +
                                       mem.temp_size_in_bytes),
        },
        "per_device": {"flops": cost.flops, "bytes": cost.bytes,
                       "collective_bytes": cost.coll_bytes,
                       "collectives": dict(cost.coll_detail)},
        "roofline": {**terms,
                     "dominant": max(terms, key=terms.get),
                     "bound_s": max(terms.values())},
    }


def dataclass_dict(p):
    import dataclasses
    return dataclasses.asdict(p)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--algo", default="pagerank", choices=list(ALGOS))
    ap.add_argument("--scale", default="paper-large",
                    choices=list(GRAPH_SCALES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both", "host",
                             "production"],
                    help="--dryrun: single|multi|both pod lowering. "
                         "Real runs: host = 1-D mesh over the host's "
                         "devices (see --devices), production = the "
                         "(16,16) pod mesh; both select the sharded "
                         "multi-device driver (core/sharded.py)")
    ap.add_argument("--devices", type=int, default=0,
                    help="run the real (non-dryrun) job SHARDED over this "
                         "many devices via run_sharded: supersteps "
                         "execute under shard_map with the bucket "
                         "exchange as a jax.lax.all_to_all. On CPU the "
                         "launcher fakes the device count via XLA_FLAGS "
                         "automatically; composes with --ooc for "
                         "per-worker tiered stores")
    ap.add_argument("--join", default="full_outer")
    ap.add_argument("--groupby", default="scatter")
    ap.add_argument("--connector", default="partitioning")
    ap.add_argument("--sender-combine", type=int, default=1)
    ap.add_argument("--partition", default="hash", choices=["hash","range"])
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "ref", "pallas", "pallas_tpu"],
                    help="superstep hot-path kernel dispatch "
                         "(kernels/backend.py): auto resolves per backend "
                         "(compiled Pallas on TPU, jnp reference "
                         "elsewhere); pallas forces the kernels "
                         "(interpret mode off-TPU); ref forces the jnp "
                         "path. With --auto-plan the planner prices both "
                         "and the chosen plan carries the winner")
    ap.add_argument("--auto-plan", action="store_true",
                    help="let the cost-based planner pick (and, in the "
                         "real-run mode, mid-run re-pick) the plan")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default="results/dryrun")
    # non-dryrun demo mode
    ap.add_argument("--dataset", default="webmap-tiny")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--ooc", action="store_true",
                    help="run out-of-core: stream super-partitions "
                         "through the device within --budget-partitions")
    ap.add_argument("--budget-partitions", type=int, default=0,
                    help="device-memory budget in partitions for --ooc "
                         "(default: parts // 2)")
    ap.add_argument("--stream", dest="stream", action="store_true",
                    default=True,
                    help="pipeline the --ooc super-partition stream: "
                         "prefetch the next upload and drain the previous "
                         "result while the current one computes (default)")
    ap.add_argument("--no-stream", dest="stream", action="store_false",
                    help="synchronous --ooc loop: upload, step, block, "
                         "collect per super-partition")
    ap.add_argument("--barrier-free", dest="barrier_free",
                    action="store_true", default=True,
                    help="barrier-free superstep pipeline (default): "
                         "rebuild each destination's inbox chunk and "
                         "apply its mutations per-destination, "
                         "overlapped with the next superstep's compute "
                         "— no global inter-superstep barrier")
    ap.add_argument("--no-barrier-free", dest="barrier_free",
                    action="store_false",
                    help="keep the global superstep barrier (the PR-4 "
                         "executor): full inbox rebuild + mutation "
                         "apply between supersteps")
    ap.add_argument("--io-threads", type=int, default=None,
                    help="background page-I/O engine worker threads for "
                         "the --ooc disk tier (default: 1 when "
                         "--disk-dir is set, else 0); readahead of the "
                         "next destination's pages + coalesced "
                         "dirty-page drain off the critical path")
    ap.add_argument("--readahead-pages", type=int, default=8,
                    help="max pages the I/O engine prefetches per "
                         "dispatch tick (disk tier only)")
    ap.add_argument("--disk-dir", default=None,
                    help="--ooc disk tier: spill directory for the "
                         "buffer cache's page files (enables the "
                         "HBM <-> DRAM <-> disk hierarchy)")
    ap.add_argument("--memory-budget-bytes", type=int, default=None,
                    help="--ooc disk tier: host-DRAM byte budget for "
                         "the page cache (requires --disk-dir); cold "
                         "pages spill to disk and fault back on access")
    ap.add_argument("--eviction", default="lru", choices=["lru", "mru"],
                    help="--ooc disk tier page-replacement policy: lru, "
                         "or mru (resists the superstep's cyclic "
                         "sequential scan)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot the run every N supersteps into "
                         "--checkpoint-dir (required with --recover so "
                         "a failure has something to restore)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for checkpoints (npz for host/"
                         "sharded, hard-linked page snapshots for --ooc)")
    ap.add_argument("--recover", action="store_true",
                    help="run under the failure manager's recovery "
                         "supervisor: recoverable failures (worker loss, "
                         "disk I/O, page/checkpoint corruption) restore "
                         "the latest VALID checkpoint onto the surviving "
                         "workers and replay; application errors forward")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="recovery attempts before the failure is "
                         "forwarded (default 3); also the per-worker "
                         "recoverable-failure budget before blacklisting")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a span timeline of the run and write it "
                         "as Chrome trace-event JSON to PATH (load in "
                         "chrome://tracing or https://ui.perfetto.dev)")
    ap.add_argument("--progress", action="store_true",
                    help="print one human-readable line per superstep "
                         "(active frontier, messages, wall, cache hit "
                         "rate, readiness stall, current plan)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the per-superstep metrics registry "
                         "snapshots (counters / gauges / histogram "
                         "percentiles) collected in SuperstepStats")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write a schema-validated run report "
                         "(pregelix-run-report/v1 JSON) to PATH: the "
                         "per-superstep predicted-vs-measured plan audit, "
                         "controller decision log, and HBM/DRAM/SSD tier "
                         "occupancy peaks; validate or diff with "
                         "python -m repro.obs.report")
    ap.add_argument("--explain", action="store_true",
                    help="print the plan-audit ledger after the run: one "
                         "row per superstep with the chosen plan's "
                         "predicted cost terms next to the measured leg "
                         "times and a log-ratio drift score, plus every "
                         "replan/recalibrate decision with the candidate "
                         "price table it was made from")
    args = ap.parse_args()

    plan = "auto" if args.auto_plan else PhysicalPlan(
        join=args.join, groupby=args.groupby,
        connector=args.connector,
        sender_combine=bool(args.sender_combine),
        partition=args.partition,
        kernel_impl=args.kernel_impl)
    if args.dryrun:
        out_dir = Path(args.out)
        out_dir.mkdir(parents=True, exist_ok=True)
        meshes = (["single", "multi"] if args.mesh == "both"
                  else [args.mesh])
        for mk in meshes:
            name = f"{args.tag}_pregelix-{args.algo}_{args.scale}_{mk}.json"
            print(f"[pregel-dryrun] {args.algo} x {args.scale} x {mk}",
                  flush=True)
            try:
                rec = pregel_dryrun(args.algo, args.scale, mk, plan)
            except Exception as e:  # noqa: BLE001
                import traceback
                rec = {"status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-3000:]}
            (out_dir / name).write_text(json.dumps(rec, indent=1))
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(f"  ok compile={rec['compile_s']}s "
                      f"mem/dev={rec['memory']['total_per_device_bytes']/2**30:.2f}GiB "
                      f"dominant={r['dominant']}", flush=True)
            else:
                print("  error:", rec["error"][:200], flush=True)
        return

    # small-scale real run (CPU demo)
    import numpy as np
    from repro.core import gather_values, load_graph, run_host
    from repro.graph import DATASETS
    from repro.obs import (explain, fmt_plan, memwatch, progress_line,
                           report, trace, write_chrome_trace)
    edges, n = DATASETS[args.dataset]()
    program = ALGOS[args.algo](n)
    vert = load_graph(edges, n, P=args.parts,
                      value_dims=program.value_dims)
    from repro.runtime import faults
    faults.install_from_env()   # REPRO_FAULT_PLAN: chaos harness
    if args.recover and not args.checkpoint_dir:
        ap.error("--recover needs --checkpoint-dir (and a nonzero "
                 "--checkpoint-every) so a failure has a snapshot "
                 "to restore")
    ft_kw = dict(checkpoint_every=args.checkpoint_every,
                 checkpoint_dir=args.checkpoint_dir,
                 recover=args.recover, max_retries=args.max_retries)
    if args.trace:
        trace.start()
    if args.report or args.explain:
        explain.start()
        memwatch.start()
    show = None
    if args.progress:
        plan_tag = None if plan == "auto" else plan

        def show(i, rec):
            print(progress_line(rec, plan_tag, n_vertices=n), flush=True)
    sharded = args.devices > 1 or args.mesh in ("host", "production")
    if sharded:
        from repro.core.sharded import run_sharded
        from repro.launch.mesh import make_host_mesh
        mesh = (make_production_mesh() if args.mesh == "production"
                else make_host_mesh(args.devices or None))
        n_dev = int(mesh.devices.size)
        kimp = (args.kernel_impl if args.auto_plan
                and args.kernel_impl != "auto" else None)
        ooc_kw = {}
        tier = ""
        if args.ooc:
            per_worker = args.parts // n_dev
            budget = args.budget_partitions
            if budget and per_worker % budget:
                ap.error(f"--budget-partitions {budget} must divide the "
                         f"per-worker block {per_worker} "
                         f"(--parts {args.parts} / {n_dev} devices)")
            if not budget:
                budget = next(b for b in
                              range(max(per_worker // 2, 1), 0, -1)
                              if per_worker % b == 0)
            if args.memory_budget_bytes and not args.disk_dir:
                ap.error("--memory-budget-bytes requires --disk-dir "
                         "(a budget needs somewhere to spill)")
            ooc_kw = dict(budget_partitions=budget,
                          disk_dir=args.disk_dir,
                          memory_budget_bytes=args.memory_budget_bytes,
                          io_threads=args.io_threads,
                          readahead_pages=args.readahead_pages,
                          eviction=args.eviction)
            tier = (f", ooc budget={budget}/{per_worker} per worker" +
                    (f", disk tier at {args.disk_dir}/worker*"
                     f" [{args.eviction}]" if args.disk_dir else ""))
        if args.ooc and (args.checkpoint_every or args.recover):
            # sharded npz checkpointing is in-memory mode only; recover
            # without checkpoints would only restart from scratch
            ft_kw = dict(recover=args.recover,
                         max_retries=args.max_retries)
        res = run_sharded(vert, program, plan, mesh=mesh,
                          max_supersteps=40, kernel_impl=kimp,
                          on_superstep=show, **ooc_kw, **ft_kw)
        mode = f"sharded x{n_dev} devices{tier}"
        ex = [s for s in res.stats if "exchange_stall_s" in s]
        if ex:
            print(f"exchange: {sum(s['exchange_stall_s'] for s in ex):.3f}s "
                  f"stall, "
                  f"{sum(s['exchange_bytes'] for s in ex) / 2**20:.1f} MiB "
                  f"over {len(ex)} supersteps on {n_dev} workers")
    elif args.ooc:
        from repro.core.ooc import run_out_of_core
        budget = args.budget_partitions
        if budget and args.parts % budget:
            ap.error(f"--budget-partitions {budget} must divide "
                     f"--parts {args.parts}")
        if not budget:   # largest divisor of parts that is <= parts // 2
            budget = next(b for b in range(max(args.parts // 2, 1), 0, -1)
                          if args.parts % b == 0)
        if args.memory_budget_bytes and not args.disk_dir:
            ap.error("--memory-budget-bytes requires --disk-dir "
                     "(a budget needs somewhere to spill)")
        # pin the kernel dispatch inside the auto-planner's search space
        # (a concrete plan already carries it from the CLI knob)
        kimp = (args.kernel_impl if args.auto_plan
                and args.kernel_impl != "auto" else None)
        res = run_out_of_core(vert, program, plan,
                              budget_partitions=budget, max_supersteps=40,
                              kernel_impl=kimp,
                              stream=args.stream,
                              barrier_free=args.barrier_free,
                              memory_budget_bytes=args.memory_budget_bytes,
                              disk_dir=args.disk_dir,
                              eviction=args.eviction,
                              io_threads=args.io_threads,
                              readahead_pages=args.readahead_pages,
                              on_superstep=show, **ft_kw)
        tier = (f", disk tier at {args.disk_dir} "
                f"[{args.eviction}]" if args.disk_dir else "")
        exe = ("synchronous" if not args.stream else
               "barrier-free" if args.barrier_free else "streaming")
        mode = (f"out-of-core (budget={budget}/{args.parts} partitions, "
                f"{exe}{tier})")
    else:
        host_cb = ((lambda i, v, m, g, rec: show(i, rec))
                   if show is not None else None)
        kimp = (args.kernel_impl if args.auto_plan
                and args.kernel_impl != "auto" else None)
        res = run_host(vert, program, plan, max_supersteps=40,
                       kernel_impl=kimp, on_superstep=host_cb, **ft_kw)
        mode = "in-memory"
    vals = gather_values(res.vertex, n)
    print(f"{args.algo} on {args.dataset} [{mode}]: "
          f"{res.supersteps} supersteps, {res.wall_s:.2f}s wall")
    for ev in getattr(res, "recovery", ()) or ():
        print(f"recovery #{ev.get('attempt')}: restored from "
              f"{ev.get('restored_from') or 'initial relations'} onto "
              f"{ev.get('healthy_workers')} worker(s) "
              f"(blacklist {ev.get('blacklist') or '[]'}) after "
              f"{ev.get('error')}")
    if args.ooc and args.disk_dir:
        recs = [s for s in res.stats if "cache_hit_rate" in s]
        if recs:
            hr = sum(s["cache_hit_rate"] for s in recs) / len(recs)
            sb = sum(s["spill_read_bytes"] + s["spill_write_bytes"]
                     for s in recs)
            qd = max((s.get("io_queue_depth", 0) for s in recs),
                     default=0)
            print(f"disk tier: mean page hit rate {hr:.2f}, "
                  f"{sb / 2**20:.1f} MiB spilled, "
                  f"io queue depth peak {qd}")
    if args.ooc:
        recs = [s for s in res.stats if "readiness_stall_s" in s]
        if recs:
            stall = sum(s["readiness_stall_s"] for s in recs)
            print(f"readiness stall: {stall:.3f}s total over "
                  f"{len(recs)} supersteps "
                  f"({'barrier-free' if args.barrier_free and args.stream else 'barrier'})")
    if args.auto_plan:
        switches = [s for s in res.stats
                    if s.get("event") == "plan-switch"]
        print(f"final plan: join={res.plan.join} "
              f"groupby={res.plan.groupby} "
              f"connector={res.plan.connector} "
              f"sender_combine={res.plan.sender_combine} "
              f"storage={res.plan.storage}; "
              f"{len(switches)} plan switch(es)")
        for s in switches:
            print(f"  superstep {s['superstep']}: -> join={s['join']} "
                  f"connector={s['connector']} "
                  f"sender_combine={s['sender_combine']} "
                  f"storage={s.get('storage', '-')}")
    print("per-superstep:", [round(s['wall_s'], 3) for s in res.stats
                             if 'wall_s' in s])
    if args.metrics:
        for s in res.stats:
            m = s.get("metrics")
            if not m:
                continue
            print(f"metrics @ superstep {s.get('superstep', '?')}:")
            for name in sorted(m):
                snap = m[name]
                if isinstance(snap, dict):   # histogram percentiles
                    body = "  ".join(
                        f"{k}={v:.4g}" for k, v in snap.items())
                else:
                    body = f"{snap:.6g}"
                print(f"  {name:<22} {body}")
    if args.report or args.explain:
        aud = explain.stop()
        mem = memwatch.stop()
        rep = report.build_report(
            stats=res.stats, explain=aud, memwatch=mem,
            recovery=getattr(res, "recovery", None),
            meta={"algo": args.algo, "dataset": args.dataset,
                  "mode": mode, "parts": args.parts,
                  "plan": fmt_plan(res.plan),
                  "supersteps": res.supersteps,
                  "wall_s": res.wall_s})
        if args.explain:
            print(report.to_markdown(rep))
        if args.report:
            report.write_report(args.report, rep)
            errs = report.validate_report(rep)
            print(f"report: {args.report} "
                  f"({len(rep['supersteps'])} supersteps, "
                  f"{len(rep['decisions'])} decisions, "
                  f"{len(errs)} schema violation(s))")
    if args.trace:
        tracer = trace.stop()
        summary = write_chrome_trace(args.trace, tracer)
        print(f"trace: {args.trace} "
              f"({summary['spans']} spans on "
              f"{summary['span_threads']} thread(s); load in "
              f"chrome://tracing or ui.perfetto.dev)")
    print("value head:", vals[:5, 0])


if __name__ == "__main__":
    main()
