"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (smoke tests, benches) sees 1 device.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devs)} present; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_host_mesh(devices: int | None = None):
    """A 1-D (data,) mesh over the host's devices — CPU examples/tests
    and the sharded driver's default. ``devices`` pins an explicit count
    (the ``pregel_run --devices N`` knob); None takes everything
    present. Raises when more devices are requested than exist — the
    caller forgot ``XLA_FLAGS=--xla_force_host_platform_device_count``."""
    devs = jax.devices()
    if devices is not None:
        if devices > len(devs):
            raise RuntimeError(
                f"requested a {devices}-device host mesh but only "
                f"{len(devs)} device(s) present; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={devices} "
                "before the first jax import")
        devs = devs[:devices]
    return jax.make_mesh((len(devs),), ("data",), devices=devs)


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (('pod','data') when multi-pod)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def batch_axis_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
