"""Serving driver: batched prefill + greedy decode loop with KV caches
(int8-quantizable). ``--preset smoke`` serves a reduced config on CPU."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import (init_params, make_decode_step, make_prefill_step)


def serve(arch: str, *, preset: str = "smoke", batch: int = 4,
          prompt_len: int = 64, max_new: int = 32, seed: int = 0):
    cfg = get_config(arch)
    if preset == "smoke":
        cfg = cfg.reduced()
    if cfg.is_encoder:
        raise SystemExit(f"{arch} is encoder-only: no decode service")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(seed)
    prompts = jax.random.randint(rng, (batch, prompt_len), 0,
                                 cfg.vocab_size)
    batch_in = {"tokens": prompts, "labels": prompts}
    if cfg.frontend == "vision":
        batch_in["patch_embeds"] = jnp.zeros(
            (batch, cfg.frontend_len, cfg.d_model), jnp.float32)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    t0 = time.time()
    tok, caches = prefill(params, batch_in)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0
    out = [np.asarray(tok)]
    t0 = time.time()
    for i in range(max_new - 1):
        tok, caches = decode(params, tok, caches, jnp.int32(prompt_len + i))
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.concatenate(out, axis=1)
    print(f"[serve] {arch}: batch={batch} prompt={prompt_len} "
          f"new={max_new}")
    print(f"[serve] prefill {t_prefill*1e3:.0f}ms, decode "
          f"{t_decode / max(max_new - 1, 1) * 1e3:.1f}ms/token")
    print(f"[serve] sample generation ids: {gen[0][:16].tolist()}")
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()
    serve(args.arch, preset=args.preset, batch=args.batch,
          prompt_len=args.prompt_len, max_new=args.max_new)


if __name__ == "__main__":
    main()
