import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax import: jax locks the device count on first init.
# Smoke tests and benches do NOT get this (they see 1 device); only the
# dry-run builds the 256/512-chip production meshes.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import ALL_ARCHS, SHAPES, get_config, runnable_cells  # noqa: E402
from repro.launch import hlo_cost  # noqa: E402
from repro.launch.mesh import dp_axes, make_production_mesh  # noqa: E402
from repro.launch.specs import cell_inputs, step_fn_for  # noqa: E402

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


def run_cell(arch: str, shape: str, mesh_kind: str, *,
             causal_mode: str = "masked_full", out_dir: Path,
             tag: str = "baseline") -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    skip = runnable_cells(cfg)[shape]
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "tag": tag,
           "causal_mode": causal_mode}
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    t0 = time.time()
    with mesh:
        kind, args = cell_inputs(cfg, cell, mesh)
        fn = step_fn_for(cfg, kind, mesh, causal_mode=causal_mode)
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        xla_cost = hlo_cost.normalize_cost_analysis(compiled.cost_analysis())
        cost = hlo_cost.analyze(compiled.as_text())

    tokens = cell.global_batch * (cell.seq_len if kind == "train" else
                                  cell.seq_len if kind == "prefill" else 1)
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    mult = 6 if kind == "train" else 2
    model_flops = mult * n_active * tokens
    per_dev = {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.coll_bytes,
        "collectives": dict(cost.coll_detail),
    }
    terms = {
        "compute_s": cost.flops / PEAK_FLOPS,
        "memory_s": cost.bytes / HBM_BW,
        "collective_s": cost.coll_bytes / LINK_BW,
    }
    dom = max(terms, key=terms.get)
    rec.update({
        "status": "ok",
        "kind": kind,
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "total_per_device_bytes": (mem.argument_size_in_bytes +
                                       mem.temp_size_in_bytes),
        },
        "per_device": per_dev,
        "xla_cost_analysis_flops": xla_cost.get("flops"),
        "roofline": {
            **terms,
            "dominant": dom,
            "bound_s": max(terms.values()),
            "model_flops_total": model_flops,
            "model_flops_per_device": model_flops / chips,
            "useful_flops_ratio": (model_flops / chips) / max(cost.flops, 1),
            "roofline_fraction": (model_flops / chips / PEAK_FLOPS) /
            max(max(terms.values()), 1e-30),
        },
        "params": n_params,
        "active_params": n_active,
    })
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--causal-mode", default="masked_full")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = ALL_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else \
        [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                fname = out_dir / f"{args.tag}_{arch}_{shape}_{mesh_kind}.json"
                if fname.exists():
                    print(f"[dryrun] SKIP(existing) {fname.name}", flush=True)
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_kind} ...",
                      flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_kind,
                                   causal_mode=args.causal_mode,
                                   out_dir=out_dir, tag=args.tag)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "tag": args.tag, "status": "error",
                           "error": repr(e),
                           "traceback": traceback.format_exc()[-3000:]}
                fname.write_text(json.dumps(rec, indent=1))
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "error"
                if st == "ok":
                    r = rec["roofline"]
                    print(f"  ok compile={rec['compile_s']}s "
                          f"mem/dev={rec['memory']['total_per_device_bytes']/2**30:.2f}GiB "
                          f"dominant={r['dominant']} "
                          f"roofline_frac={r['roofline_fraction']:.3f}",
                          flush=True)
                else:
                    print(f"  {st}: {rec.get('reason', rec.get('error'))}"[:300],
                          flush=True)
    print(f"[dryrun] done ok={n_ok} skipped={n_skip} failed={n_fail}",
          flush=True)
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
