from repro.graph.algorithms import (BFS, SSSP, ConnectedComponents,
                                    PageRank, PathMerge, Reachability)
from repro.graph.generators import (DATASETS, chain_graph, rmat_graph,
                                    random_walk_sample, uniform_graph)

__all__ = ["BFS", "SSSP", "ConnectedComponents", "PageRank", "PathMerge",
           "Reachability", "DATASETS", "chain_graph", "rmat_graph",
           "random_walk_sample", "uniform_graph"]
