"""Synthetic graph generators — stand-ins for the paper's Webmap (power-law
web crawl) and BTC (semantic graph, near-uniform degree) datasets, plus the
random-walk down-sampler the paper used to build Webmap samples.
"""
from __future__ import annotations

import numpy as np


def rmat_graph(n_vertices: int, n_edges: int, *, seed: int = 0,
               a=0.57, b=0.19, c=0.19) -> np.ndarray:
    """R-MAT power-law generator (Webmap stand-in). -> (E, 2) int64."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(n_vertices, 2))))
    src = np.zeros(n_edges, np.int64)
    dst = np.zeros(n_edges, np.int64)
    for lvl in range(scale):
        r = rng.random(n_edges)
        go_right_src = r > (a + b)                 # c + d quadrants
        go_right_dst = ((r > a) & (r <= a + b)) | (r > a + b + c)
        src |= go_right_src.astype(np.int64) << lvl
        dst |= go_right_dst.astype(np.int64) << lvl
    src %= n_vertices
    dst %= n_vertices
    keep = src != dst
    return np.stack([src[keep], dst[keep]], axis=1)


def uniform_graph(n_vertices: int, n_edges: int, *, seed: int = 0,
                  undirected: bool = True) -> np.ndarray:
    """Near-uniform-degree generator (BTC stand-in: avg degree ~8.94 across
    all sample sizes)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges)
    dst = rng.integers(0, n_vertices, n_edges)
    keep = src != dst
    e = np.stack([src[keep], dst[keep]], axis=1)
    if undirected:
        e = np.concatenate([e, e[:, ::-1]], axis=0)
    return e


def grid_graph(side: int) -> np.ndarray:
    """2-D lattice (road-network stand-in: high diameter, small frontier —
    the regime where the paper's left-outer join wins SSSP). Directed both
    ways. -> (E, 2)."""
    idx = np.arange(side * side).reshape(side, side)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))
    e = np.concatenate(e, 0)
    return np.concatenate([e, e[:, ::-1]], 0)


def chain_graph(n_vertices: int) -> np.ndarray:
    """Simple path (genome-assembly path-merging demo)."""
    v = np.arange(n_vertices - 1, dtype=np.int64)
    return np.stack([v, v + 1], axis=1)


def random_walk_sample(edges: np.ndarray, n_vertices: int,
                       target_vertices: int, *, seed: int = 0,
                       restart: float = 0.15) -> np.ndarray:
    """Random-walk graph sampler (the paper built Webmap samples with a
    Pregelix random-walk sampler; this is the numpy equivalent). Returns
    the induced edge list on the visited vertex set."""
    rng = np.random.default_rng(seed)
    order = np.argsort(edges[:, 0], kind="stable")
    se = edges[order]
    starts = np.searchsorted(se[:, 0], np.arange(n_vertices + 1))
    visited = set()
    cur = int(rng.integers(n_vertices))
    visited.add(cur)
    steps = 0
    while len(visited) < target_vertices and steps < target_vertices * 50:
        steps += 1
        lo, hi = starts[cur], starts[cur + 1]
        if hi <= lo or rng.random() < restart:
            cur = int(rng.integers(n_vertices))
        else:
            cur = int(se[int(rng.integers(lo, hi)), 1])
        visited.add(cur)
    keep = np.fromiter((int(s) in visited and int(d) in visited
                        for s, d in edges), bool, len(edges))
    sub = edges[keep]
    # renumber
    ids = {v: i for i, v in enumerate(sorted(visited))}
    out = np.array([[ids[int(s)], ids[int(d)]] for s, d in sub],
                   np.int64).reshape(-1, 2)
    return out


# named dataset registry (sizes scaled for a single host; the paper's Table
# 3/4 relative ladder is preserved: each step ~2x)
DATASETS = {
    "webmap-tiny": lambda: (rmat_graph(20_000, 240_000, seed=1), 20_000),
    "webmap-xsmall": lambda: (rmat_graph(40_000, 560_000, seed=2), 40_000),
    "webmap-small": lambda: (rmat_graph(80_000, 820_000, seed=3), 80_000),
    "webmap-medium": lambda: (rmat_graph(160_000, 1_200_000, seed=4),
                              160_000),
    "webmap-large": lambda: (rmat_graph(320_000, 1_800_000, seed=5),
                             320_000),
    "btc-tiny": lambda: (uniform_graph(30_000, 90_000, seed=6), 30_000),
    "btc-xsmall": lambda: (uniform_graph(60_000, 270_000, seed=7), 60_000),
    "btc-small": lambda: (uniform_graph(120_000, 540_000, seed=8), 120_000),
    "btc-medium": lambda: (uniform_graph(240_000, 1_070_000, seed=9),
                           240_000),
    "btc-large": lambda: (uniform_graph(480_000, 2_140_000, seed=10),
                          480_000),
}
