"""The Pregelix built-in algorithm library (paper Section 6): PageRank,
SSSP, connected components, BFS, reachability — as vectorized
VertexPrograms. Each ``main``-style hint block mirrors the paper's Figure 9
(join / group-by / connector choices per algorithm).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.plan import PhysicalPlan
from repro.core.program import ComputeOut, VertexProgram

INF = jnp.float32(3.4e38)


class PageRank(VertexProgram):
    """value = [rank, out_degree]. Messages = rank contributions (sum).
    Paper hint: full-outer join (message-dense), sort/scatter group-by."""

    value_dims = 2
    msg_dims = 1
    agg_dims = 1
    combine_op = "sum"
    suggested_plan = PhysicalPlan(join="full_outer", groupby="scatter",
                                  sender_combine=True)

    def __init__(self, num_vertices: int, damping: float = 0.85,
                 iterations: int = 15):
        self.n = num_vertices
        self.d = damping
        self.iters = iterations

    def init_value(self, vid, out_degree, gs):
        rank = jnp.full(vid.shape, 1.0 / self.n, jnp.float32)
        return jnp.stack([rank, out_degree], axis=-1)

    def compute(self, vid, value, msg, has_msg, active, gs):
        incoming = msg[..., 0]
        rank = jnp.where(gs.superstep == 0, value[..., 0],
                         (1.0 - self.d) / self.n + self.d * incoming)
        new_val = jnp.stack([rank, value[..., 1]], axis=-1)
        last = gs.superstep >= self.iters - 1
        return ComputeOut(value=new_val,
                          halt=jnp.broadcast_to(last, vid.shape),
                          send_gate=jnp.broadcast_to(~last, vid.shape),
                          aggregate=rank[..., None])

    def send(self, src_vid, src_value, edge_val, dst_vid, gs):
        deg = jnp.maximum(src_value[..., 1], 1.0)
        return (src_value[..., 0] / deg)[..., None]


class SSSP(VertexProgram):
    """Single source shortest paths (paper Figure 9). value = [dist].
    Messages = candidate distances (min). Paper hint: LEFT-OUTER join +
    HashSort group-by + unmerged connector — message-sparse."""

    value_dims = 1
    msg_dims = 1
    agg_dims = 1
    combine_op = "min"
    suggested_plan = PhysicalPlan(join="left_outer", groupby="scatter",
                                  connector="partitioning",
                                  sender_combine=True)

    def __init__(self, source: int):
        self.source = source

    def init_value(self, vid, out_degree, gs):
        dist = jnp.where(vid == self.source, 0.0, INF)
        return dist[..., None]

    def compute(self, vid, value, msg, has_msg, active, gs):
        cur = value[..., 0]
        incoming = jnp.where(has_msg, msg[..., 0], INF)
        first = gs.superstep == 0
        new = jnp.minimum(cur, incoming)
        improved = new < cur
        seed = first & (vid == self.source)
        send = improved | seed
        return ComputeOut(value=new[..., None],
                          halt=jnp.ones_like(send),  # vote halt; msgs re-activate
                          send_gate=send,
                          aggregate=jnp.where(new < INF, 1.0, 0.0)[..., None])

    def send(self, src_vid, src_value, edge_val, dst_vid, gs):
        return (src_value[..., 0] + edge_val)[..., None]


class ConnectedComponents(VertexProgram):
    """Label propagation: min component id (paper's CC). Dense early,
    sparse late — either join plan is reasonable (Figure 14c)."""

    value_dims = 1
    msg_dims = 1
    agg_dims = 1
    combine_op = "min"
    suggested_plan = PhysicalPlan(join="full_outer", groupby="scatter",
                                  sender_combine=True)

    def init_value(self, vid, out_degree, gs):
        return jnp.where(vid >= 0, vid, 0).astype(jnp.float32)[..., None]

    def compute(self, vid, value, msg, has_msg, active, gs):
        cur = value[..., 0]
        incoming = jnp.where(has_msg, msg[..., 0], INF)
        new = jnp.minimum(cur, incoming)
        improved = new < cur
        first = gs.superstep == 0
        send = improved | first
        return ComputeOut(value=new[..., None],
                          halt=jnp.ones_like(send),
                          send_gate=send,
                          aggregate=jnp.zeros(vid.shape + (1,)))

    def send(self, src_vid, src_value, edge_val, dst_vid, gs):
        return src_value[..., 0:1]


class BFS(VertexProgram):
    """Breadth-first levels from a source. value = [level] (-1 unreached
    encoded as INF)."""

    value_dims = 1
    msg_dims = 1
    agg_dims = 1
    combine_op = "min"
    suggested_plan = PhysicalPlan(join="left_outer", groupby="scatter",
                                  sender_combine=True)

    def __init__(self, source: int):
        self.source = source

    def init_value(self, vid, out_degree, gs):
        return jnp.where(vid == self.source, 0.0, INF)[..., None]

    def compute(self, vid, value, msg, has_msg, active, gs):
        cur = value[..., 0]
        incoming = jnp.where(has_msg, msg[..., 0], INF)
        new = jnp.minimum(cur, incoming)
        improved = new < cur
        send = improved | ((gs.superstep == 0) & (vid == self.source))
        return ComputeOut(value=new[..., None],
                          halt=jnp.ones_like(send),
                          send_gate=send,
                          aggregate=jnp.where(new < INF, 1.0, 0.0)[..., None])

    def send(self, src_vid, src_value, edge_val, dst_vid, gs):
        return (src_value[..., 0] + 1.0)[..., None]


class Reachability(VertexProgram):
    """Boolean reachability from a source set (paper's built-in library)."""

    value_dims = 1
    msg_dims = 1
    agg_dims = 1
    combine_op = "max"
    suggested_plan = PhysicalPlan(join="left_outer", groupby="scatter",
                                  sender_combine=True)

    def __init__(self, source: int):
        self.source = source

    def init_value(self, vid, out_degree, gs):
        return (vid == self.source).astype(jnp.float32)[..., None]

    def compute(self, vid, value, msg, has_msg, active, gs):
        reached = value[..., 0] > 0
        incoming = has_msg & (msg[..., 0] > 0)
        new = reached | incoming
        newly = new & ~reached
        send = newly | ((gs.superstep == 0) & (vid == self.source))
        return ComputeOut(value=new.astype(jnp.float32)[..., None],
                          halt=jnp.ones_like(send),
                          send_gate=send,
                          aggregate=new.astype(jnp.float32)[..., None])

    def send(self, src_vid, src_value, edge_val, dst_vid, gs):
        return jnp.ones_like(src_value[..., 0:1])


class KCore(VertexProgram):
    """k-core decomposition (peeling): a vertex dies when its count of
    LIVE neighbors drops below k; death notifications are summed by the
    combiner. value = [live_degree, alive]. Exercises a different message
    pattern than the min/sum library algorithms: monotone decrement with
    self-triggered cascades."""

    value_dims = 2
    msg_dims = 1
    agg_dims = 1
    combine_op = "sum"
    suggested_plan = PhysicalPlan(join="full_outer", groupby="scatter",
                                  sender_combine=True)

    def __init__(self, k: int):
        self.k = k

    def init_value(self, vid, out_degree, gs):
        return jnp.stack([out_degree,
                          jnp.ones(vid.shape, jnp.float32)], axis=-1)

    def compute(self, vid, value, msg, has_msg, active, gs):
        deg = value[..., 0] - jnp.where(has_msg, msg[..., 0], 0.0)
        alive = value[..., 1] > 0
        dies = alive & (deg < self.k)
        new_alive = alive & ~dies
        return ComputeOut(
            value=jnp.stack([deg, new_alive.astype(jnp.float32)], axis=-1),
            halt=jnp.ones_like(dies),        # messages re-activate
            send_gate=dies,                  # notify neighbors of death
            aggregate=new_alive.astype(jnp.float32)[..., None])

    def send(self, src_vid, src_value, edge_val, dst_vid, gs):
        return jnp.ones_like(src_value[..., 0:1])


class PathMerge(VertexProgram):
    """Genomix-style chain compaction (paper Section 6, genome assembly):
    vertices on a simple path (out-degree 1) merge into their successor by
    deleting themselves and forwarding their accumulated length. Exercises
    graph MUTATIONS (delete + resolve) and suits the LSM/delta storage.
    value = [acc_len, out_degree]."""

    value_dims = 2
    msg_dims = 1
    agg_dims = 1
    combine_op = "sum"
    mutates = True
    suggested_plan = PhysicalPlan(join="full_outer", groupby="sort",
                                  storage="delta")

    def __init__(self, rounds: int = 8):
        self.rounds = rounds

    def init_value(self, vid, out_degree, gs):
        return jnp.stack([jnp.ones(vid.shape, jnp.float32), out_degree],
                         axis=-1)

    def compute(self, vid, value, msg, has_msg, active, gs):
        acc = value[..., 0] + jnp.where(has_msg, msg[..., 0], 0.0)
        deg = value[..., 1]
        # odd/even pairing avoids merging both ends of an edge at once
        mergeable = (deg == 1) & (vid % 2 == gs.superstep % 2) & (vid >= 0)
        done = gs.superstep >= self.rounds
        return ComputeOut(
            value=jnp.stack([acc, deg], axis=-1),
            halt=jnp.broadcast_to(done, vid.shape),
            send_gate=mergeable & ~done,
            aggregate=acc[..., None],
            delete_self=mergeable & ~done)

    def send(self, src_vid, src_value, edge_val, dst_vid, gs):
        return src_value[..., 0:1]
