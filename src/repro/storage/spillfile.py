"""mmap-backed on-disk page layouts (the spill tier under the pager).

Each page spills to its own ``.npy`` file written through
``numpy.lib.format.open_memmap`` — the array bytes land contiguously
after the npy header, so a page write-back or fault-in is one sequential
I/O pass (GraphD's discipline: out-of-core graph state must stream, not
seek; arXiv 1601.05590). The Vertex relation slices and the
run-structured host inbox (the ``(P_dst, P_src, C)`` run buffers of
``core/ooc.py``) both serialize contiguously, which is what makes inbox
spill and reload sequential.

Writes are ATOMIC: data goes to a temp file in the same directory and is
``os.replace``d over the page file. That makes hard links safe in both
directions — a checkpoint can ``os.link`` a page file instead of copying
it (``export_to``) and a resume can ``os.link`` checkpoint pages into a
new spill directory (``adopt``): a later write-back replaces the
directory entry rather than scribbling on the shared inode, so the
checkpoint stays immutable for free.
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from pathlib import Path

import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")


def _key_filename(key) -> str:
    parts = key if isinstance(key, tuple) else (key,)
    return _SAFE.sub("-", "_".join(str(p) for p in parts)) + ".npy"


class SpillSlot:
    """One page's on-disk home: a single ``.npy`` file."""

    def __init__(self, path):
        self.path = Path(path)

    def exists(self) -> bool:
        return self.path.exists()

    def store(self, arr: np.ndarray):
        """Sequential, atomic write-back of the whole page. The temp
        file is thread-unique so a background I/O-engine drain and a
        foreground flush can never collide on it."""
        tmp = self.path.with_name(
            f".{self.path.name}.{threading.get_ident()}.tmp")
        mm = np.lib.format.open_memmap(tmp, mode="w+", dtype=arr.dtype,
                                       shape=arr.shape)
        mm[...] = arr
        mm.flush()
        del mm
        os.replace(tmp, self.path)

    def load(self) -> np.ndarray:
        """Fault the page back in (one sequential read of the mmap)."""
        mm = np.load(self.path, mmap_mode="r")
        out = np.array(mm)
        del mm
        return out

    def delete(self):
        try:
            self.path.unlink()
        except FileNotFoundError:
            pass

    def export_to(self, dst, *, allow_link: bool = True):
        """Publish this page file at ``dst`` without a DRAM round-trip:
        hard-link when the filesystem allows it, else a kernel-side file
        copy. Atomic write-backs make the link safe (see module doc)."""
        dst = Path(dst)
        if allow_link:
            try:
                os.link(self.path, dst)
                return
            except OSError:
                pass
        shutil.copyfile(self.path, dst)

    def adopt(self, src, *, allow_link: bool = True):
        """Populate this slot from an existing page file (resume path)."""
        src = Path(src)
        self.delete()
        if allow_link:
            try:
                os.link(src, self.path)
                return
            except OSError:
                pass
        shutil.copyfile(src, self.path)


class SpillDir:
    """A directory of page files, one slot per page key."""

    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def slot_for(self, key) -> SpillSlot:
        return SpillSlot(self.root / _key_filename(key))

    def bytes_on_disk(self) -> int:
        """Bytes currently occupying the SSD tier (every page file in
        the directory). A directory walk, so only sampled at superstep
        boundaries (``repro.obs.memwatch``); temp files mid-``replace``
        are skipped."""
        total = 0
        try:
            with os.scandir(self.root) as it:
                for e in it:
                    if e.name.endswith(".npy") and e.is_file():
                        try:
                            total += e.stat().st_size
                        except OSError:
                            pass
        except OSError:
            pass
        return total
