"""mmap-backed on-disk page layouts (the spill tier under the pager).

Each page spills to its own ``.npy`` file written through
``numpy.lib.format.open_memmap`` — the array bytes land contiguously
after the npy header, so a page write-back or fault-in is one sequential
I/O pass (GraphD's discipline: out-of-core graph state must stream, not
seek; arXiv 1601.05590). The Vertex relation slices and the
run-structured host inbox (the ``(P_dst, P_src, C)`` run buffers of
``core/ooc.py``) both serialize contiguously, which is what makes inbox
spill and reload sequential.

Writes are ATOMIC: data goes to a temp file in the same directory and is
``os.replace``d over the page file. That makes hard links safe in both
directions — a checkpoint can ``os.link`` a page file instead of copying
it (``export_to``) and a resume can ``os.link`` checkpoint pages into a
new spill directory (``adopt``): a later write-back replaces the
directory entry rather than scribbling on the shared inode, so the
checkpoint stays immutable for free.

Writes are also CHECKSUMMED: a 12-byte trailer (magic + checksum algo +
CRC32C of the array bytes) is appended after the npy payload — ``np.load``
ignores trailing bytes, so the file stays a valid ``.npy``. ``load``
recomputes the CRC on every fault-in and raises the typed
``PageCorruption`` on mismatch; the recovery supervisor treats that as
recoverable (restore from the last valid checkpoint), and checkpoint
verification walks the same trailers to reject corrupt snapshots.
Hard-linked checkpoint exports carry the trailer for free.

Both ``store`` and ``load`` are chaos-harness sites (``spill.write`` /
``spill.read`` / ``page.corrupt`` — see ``repro.runtime.faults``).
"""
from __future__ import annotations

import os
import re
import shutil
import struct
import threading
import zlib
from pathlib import Path

import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")

# -- page checksums ------------------------------------------------------
# CRC32C (Castagnoli) via the accelerated module when the environment has
# one; otherwise zlib's C-speed CRC32 (IEEE). The trailer records which
# algorithm signed the page, so verification always uses the right one —
# a pure-Python CRC32C over multi-MiB pages would tax every fault-in.
try:                                    # pragma: no cover - env dependent
    from crc32c import crc32c as _crc32c_fn
except ImportError:
    try:                                # pragma: no cover - env dependent
        from google_crc32c import value as _crc32c_fn
    except ImportError:
        _crc32c_fn = None

_ALGO_CRC32C = 1
_ALGO_CRC32 = 2
_TRAILER = struct.Struct("<4sBB2xI")    # magic, version, algo, pad, crc
_MAGIC = b"PGXC"
TRAILER_BYTES = _TRAILER.size


class PageCorruption(RuntimeError):
    """A page file failed its CRC on fault-in. Typed so the failure
    manager can classify it as recoverable infrastructure damage (the
    fix is a checkpoint restore, not a retry — re-reading corrupt bytes
    returns the same corrupt bytes)."""

    def __init__(self, path, detail: str = "checksum mismatch"):
        super().__init__(f"corrupt page {path}: {detail}")
        self.path = str(path)


def page_checksum(buf) -> tuple:
    """(algo, crc) of a page payload under the preferred algorithm."""
    if _crc32c_fn is not None:
        return _ALGO_CRC32C, _crc32c_fn(bytes(buf)) & 0xFFFFFFFF
    return _ALGO_CRC32, zlib.crc32(buf) & 0xFFFFFFFF


def _checksum_with(algo: int, buf):
    if algo == _ALGO_CRC32C and _crc32c_fn is not None:
        return _crc32c_fn(bytes(buf)) & 0xFFFFFFFF
    if algo == _ALGO_CRC32:
        return zlib.crc32(buf) & 0xFFFFFFFF
    return None                          # unverifiable in this env


def read_trailer(path) -> tuple:
    """(algo, crc) from a page file's trailer, or (None, None) when the
    file predates checksumming (legacy pages stay loadable)."""
    try:
        with open(path, "rb") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            if size < TRAILER_BYTES:
                return None, None
            f.seek(size - TRAILER_BYTES)
            raw = f.read(TRAILER_BYTES)
    except OSError:
        return None, None
    magic, _ver, algo, crc = _TRAILER.unpack(raw)
    if magic != _MAGIC:
        return None, None
    return algo, crc


def verify_page_file(path) -> bool:
    """Recompute a page file's CRC against its trailer (checkpoint
    verification). True when it matches or the file has no trailer /
    the algo is unavailable here; False on mismatch or unreadable npy."""
    algo, want = read_trailer(path)
    if algo is None:
        return True
    try:
        mm = np.load(path, mmap_mode="r")
    except (OSError, ValueError):
        return False
    try:
        got = _checksum_with(algo, _payload_view(mm))
    finally:
        del mm
    return got is None or got == want


def _payload_view(mm: np.ndarray):
    """The page's data bytes as a flat buffer (what the CRC covers)."""
    return memoryview(np.ascontiguousarray(mm)).cast("B")


def _faults():
    from repro.runtime import faults
    return faults


def _key_filename(key) -> str:
    parts = key if isinstance(key, tuple) else (key,)
    return _SAFE.sub("-", "_".join(str(p) for p in parts)) + ".npy"


class SpillSlot:
    """One page's on-disk home: a single ``.npy`` file (+ CRC trailer)."""

    def __init__(self, path):
        self.path = Path(path)

    def exists(self) -> bool:
        return self.path.exists()

    def store(self, arr: np.ndarray):
        """Sequential, atomic, checksummed write-back of the whole page.
        The temp file is thread-unique so a background I/O-engine drain
        and a foreground flush can never collide on it."""
        faults = _faults()
        faults.hit("spill.write", str(self.path.name))
        tmp = self.path.with_name(
            f".{self.path.name}.{threading.get_ident()}.tmp")
        mm = np.lib.format.open_memmap(tmp, mode="w+", dtype=arr.dtype,
                                       shape=arr.shape)
        mm[...] = arr
        mm.flush()
        algo, crc = page_checksum(_payload_view(mm))
        del mm
        with open(tmp, "ab") as f:
            f.write(_TRAILER.pack(_MAGIC, 1, algo, crc))
        if faults.corrupt("page.corrupt", str(self.path.name)):
            # Damage a payload byte AFTER the trailer was signed — the
            # next fault-in's CRC check must catch it.
            with open(tmp, "r+b") as f:
                f.seek(0, os.SEEK_END)
                if f.tell() > TRAILER_BYTES + 1:
                    f.seek(-(TRAILER_BYTES + 1), os.SEEK_END)
                    b = f.read(1)
                    f.seek(-1, os.SEEK_CUR)
                    f.write(bytes([b[0] ^ 0xFF]))
        os.replace(tmp, self.path)

    def load(self) -> np.ndarray:
        """Fault the page back in (one sequential read of the mmap) and
        verify its CRC trailer; raises PageCorruption on mismatch."""
        _faults().hit("spill.read", str(self.path.name))
        algo, want = read_trailer(self.path)
        try:
            mm = np.load(self.path, mmap_mode="r")
        except ValueError as e:
            # damage reached the npy header itself
            raise PageCorruption(self.path, f"unreadable npy ({e})")
        out = np.array(mm)
        del mm
        if algo is not None:
            got = _checksum_with(algo, _payload_view(out))
            if got is not None and got != want:
                raise PageCorruption(self.path)
        return out

    def delete(self):
        try:
            self.path.unlink()
        except FileNotFoundError:
            pass

    def export_to(self, dst, *, allow_link: bool = True):
        """Publish this page file at ``dst`` without a DRAM round-trip:
        hard-link when the filesystem allows it, else a kernel-side file
        copy. Atomic write-backs make the link safe (see module doc)."""
        dst = Path(dst)
        if allow_link:
            try:
                os.link(self.path, dst)
                return
            except OSError:
                pass
        shutil.copyfile(self.path, dst)

    def adopt(self, src, *, allow_link: bool = True):
        """Populate this slot from an existing page file (resume path)."""
        src = Path(src)
        self.delete()
        if allow_link:
            try:
                os.link(src, self.path)
                return
            except OSError:
                pass
        shutil.copyfile(src, self.path)


class SpillDir:
    """A directory of page files, one slot per page key."""

    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def slot_for(self, key) -> SpillSlot:
        return SpillSlot(self.root / _key_filename(key))

    def bytes_on_disk(self) -> int:
        """Bytes currently occupying the SSD tier (every page file in
        the directory). A directory walk, so only sampled at superstep
        boundaries (``repro.obs.memwatch``); temp files mid-``replace``
        are skipped."""
        total = 0
        try:
            with os.scandir(self.root) as it:
                for e in it:
                    if e.name.endswith(".npy") and e.is_file():
                        try:
                            total += e.stat().st_size
                        except OSError:
                            pass
        except OSError:
            pass
        return total
