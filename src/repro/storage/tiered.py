"""TieredStore — the HBM ↔ DRAM ↔ disk facade the OOC driver runs on.

``core/ooc.py``'s dispatcher/collector used to read and write raw NumPy
host arrays; this facade puts the buffer cache (``storage.pager``)
between them and the spill tier (``storage.spillfile``), extending the
memory hierarchy by one level:

    prefetch:  disk ──(page fault)──▶ DRAM ──(jax.device_put)──▶ HBM
    commit:    HBM ──(np.asarray)──▶ DRAM ──(lazy write-back)──▶ disk

Relations are chunked one page per (relation, super-partition) — exactly
the granularity the streaming executor touches — so the pipeline's
existing overlap discipline hides the disk leg the same way it hides the
host link. Dynamic pages (run-structured inbox generations, collected
out-blocks, mutation blocks) share the same pool and budget via the raw
``put_page``/``get_page`` API.

When ``io_threads > 0`` (and a disk dir is configured) the store owns a
background page-I/O engine (``storage.io_engine``): ``readahead(keys)``
schedules the next dispatchable destination's page faults off the
critical path, and every readahead tick also drains cold dirty pages
(write coalescing, eviction-order targeting) so foreground evictions
find clean victims. ``flush`` drains the engine before the synchronous
write-back pass, and ``close`` shuts it down with the dirty queue
drained — see the engine's module docstring for the locking/pin rules.

With ``disk_dir=None`` and no budget the store degenerates to the pure
DRAM tier (every page stays resident; zero I/O) — the disk tier is a
strictly additive layer, which is what makes the disk-vs-DRAM parity
suite bit-for-bit (``tests/test_storage.py``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.storage.pager import BufferPool
from repro.storage.spillfile import SpillDir, SpillSlot


class TieredStore:
    """Named, super-partition-chunked relations over a ``BufferPool``."""

    def __init__(self, *, n_sp: int, budget_bytes: Optional[int] = None,
                 disk_dir: Optional[str] = None, policy: str = "lru",
                 io_threads: int = 0, readahead_pages: int = 8,
                 metrics=None):
        self.n_sp = int(n_sp)
        self.spill = SpillDir(disk_dir) if disk_dir else None
        self.pool = BufferPool(budget_bytes, policy=policy,
                               spill=self.spill)
        self.engine = None
        if io_threads > 0 and self.spill is not None:
            from repro.storage.io_engine import IOEngine
            self.engine = IOEngine(self.pool, threads=io_threads,
                                   readahead_pages=readahead_pages,
                                   metrics=metrics)
            self.pool.attach_engine(self.engine)
        self._relations: dict = {}   # name -> per-chunk row counts

    @property
    def spilling(self) -> bool:
        return self.spill is not None

    # ---- relations (chunked on the leading partition axis) -----------
    def register(self, name: str, arr: np.ndarray):
        """Split a (P, ...) relation into n_sp pages. The chunks copy out
        of ``arr`` so the source can be freed immediately."""
        arr = np.asarray(arr)
        P = arr.shape[0]
        assert P % self.n_sp == 0, (name, P, self.n_sp)
        sp = P // self.n_sp
        self._relations[name] = sp
        for s in range(self.n_sp):
            self.pool.put((name, s), arr[s * sp:(s + 1) * sp])

    def read(self, name: str, s: int) -> np.ndarray:
        """Chunk ``s`` of a relation (page fault from disk on a miss).
        The array is the cached buffer — treat it as read-only."""
        return self.pool.get((name, s))

    def write(self, name: str, s: int, arr: np.ndarray):
        """Full-chunk replacement (the ``inplace`` write-back policy):
        dirties the page; the disk write happens lazily on eviction."""
        self.pool.put((name, s), arr)

    def write_rows(self, name: str, s: int, mask: np.ndarray,
                   rows: np.ndarray):
        """Scatter-merge changed rows into a chunk (the ``delta`` /
        LSM-deferred-merge policy). A chunk with no changed rows is not
        even dirtied — a converged super-partition costs zero disk
        write-back."""
        if not mask.any():
            return
        page = self.pool.get((name, s))
        page[mask] = rows
        self.pool.mark_dirty((name, s))

    def pin(self, name: str, s: int):
        self.pool.pin((name, s))

    def unpin(self, name: str, s: int):
        self.pool.unpin((name, s))

    def gather(self, name: str) -> np.ndarray:
        """Reassemble a full relation (the final HDFS-write analogue)."""
        return np.concatenate([self.read(name, s)
                               for s in range(self.n_sp)], axis=0)

    # ---- raw page KV (inbox generations, out/mutation blocks) --------
    def put_page(self, key, arr: np.ndarray, *, immutable: bool = False):
        self.pool.put(key, arr, immutable=immutable)

    def get_page(self, key) -> np.ndarray:
        return self.pool.get(key)

    def delete_page(self, key):
        self.pool.delete(key)

    # ---- background I/O ----------------------------------------------
    def readahead(self, keys):
        """Schedule background faults for ``keys`` (the pages the next
        dispatchable destination will touch) and a clean-ahead pass over
        cold dirty pages. No-op without an engine — the DRAM tier has no
        disk leg to hide."""
        if self.engine is None:
            return 0
        self.engine.clean_ahead()
        return self.engine.prefetch(keys)

    # ---- statistics / checkpoint surface -----------------------------
    def stats(self) -> dict:
        d = self.pool.stats()
        if self.engine is not None:
            d.update(self.engine.stats())
        return d

    def take_interval(self) -> dict:
        """Per-superstep counters (pager + I/O engine) since the last
        call — what the OOC statistics stream records, so the planner
        observes current paging behavior, not cumulative."""
        d = self.pool.take_interval()
        if self.engine is not None:
            d.update(self.engine.take_interval())
        return d

    def occupancy(self) -> dict:
        """Instantaneous tier occupancy for the memory-pressure ledger
        (``repro.obs.memwatch``): the pool's DRAM page accounting plus
        the bytes actually occupying the spill directory."""
        d = self.pool.occupancy()
        d["spilling"] = self.spilling
        d["spill_bytes"] = (self.spill.bytes_on_disk()
                            if self.spill is not None else 0)
        return d

    def page_keys(self):
        return self.pool.keys()

    def flush(self):
        if self.engine is not None:
            self.engine.drain()
        self.pool.flush()

    def export_page(self, key, dst_path):
        """Publish one page at ``dst_path`` for a checkpoint. Disk-tier
        pages move at the FILE level (hard-link for immutable pages such
        as inbox generations, kernel copy otherwise) — no DRAM
        re-serialization; DRAM-tier pages serialize through a SpillSlot
        so every exported page carries a CRC trailer either way."""
        page = self.pool.page(key)
        if self.spilling:
            if page.dirty or page.slot is None or not page.slot.exists():
                if page.slot is None:
                    page.slot = self.spill.slot_for(key)
                page.slot.store(self.pool.get(key))
                self.pool.spill_write_bytes += page.nbytes
                page.dirty = False
            page.slot.export_to(dst_path, allow_link=page.immutable)
        else:
            SpillSlot(dst_path).store(self.pool.get(key))

    def adopt_page(self, key, src_path, *, relation: Optional[str] = None,
                   immutable: bool = False):
        """Install a checkpointed page file as page ``key`` (resume
        path). Disk tier: hard-link/copy the file and leave the page
        non-resident (the run faults it in on first touch — resuming
        never streams the whole job through DRAM); DRAM tier: load it."""
        if self.spilling:
            slot = self.spill.slot_for(key)
            slot.adopt(src_path)
            mm = np.load(slot.path, mmap_mode="r")
            nbytes, rows = int(mm.nbytes), mm.shape[0]
            del mm
            self.pool.adopt(key, slot, nbytes, immutable=immutable)
        else:
            arr = SpillSlot(src_path).load()   # verifies the CRC trailer
            rows = arr.shape[0]
            self.pool.put(key, arr)
        if relation is not None:
            self._relations[relation] = rows

    def close(self, *, delete_files: bool = True):
        if self.engine is not None:
            self.engine.close()
        self.pool.close(delete_files=delete_files)
