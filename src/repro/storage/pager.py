"""Page-granular buffer cache (the Hyracks buffer-cache analogue).

Pregelix's graceful out-of-core story rests on every operator reading and
writing relations THROUGH a buffer cache, so the same physical plans run
whether the working set fits in memory or not (paper Sections 2.3/5.4).
This module is that layer for the TPU-adapted hierarchy: a ``BufferPool``
holds fixed-key ``Page`` objects (one page = one super-partition slice of
one relation, or one run-structured inbox chunk) under a configurable
DRAM byte budget, evicting to mmap-backed spill files
(``storage.spillfile``) when the budget is exceeded and faulting pages
back in on access.

Eviction policies (``policy=``):

* ``"lru"``   — classic least-recently-used. Right when the working set
  fits or accesses have temporal locality.
* ``"mru"``   — evict the MOST recently used unpinned page. The OOC
  driver's access pattern is a CYCLIC SEQUENTIAL SCAN (super-partitions
  0..n_sp-1, every superstep): under LRU a cache smaller than the scan
  re-faults every page every cycle (hit rate 0), while MRU pins down a
  stable prefix of the cycle and converges to a hit rate of
  budget/working-set — the classic sequential-flooding fix, tuned to the
  superstep's cyclic pattern (GraphH's hot-data cache makes the same
  observation, arXiv 1705.05595).

Pages are PINNED while a pipeline slot is in flight (the dispatcher pins
a super-partition's pages at upload, the collector unpins at commit);
pinned pages are never eviction victims, so the budget must cover the
pinned working set — the pool raises with the shortfall when it cannot.
Dirty pages write back lazily: only on eviction, ``flush()`` (checkpoint
barrier) or shape-changing replacement, and clean pages are dropped
without any I/O.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.storage.spillfile import SpillDir

EVICTION_POLICIES = ("lru", "mru")


class Page:
    """One cached block: resident numpy data or a spill-file residue."""

    __slots__ = ("key", "data", "nbytes", "dirty", "pins", "immutable",
                 "slot")

    def __init__(self, key, data: Optional[np.ndarray], *,
                 dirty: bool, immutable: bool = False, slot=None):
        self.key = key
        self.data = data
        self.nbytes = int(data.nbytes) if data is not None else 0
        self.dirty = dirty
        self.pins = 0
        self.immutable = immutable
        self.slot = slot

    @property
    def resident(self) -> bool:
        return self.data is not None


def _own(arr: np.ndarray) -> np.ndarray:
    """Contiguous array that OWNS its buffer: a page must not keep a view
    alive into a larger caller array (that would defeat eviction)."""
    a = np.ascontiguousarray(arr)
    if a.base is not None:
        a = a.copy()
    return a


class BufferPool:
    """Budgeted page cache with pluggable eviction and lazy write-back.

    ``budget_bytes=None`` disables eviction (pure-DRAM tier: every page
    stays resident; hit/miss statistics still flow). A byte budget
    requires a ``spill`` directory to evict into.
    """

    def __init__(self, budget_bytes: Optional[int] = None, *,
                 policy: str = "lru", spill: Optional[SpillDir] = None):
        if policy not in EVICTION_POLICIES:
            raise ValueError(f"policy must be one of {EVICTION_POLICIES}, "
                             f"got {policy!r}")
        if budget_bytes is not None and spill is None:
            raise ValueError(
                "a DRAM byte budget needs a spill directory to evict into "
                "(pass disk_dir=...)")
        self.budget = int(budget_bytes) if budget_bytes is not None else None
        self.policy = policy
        self.spill = spill
        self._pages: dict = {}
        self._order: OrderedDict = OrderedDict()   # residency, LRU->MRU
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.resident_bytes = 0
        self.peak_resident_bytes = 0
        self.spill_read_bytes = 0
        self.spill_write_bytes = 0

    # ---- internals ---------------------------------------------------
    def _account(self, delta: int):
        self.resident_bytes += delta
        if self.resident_bytes > self.peak_resident_bytes:
            self.peak_resident_bytes = self.resident_bytes

    def _touch(self, key):
        if key in self._order:
            self._order.move_to_end(key)

    def _victim(self) -> Optional[Page]:
        keys = (self._order if self.policy == "lru"
                else reversed(self._order))
        for k in keys:
            page = self._pages[k]
            if page.pins == 0:
                return page
        return None

    def _evict(self, page: Page):
        if page.dirty:
            self._writeback(page)
        self._order.pop(page.key, None)
        self._account(-page.nbytes)
        page.data = None
        self.evictions += 1

    def _writeback(self, page: Page):
        if page.slot is None:
            page.slot = self.spill.slot_for(page.key)
        page.slot.store(page.data)
        self.spill_write_bytes += page.nbytes
        page.dirty = False

    def _ensure_room(self, nbytes: int):
        if self.budget is None:
            return
        while self.resident_bytes + nbytes > self.budget:
            victim = self._victim()
            if victim is None:
                pinned = sum(p.nbytes for p in self._pages.values()
                             if p.resident and p.pins > 0)
                if nbytes > self.budget:
                    raise RuntimeError(
                        f"buffer-cache budget of {self.budget} bytes is "
                        f"smaller than a single page ({nbytes} bytes — "
                        f"one super-partition slice of one relation); "
                        f"raise memory_budget_bytes at least that far")
                raise RuntimeError(
                    f"buffer-cache budget of {self.budget} bytes cannot "
                    f"hold the pinned working set ({pinned} bytes pinned, "
                    f"{nbytes} more requested); raise "
                    f"memory_budget_bytes or lower prefetch_depth")
            self._evict(victim)

    def _insert_resident(self, page: Page):
        self._ensure_room(page.nbytes)
        self._account(page.nbytes)
        self._order[page.key] = None
        self._order.move_to_end(page.key)

    # ---- public API --------------------------------------------------
    def put(self, key, arr: np.ndarray, *, dirty: bool = True,
            immutable: bool = False):
        """Insert or replace a page. ``dirty=True`` (default) defers the
        spill write until eviction/flush; ``immutable=True`` marks the
        page's spill file safe to hard-link (checkpoints)."""
        arr = _own(np.asarray(arr))
        old = self._pages.get(key)
        pins = 0
        if old is not None:
            if old.resident:
                self._order.pop(key, None)
                self._account(-old.nbytes)
            slot = old.slot
            pins = old.pins    # replacement keeps the caller's pins
        else:
            slot = None
        page = Page(key, arr, dirty=dirty, immutable=immutable, slot=slot)
        page.pins = pins
        if not dirty and slot is None and self.spill is not None:
            # caller asserts the data is already durable; without a file
            # backing it an eviction would lose it, so keep it dirty
            page.dirty = True
        self._pages[key] = page
        self._insert_resident(page)
        return page

    def adopt(self, key, slot, nbytes: int, *, immutable: bool = False):
        """Install a NON-RESIDENT page backed by an existing spill file
        (the resume-from-checkpoint path): no bytes enter DRAM until the
        first ``get`` faults it in."""
        page = Page(key, None, dirty=False, immutable=immutable,
                    slot=slot)
        page.nbytes = int(nbytes)
        self._pages[key] = page
        return page

    def get(self, key) -> np.ndarray:
        """Fetch a page's data, faulting it in from its spill file if it
        was evicted. The returned array is the CACHED buffer — callers
        that mutate it must call ``mark_dirty``."""
        page = self._pages[key]
        if page.resident:
            self.hits += 1
            self._touch(key)
            return page.data
        self.misses += 1
        self._ensure_room(page.nbytes)
        page.data = page.slot.load()
        page.nbytes = int(page.data.nbytes)
        self.spill_read_bytes += page.nbytes
        self._insert_resident(page)
        return page.data

    def __contains__(self, key) -> bool:
        return key in self._pages

    def keys(self):
        return list(self._pages.keys())

    def page(self, key) -> Page:
        return self._pages[key]

    def mark_dirty(self, key):
        self._pages[key].dirty = True

    def pin(self, key):
        """Pin (faulting in if needed): the page cannot be evicted until
        the matching ``unpin``. Pins nest."""
        self.get(key)
        self._pages[key].pins += 1

    def unpin(self, key):
        page = self._pages[key]
        if page.pins <= 0:
            raise RuntimeError(f"unpin of unpinned page {key!r}")
        page.pins -= 1

    def delete(self, key):
        page = self._pages.pop(key, None)
        if page is None:
            return
        if page.resident:
            self._order.pop(key, None)
            self._account(-page.nbytes)
        if page.slot is not None:
            page.slot.delete()

    def flush(self):
        """Write back every dirty page (no evictions). The pool must have
        a spill directory; this is the checkpoint barrier."""
        if self.spill is None:
            return
        for page in self._pages.values():
            if page.resident and page.dirty:
                self._writeback(page)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "hits": self.hits, "misses": self.misses,
            "hit_rate": self.hits / total if total else 1.0,
            "evictions": self.evictions,
            "resident_bytes": self.resident_bytes,
            "peak_resident_bytes": self.peak_resident_bytes,
            "spill_read_bytes": self.spill_read_bytes,
            "spill_write_bytes": self.spill_write_bytes,
        }

    def close(self, *, delete_files: bool = True):
        for key in list(self._pages):
            page = self._pages.pop(key)
            if page.resident:
                self._order.pop(key, None)
                self._account(-page.nbytes)
            if delete_files and page.slot is not None:
                page.slot.delete()
