"""Page-granular buffer cache (the Hyracks buffer-cache analogue).

Pregelix's graceful out-of-core story rests on every operator reading and
writing relations THROUGH a buffer cache, so the same physical plans run
whether the working set fits in memory or not (paper Sections 2.3/5.4).
This module is that layer for the TPU-adapted hierarchy: a ``BufferPool``
holds fixed-key ``Page`` objects (one page = one super-partition slice of
one relation, or one run-structured inbox chunk) under a configurable
DRAM byte budget, evicting to mmap-backed spill files
(``storage.spillfile``) when the budget is exceeded and faulting pages
back in on access.

Eviction policies (``policy=``):

* ``"lru"``   — classic least-recently-used. Right when the working set
  fits or accesses have temporal locality.
* ``"mru"``   — evict the MOST recently used unpinned page. The OOC
  driver's access pattern is a CYCLIC SEQUENTIAL SCAN (super-partitions
  0..n_sp-1, every superstep): under LRU a cache smaller than the scan
  re-faults every page every cycle (hit rate 0), while MRU pins down a
  stable prefix of the cycle and converges to a hit rate of
  budget/working-set — the classic sequential-flooding fix, tuned to the
  superstep's cyclic pattern (GraphH's hot-data cache makes the same
  observation, arXiv 1705.05595).

Pages are PINNED while a pipeline slot is in flight (the dispatcher pins
a super-partition's pages at upload, the collector unpins at commit);
pinned pages are never eviction victims, so the budget must cover the
pinned working set — the pool raises with the shortfall when it cannot.
Dirty pages write back lazily: only on eviction, ``flush()`` (checkpoint
barrier) or shape-changing replacement, and clean pages are dropped
without any I/O.

BACKGROUND I/O (``storage.io_engine``): when an ``IOEngine`` is attached
the pool becomes a shared structure — every public method takes the pool
lock, and the engine moves page bytes through the ``fault_background`` /
``writeback_background`` entry points, which mark the page ``io_busy``
while the disk transfer runs OUTSIDE the lock. ``io_busy`` pages are
never eviction victims (eviction must not block behind an in-flight
transfer), and with an engine attached the evictor PREFERS CLEAN victims
— the engine's ``clean_ahead`` keeps cold dirty pages written back ahead
of time, so foreground evictions degrade to a free page drop instead of
a synchronous disk write. A per-page ``version`` counter (bumped by
``mark_dirty`` and in-place writes) lets a background write-back detect
that it raced a new mutation and leave the page dirty for the next
drain, which is what makes write coalescing safe.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.obs import trace
from repro.storage.io_engine import DEFAULT_RETRY, retry_io
from repro.storage.spillfile import SpillDir

EVICTION_POLICIES = ("lru", "mru")


def _faults():
    from repro.runtime import faults
    return faults


class Page:
    """One cached block: resident numpy data or a spill-file residue."""

    __slots__ = ("key", "data", "nbytes", "dirty", "pins", "immutable",
                 "slot", "version")

    def __init__(self, key, data: Optional[np.ndarray], *,
                 dirty: bool, immutable: bool = False, slot=None):
        self.key = key
        self.data = data
        self.nbytes = int(data.nbytes) if data is not None else 0
        self.dirty = dirty
        self.pins = 0
        self.immutable = immutable
        self.slot = slot
        self.version = 0       # bumped on every mutation of `data`

    @property
    def resident(self) -> bool:
        return self.data is not None


def _own(arr: np.ndarray) -> np.ndarray:
    """Contiguous array that OWNS its buffer: a page must not keep a view
    alive into a larger caller array (that would defeat eviction)."""
    a = np.ascontiguousarray(arr)
    if a.base is not None:
        a = a.copy()
    return a


# the counters ``take_interval`` snapshots per superstep
_INTERVAL_FIELDS = ("hits", "misses", "evictions", "spill_read_bytes",
                    "spill_write_bytes")


class BufferPool:
    """Budgeted page cache with pluggable eviction and lazy write-back.

    ``budget_bytes=None`` disables eviction (pure-DRAM tier: every page
    stays resident; hit/miss statistics still flow). A byte budget
    requires a ``spill`` directory to evict into.
    """

    def __init__(self, budget_bytes: Optional[int] = None, *,
                 policy: str = "lru", spill: Optional[SpillDir] = None):
        if policy not in EVICTION_POLICIES:
            raise ValueError(f"policy must be one of {EVICTION_POLICIES}, "
                             f"got {policy!r}")
        if budget_bytes is not None and spill is None:
            raise ValueError(
                "a DRAM byte budget needs a spill directory to evict into "
                "(pass disk_dir=...)")
        self.budget = int(budget_bytes) if budget_bytes is not None else None
        self.policy = policy
        self.spill = spill
        self.engine = None          # attached storage.io_engine.IOEngine
        # Foreground disk ops ride the same retry ladder as the engine's
        # background ops; an attached IOEngine shares its policy and its
        # health-score callback through these two attributes.
        self.retry_policy = DEFAULT_RETRY
        self.retry_notify = None
        self._mu = threading.RLock()
        self._cv = threading.Condition(self._mu)   # background-fault done
        self._io_busy: set = set()   # keys with in-flight engine I/O
        self._tombstones: set = set()   # deleted while I/O was in flight
        self._pages: dict = {}
        self._order: OrderedDict = OrderedDict()   # residency, LRU->MRU
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.resident_bytes = 0
        self.peak_resident_bytes = 0
        self.spill_read_bytes = 0
        self.spill_write_bytes = 0
        self._interval_base = {f: 0 for f in _INTERVAL_FIELDS}

    # ---- internals (callers hold self._mu) ---------------------------
    def _account(self, delta: int):
        self.resident_bytes += delta
        if self.resident_bytes > self.peak_resident_bytes:
            self.peak_resident_bytes = self.resident_bytes

    def _touch(self, key):
        if key in self._order:
            self._order.move_to_end(key)

    def _candidates(self):
        return (self._order if self.policy == "lru"
                else reversed(self._order))

    def _victim(self) -> Optional[Page]:
        """Next eviction victim: first evictable page in policy order.
        With an IOEngine attached, CLEAN evictable pages are preferred
        (dropping them is free; the engine's clean-ahead exists exactly
        to make such victims available) and pages with in-flight engine
        I/O are never victims."""
        fallback = None
        for k in self._candidates():
            page = self._pages[k]
            if page.pins > 0 or k in self._io_busy:
                continue
            if self.engine is None or not page.dirty:
                return page
            if fallback is None:
                fallback = page
        return fallback

    def _evict(self, page: Page):
        if page.dirty:
            self._writeback(page)
        self._order.pop(page.key, None)
        self._account(-page.nbytes)
        page.data = None
        self.evictions += 1

    def _writeback(self, page: Page):
        with trace.span("page_writeback", "writeback"):
            if page.slot is None:
                page.slot = self.spill.slot_for(page.key)
            retry_io(lambda: page.slot.store(page.data),
                     self.retry_policy, on_retry=self.retry_notify)
        self.spill_write_bytes += page.nbytes
        page.dirty = False

    def _ensure_room(self, nbytes: int):
        if self.budget is None:
            return
        while self.resident_bytes + nbytes > self.budget:
            victim = self._victim()
            if victim is None:
                if self._io_busy:
                    # every otherwise-evictable page is mid-transfer on
                    # the I/O engine (or its readahead reservation holds
                    # the bytes): wait for a completion and retry
                    # instead of failing the caller — eviction skips
                    # io-busy pages, it never blocks ON one, but the
                    # budget itself must wait for the bytes to settle
                    self._cv.wait(timeout=1.0)
                    continue
                pinned = sum(p.nbytes for p in self._pages.values()
                             if p.resident and p.pins > 0)
                if nbytes > self.budget:
                    raise RuntimeError(
                        f"buffer-cache budget of {self.budget} bytes is "
                        f"smaller than a single page ({nbytes} bytes — "
                        f"one super-partition slice of one relation); "
                        f"raise memory_budget_bytes at least that far")
                raise RuntimeError(
                    f"buffer-cache budget of {self.budget} bytes cannot "
                    f"hold the pinned working set ({pinned} bytes pinned, "
                    f"{nbytes} more requested); raise "
                    f"memory_budget_bytes or lower prefetch_depth")
            self._evict(victim)

    def _insert_resident(self, page: Page):
        self._ensure_room(page.nbytes)
        self._account(page.nbytes)
        self._order[page.key] = None
        self._order.move_to_end(page.key)

    # ---- public API --------------------------------------------------
    def put(self, key, arr: np.ndarray, *, dirty: bool = True,
            immutable: bool = False):
        """Insert or replace a page. ``dirty=True`` (default) defers the
        spill write until eviction/flush; ``immutable=True`` marks the
        page's spill file safe to hard-link (checkpoints)."""
        arr = _own(np.asarray(arr))
        with self._mu:
            old = self._pages.get(key)
            pins = 0
            if old is not None:
                if old.resident:
                    self._order.pop(key, None)
                    self._account(-old.nbytes)
                slot = old.slot
                pins = old.pins    # replacement keeps the caller's pins
            else:
                slot = None
            page = Page(key, arr, dirty=dirty, immutable=immutable,
                        slot=slot)
            page.pins = pins
            if not dirty and slot is None and self.spill is not None:
                # caller asserts the data is already durable; without a
                # file backing it an eviction would lose it, so keep it
                # dirty
                page.dirty = True
            self._pages[key] = page
            self._insert_resident(page)
            return page

    def adopt(self, key, slot, nbytes: int, *, immutable: bool = False):
        """Install a NON-RESIDENT page backed by an existing spill file
        (the resume-from-checkpoint path): no bytes enter DRAM until the
        first ``get`` faults it in."""
        with self._mu:
            page = Page(key, None, dirty=False, immutable=immutable,
                        slot=slot)
            page.nbytes = int(nbytes)
            self._pages[key] = page
            return page

    def get(self, key) -> np.ndarray:
        """Fetch a page's data, faulting it in from its spill file if it
        was evicted. The returned array is the CACHED buffer — callers
        that mutate it must call ``mark_dirty``."""
        with self._mu:
            page = self._pages[key]
            if not page.resident and key in self._io_busy:
                # a background fault for this page is already in flight:
                # wait for its bytes instead of duplicating the disk
                # read on the critical path (on timeout or engine
                # failure we fall through to the synchronous fault,
                # which surfaces the real error)
                self._cv.wait_for(
                    lambda: self._pages.get(key) is not page
                    or page.resident or key not in self._io_busy,
                    timeout=30.0)
                page = self._pages[key]
            if page.resident:
                self.hits += 1
                self._touch(key)
                return page.data
            self.misses += 1
            slot = page.slot
            # perform the disk read OUTSIDE the lock (marked io_busy so
            # the engine and the evictor leave the page alone): a
            # foreground fault must not serialize every background
            # worker behind its transfer
            self._io_busy.add(key)
        try:
            with trace.span("page_fault", "fault"):
                _faults().hit("pager.fault", str(key))
                data = retry_io(slot.load, self.retry_policy,
                                on_retry=self.retry_notify)
        except BaseException:
            with self._mu:
                self._io_done(key)
            raise
        with self._mu:
            self._io_done(key)
            if self._pages.get(key) is not page:
                # deleted/replaced while we read: hand the caller the
                # bytes but do not resurrect the page in the pool
                return data
            if page.resident:      # engine landed it while we read
                self._touch(key)
                return page.data
            self._ensure_room(int(data.nbytes))
            page.data = data
            page.nbytes = int(data.nbytes)
            self.spill_read_bytes += page.nbytes
            self._insert_resident(page)
            return page.data

    def __contains__(self, key) -> bool:
        with self._mu:
            return key in self._pages

    def keys(self):
        with self._mu:
            return list(self._pages.keys())

    def page(self, key) -> Page:
        with self._mu:
            return self._pages[key]

    def mark_dirty(self, key):
        with self._mu:
            page = self._pages[key]
            page.dirty = True
            page.version += 1

    def pin(self, key):
        """Pin (faulting in if needed): the page cannot be evicted until
        the matching ``unpin``. Pins nest. The fault runs outside the
        lock (see ``get``), so the pin re-checks residency — an eviction
        sneaking between the fault and the pin just re-faults."""
        while True:
            self.get(key)
            with self._mu:
                page = self._pages[key]
                if page.resident:
                    page.pins += 1
                    return

    def unpin(self, key):
        with self._mu:
            page = self._pages[key]
            if page.pins <= 0:
                raise RuntimeError(f"unpin of unpinned page {key!r}")
            page.pins -= 1

    def delete(self, key):
        with self._mu:
            page = self._pages.pop(key, None)
            if page is None:
                return
            if page.resident:
                self._order.pop(key, None)
                self._account(-page.nbytes)
            if page.slot is not None:
                page.slot.delete()
                if key in self._io_busy:
                    # an engine write in flight may atomically recreate
                    # the file; the I/O completion sweeps it back up
                    self._tombstones.add(key)

    def flush(self):
        """Write back every dirty page (no evictions). The pool must have
        a spill directory; this is the checkpoint barrier. With an
        IOEngine attached the caller drains it first (``TieredStore.flush``
        does), so no page is mid-transfer here."""
        if self.spill is None:
            return
        with self._mu:
            for page in self._pages.values():
                if page.resident and page.dirty \
                        and page.key not in self._io_busy:
                    self._writeback(page)

    # ---- IOEngine entry points ---------------------------------------
    def attach_engine(self, engine):
        self.engine = engine

    def _io_done(self, key):
        """Clear a key's in-flight marker and wake every waiter (both
        foreground faults waiting on this page and _ensure_room waiting
        for evictable room); if the page was deleted while the transfer
        ran, remove the file the write may have recreated (callers hold
        self._mu)."""
        self._io_busy.discard(key)
        self._cv.notify_all()
        if key in self._tombstones:
            self._tombstones.discard(key)
            if self.spill is not None:
                self.spill.slot_for(key).delete()

    def wants_prefetch(self, key) -> bool:
        """True when a background fault for ``key`` would do useful work
        (page exists, is evicted, has a spill file, no I/O in flight)."""
        with self._mu:
            page = self._pages.get(key)
            return (page is not None and not page.resident
                    and key not in self._io_busy
                    and page.slot is not None)

    def dirty_eviction_candidates(self, limit: int):
        """Keys of up to ``limit`` dirty, unpinned, idle resident pages
        in EVICTION ORDER — the engine's clean-ahead targets; only
        meaningful under a byte budget."""
        out = []
        with self._mu:
            if self.budget is None or self.spill is None:
                return out
            if self.resident_bytes < self.budget - self.budget // 8:
                # no eviction pressure: a drain now would only risk
                # rewriting pages that get re-dirtied before they are
                # ever evicted
                return out
            for k in self._candidates():
                page = self._pages[k]
                if (page.dirty and page.pins == 0 and page.resident
                        and k not in self._io_busy):
                    out.append(k)
                    if len(out) >= limit:
                        break
        return out

    def fault_background(self, key) -> Optional[int]:
        """Engine-side page fault: RESERVE room under the lock by
        evicting CLEAN victims only (a readahead must never perform or
        wait on a dirty write-back — if no free room exists it is simply
        dropped, before paying the read), load the spill file OUTSIDE
        the lock, and install the bytes if the page is still evicted.
        Returns the bytes installed, or None when the readahead was
        dropped or the foreground won the race."""
        with self._mu:
            page = self._pages.get(key)
            if (page is None or page.resident or key in self._io_busy
                    or page.slot is None):
                return None
            hold = int(page.nbytes)
            if self.budget is not None:
                while self.resident_bytes + hold > self.budget:
                    victim = next(
                        (self._pages[k] for k in self._candidates()
                         if self._pages[k].pins == 0
                         and k not in self._io_busy
                         and not self._pages[k].dirty), None)
                    if victim is None:
                        return None   # no free room: drop the readahead
                    self._evict(victim)   # clean victim: a free drop
                self._account(hold)       # reservation
            self._io_busy.add(key)
            slot = page.slot
        try:
            data = slot.load()
        except BaseException:
            with self._mu:
                if self.budget is not None:
                    self._account(-hold)
                self._io_done(key)
                self._cv.notify_all()
            raise
        with self._mu:
            installed = None
            if self._pages.get(key) is page and not page.resident:
                if self.budget is not None:
                    self._account(int(data.nbytes) - hold)
                else:
                    self._account(int(data.nbytes))
                page.data = data
                page.nbytes = int(data.nbytes)
                # an engine-served fault is still a PAGE FAULT: the
                # bytes came off disk, just off the critical path —
                # count it as a miss so cache_hit_rate (and the cost
                # model's disk-read term it feeds) reflects measured
                # disk traffic, not merely who performed the read
                self.misses += 1
                self.spill_read_bytes += page.nbytes
                self._order[key] = None
                self._order.move_to_end(key)
                installed = page.nbytes
            elif self.budget is not None:
                self._account(-hold)
            self._io_done(key)
            self._cv.notify_all()
            return installed

    def writeback_background(self, key) -> Optional[int]:
        """Engine-side dirty drain: snapshot the page under the lock,
        write its spill file outside it, and mark the page clean only if
        nobody re-dirtied it meanwhile (version check) — the coalescing
        contract. Returns bytes written, or None if there was nothing to
        do."""
        with self._mu:
            page = self._pages.get(key)
            if (page is None or not page.resident or not page.dirty
                    or key in self._io_busy):
                return None
            if page.slot is None:
                if self.spill is None:
                    return None
                page.slot = self.spill.slot_for(page.key)
            self._io_busy.add(key)
            data, slot, version = page.data, page.slot, page.version
        try:
            slot.store(data)
        except BaseException:
            with self._mu:
                self._io_done(key)
            raise
        with self._mu:
            self._io_done(key)
            cur = self._pages.get(key)
            if cur is page and page.version == version:
                page.dirty = False
            self.spill_write_bytes += data.nbytes
            return int(data.nbytes)

    # ---- statistics --------------------------------------------------
    def stats(self) -> dict:
        with self._mu:
            total = self.hits + self.misses
            return {
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / total if total else 1.0,
                "evictions": self.evictions,
                "resident_bytes": self.resident_bytes,
                "peak_resident_bytes": self.peak_resident_bytes,
                "spill_read_bytes": self.spill_read_bytes,
                "spill_write_bytes": self.spill_write_bytes,
            }

    def occupancy(self) -> dict:
        """Live page accounting for the memory-pressure ledger
        (``repro.obs.memwatch``): resident / dirty / pinned bytes at
        this instant, under the pool lock, plus the hard budget and the
        peak watermark. Unlike ``stats()`` these are walked from the
        page table, so dirty and pinned bytes — the part of the tier an
        eviction cannot reclaim — are exact."""
        with self._mu:
            resident = dirty = pinned = 0
            for p in self._pages.values():
                if not p.resident:
                    continue
                resident += p.nbytes
                if p.dirty:
                    dirty += p.nbytes
                if p.pins > 0:
                    pinned += p.nbytes
            return {
                "resident_bytes": resident,
                "dirty_bytes": dirty,
                "pinned_bytes": pinned,
                "budget_bytes": self.budget,
                "peak_resident_bytes": self.peak_resident_bytes,
                "spill_read_bytes": self.spill_read_bytes,
                "spill_write_bytes": self.spill_write_bytes,
            }

    def take_interval(self) -> dict:
        """Counters SINCE THE LAST CALL (one superstep's worth for the
        OOC driver), so the planner observes current — not cumulative —
        paging behavior. Cumulative totals stay available via
        ``stats()``."""
        with self._mu:
            out = {}
            for f in _INTERVAL_FIELDS:
                cur = getattr(self, f)
                out[f] = cur - self._interval_base[f]
                self._interval_base[f] = cur
            return out

    def close(self, *, delete_files: bool = True):
        with self._mu:
            for key in list(self._pages):
                page = self._pages.pop(key)
                if page.resident:
                    self._order.pop(key, None)
                    self._account(-page.nbytes)
                if delete_files and page.slot is not None:
                    page.slot.delete()
