"""Buffer-cache storage subsystem: the HBM ↔ host DRAM ↔ disk tier.

The paper's out-of-core execution spills every operator through the
Hyracks buffer cache (Sections 2.3/5.4); this package is that layer for
the TPU-adapted runtime:

* ``pager``     — page-granular buffer cache: DRAM byte budget, LRU and
                  cyclic-scan-resistant (MRU) eviction, pin/unpin for
                  in-flight pipeline slots, lazy dirty-page write-back
* ``spillfile`` — mmap-backed ``.npy`` page files with atomic writes
                  (sequential I/O; hard-link-safe for checkpoints)
* ``io_engine`` — background page-I/O worker threads: readahead of the
                  next dispatchable destination's pages, coalesced
                  dirty-page drain in eviction order, pin-aware
                  scheduling (eviction never blocks on in-flight I/O)
* ``tiered``    — ``TieredStore``, the facade ``core/ooc.py``'s
                  dispatcher/collector runs on instead of raw host arrays

Entry points: ``run_out_of_core(..., memory_budget_bytes=...,
disk_dir=..., eviction=..., io_threads=..., readahead_pages=...)`` and
the CLI flags ``--disk-dir`` / ``--memory-budget-bytes`` /
``--eviction`` / ``--io-threads`` / ``--readahead-pages``.
"""
from repro.storage.io_engine import (DEFAULT_RETRY, IOEngine, RetryPolicy,
                                     retry_io)
from repro.storage.pager import EVICTION_POLICIES, BufferPool, Page
from repro.storage.spillfile import (PageCorruption, SpillDir, SpillSlot,
                                     verify_page_file)
from repro.storage.tiered import TieredStore

__all__ = ["EVICTION_POLICIES", "BufferPool", "IOEngine", "Page",
           "PageCorruption", "RetryPolicy", "DEFAULT_RETRY", "retry_io",
           "SpillDir", "SpillSlot", "TieredStore", "verify_page_file"]
