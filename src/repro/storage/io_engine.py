"""Background page-I/O engine: disk reads/writes off the critical path.

The disk tier (PR 4) made page faults and dirty write-backs *lazy*, but
they still ran synchronously on whichever thread touched the pool — the
OOC dispatcher paid a disk read for every faulted page and the collector
paid a disk write for every eviction under budget pressure. This module
owns that I/O on worker threads instead (GraphD/GraphH discipline: an
out-of-core engine must overlap its disk leg with everything else):

* **Readahead** — the executor announces the pages the next dispatchable
  destination will touch (``prefetch``); non-resident ones fault in from
  their spill files in the background, so the foreground ``get`` that
  follows is a DRAM hit. A readahead that loses the race to a foreground
  fault simply drops its bytes; a readahead that *fails* is recorded and
  retried synchronously by the foreground fault, which surfaces the real
  error to the caller.
* **Dirty-page drain** — under budget pressure the engine writes back
  cold dirty pages ahead of eviction (``clean_ahead`` targets pages in
  eviction order), so the evictor finds CLEAN victims and drops them
  without blocking on disk. Writes are COALESCED: a page queued while a
  write for it is already queued is enqueued once, and a page re-dirtied
  after its write-back simply stays dirty (the pool's per-page version
  counter detects the race) to be drained again later.
* **Pin-aware scheduling** — pages with in-flight engine I/O are marked
  ``io_busy`` and are never eviction victims (``pager._victim`` skips
  them), so eviction never blocks behind the engine; the engine likewise
  never writes a page mid-replacement (versioning) and performs all disk
  I/O *outside* the pool lock.

Worker failures never kill the run silently — and transient ones never
kill it at all:

* **Retry ladder** — every disk op (background AND the pool's foreground
  faults, which share this module's ``retry_io``) retries transient
  ``OSError``s with capped exponential backoff + jitter before
  surfacing. ``PageCorruption`` is never retried: re-reading corrupt
  bytes returns the same corrupt bytes, so it surfaces immediately for
  the recovery supervisor. Retries are visible as ``retry`` trace
  instants and the ``io.retries`` registry counter.
* **Degradation ladder** — repeated faults raise a health score that
  first shrinks readahead to one page (stop speculating against a sick
  disk), then falls back to synchronous foreground I/O entirely; clean
  ops decay the score back toward full pipelining when the disk heals.
  Transitions emit ``degrade`` trace instants and the live level rides
  ``stats()``/``take_interval`` and the ``io.degrade_level`` gauge.

Per-key errors are kept in ``errors`` — BOUNDED (oldest evicted past
``ERRORS_CAP``) so a persistently bad disk can't grow it without limit —
and counted on the ``io.errors`` registry counter. Read failures
re-raise from the foreground fault; write failures leave the page dirty
for the synchronous ``flush`` fallback to surface. ``close`` drains the
queue — dirty pages handed to the engine are on disk before shutdown
returns.
"""
from __future__ import annotations

import queue
import random
import threading
import time
from dataclasses import dataclass
from typing import Optional

from repro.obs import trace
from repro.obs.metrics import Counter, Histogram
from repro.storage.spillfile import PageCorruption

_SENTINEL = object()

ERRORS_CAP = 64          # bounded error log (satellite: no unbounded growth)


@dataclass
class RetryPolicy:
    """Capped exponential backoff + jitter for transient disk faults."""
    attempts: int = 4            # total tries (1 initial + retries)
    base_s: float = 0.002        # first backoff
    cap_s: float = 0.25          # backoff ceiling
    jitter: float = 0.5          # uniform extra fraction of the delay


DEFAULT_RETRY = RetryPolicy()


def retry_io(fn, policy: RetryPolicy = DEFAULT_RETRY, *, on_retry=None):
    """Run a disk op under the retry ladder. Retries ``OSError`` (real
    EIO and injected faults alike); ``PageCorruption`` and application
    errors surface immediately. ``on_retry(attempt, exc)`` fires before
    each backoff sleep."""
    delay = policy.base_s
    for attempt in range(policy.attempts):
        try:
            return fn()
        except PageCorruption:
            raise
        except OSError as exc:
            if attempt + 1 >= policy.attempts:
                raise
            if on_retry is not None:
                on_retry(attempt, exc)
            trace.instant("io_retry", "retry", attempt=attempt,
                          error=type(exc).__name__)
            time.sleep(min(delay * (1.0 + policy.jitter * random.random()),
                           policy.cap_s))
            delay *= 2.0


class IOEngine:
    """Worker thread(s) owning a ``BufferPool``'s spill-tier I/O."""

    def __init__(self, pool, *, threads: int = 1,
                 readahead_pages: int = 8, metrics=None,
                 retry: Optional[RetryPolicy] = None):
        if threads < 1:
            raise ValueError("io engine needs at least one worker thread")
        self.pool = pool
        # `readahead_pages` is the configured CEILING; the live depth
        # adapts within [1, ceiling] from observed fault latency vs
        # compute time (`autopace`).
        self.readahead_max = max(int(readahead_pages), 1)
        self.readahead_pages = self.readahead_max
        # Retry + degradation ladder state. The policy and the counters
        # are SHARED with the pool so foreground faults ride the same
        # ladder and feed the same health score.
        self.retry = retry or RetryPolicy()
        self.retries = 0
        self.error_count = 0
        self._c_retries = (metrics.counter("io.retries")
                           if metrics is not None else Counter())
        self._c_errors = (metrics.counter("io.errors")
                          if metrics is not None else Counter())
        self._g_degrade = (metrics.gauge("io.degrade_level")
                           if metrics is not None else None)
        self._health = 0                 # fault pressure; 0 = healthy
        self.degrade_readahead_at = 4    # health >= this: readahead -> 1
        self.degrade_sync_at = 8         # health >= this: sync fallback
        self.degrade_level = 0           # 0 full / 1 throttled / 2 sync
        pool.retry_policy = self.retry
        pool.retry_notify = self._note_retry
        self._q: queue.Queue = queue.Queue()
        self._mu = threading.Lock()
        self._queued: set = set()        # (op, key) pending — coalescing
        self._idle = threading.Condition(self._mu)
        self._outstanding = 0            # queued + in-flight items
        self.errors: dict = {}           # key -> last exception
        self.reads = 0                   # completed readahead faults
        self.read_bytes = 0
        self.writes = 0                  # completed background drains
        self.write_bytes = 0
        self.dropped = 0                 # readaheads beaten by foreground
        self._depth_peak = 0
        self._depth_sum = 0
        self._depth_n = 0
        # queue-depth distribution per superstep (p50/p90/max travel in
        # SuperstepStats.extra); shared with the run registry when given
        # — then take_interval only SNAPSHOTS it and leaves the reset to
        # the registry's own interval pass (StatsCollector.record runs
        # right after), so the same numbers appear on both streams
        self._own_hist = metrics is None
        self._depth_hist = (metrics.histogram("io.queue_depth")
                            if metrics is not None else Histogram())
        self._int_reads = 0              # interval fault-latency sample
        self._int_read_s = 0.0
        self._closed = False
        self._workers = [
            threading.Thread(target=self._run, name=f"pregelix-io-{k}",
                             daemon=True)
            for k in range(int(threads))]
        for w in self._workers:
            w.start()

    # ---- scheduling --------------------------------------------------
    def _enqueue(self, op: str, key) -> bool:
        with self._mu:
            if self._closed or (op, key) in self._queued:
                return False
            self._queued.add((op, key))
            self._outstanding += 1
            depth = self._outstanding
            self._depth_peak = max(self._depth_peak, depth)
            self._depth_sum += depth
            self._depth_n += 1
        self._depth_hist.observe(depth)
        self._q.put((op, key))
        return True

    def effective_readahead(self) -> int:
        """Live depth after the degradation ladder: level 1 stops
        speculating (one page), level 2 is the sync-I/O fallback (no
        background reads at all — the foreground fault path, with its
        own retry ladder, does the work)."""
        if self.degrade_level >= 2:
            return 0
        if self.degrade_level == 1:
            return 1
        return self.readahead_pages

    def prefetch(self, keys) -> int:
        """Schedule background faults for up to ``readahead_pages`` of
        ``keys`` that are present-but-not-resident (fewer while the
        degradation ladder is engaged). Returns the number scheduled."""
        n = 0
        depth = self.effective_readahead()
        for key in keys:
            if n >= depth:
                break
            if self.pool.wants_prefetch(key) and self._enqueue("read", key):
                n += 1
        return n

    def clean_ahead(self, limit: int = 4) -> int:
        """Schedule write-backs for up to ``limit`` dirty unpinned pages
        in EVICTION ORDER (the pages the evictor would reach next), so a
        future eviction finds clean victims it can drop without I/O."""
        n = 0
        for key in self.pool.dirty_eviction_candidates(limit):
            if self._enqueue("write", key):
                n += 1
        return n

    # ---- retry / degradation ladder ----------------------------------
    def _note_retry(self, attempt: int, exc: Exception):
        """Shared with the pool's foreground faults (``retry_notify``)."""
        self._c_retries.inc()
        with self._mu:
            self.retries += 1
        self._bump_health(+1)

    def _bump_health(self, delta: int):
        with self._mu:
            self._health = max(0, self._health + delta)
            level = (2 if self._health >= self.degrade_sync_at else
                     1 if self._health >= self.degrade_readahead_at else 0)
            prev, self.degrade_level = self.degrade_level, level
        if level != prev:
            trace.instant("io_degrade" if level > prev else "io_heal",
                          "degrade", level=level, health=self._health)
        if self._g_degrade is not None:
            self._g_degrade.set(level)

    def _record_error(self, key, e: Exception):
        self._c_errors.inc()
        with self._mu:
            self.error_count += 1
            self.errors[key] = e
            while len(self.errors) > ERRORS_CAP:
                self.errors.pop(next(iter(self.errors)))

    # ---- worker ------------------------------------------------------
    def _run(self):
        from repro.runtime import faults
        while True:
            item = self._q.get()
            if item is _SENTINEL:
                self._q.task_done()
                return
            op, key = item
            try:
                if op == "read":
                    t0 = time.time()
                    with trace.span("fault_bg", "readahead"):
                        nbytes = retry_io(
                            lambda: (faults.hit("io.bg", f"read:{key}"),
                                     self.pool.fault_background(key))[1],
                            self.retry, on_retry=self._note_retry)
                    dt = time.time() - t0
                    with self._mu:
                        if nbytes is None:
                            self.dropped += 1
                        else:
                            self.reads += 1
                            self.read_bytes += nbytes
                            self._int_reads += 1
                            self._int_read_s += dt
                            self.errors.pop(key, None)
                    self._bump_health(-1)
                else:
                    with trace.span("writeback_bg", "writeback"):
                        nbytes = retry_io(
                            lambda: (faults.hit("io.bg", f"write:{key}"),
                                     self.pool.writeback_background(key))[1],
                            self.retry, on_retry=self._note_retry)
                    if nbytes is not None:
                        with self._mu:
                            self.writes += 1
                            self.write_bytes += nbytes
                            self.errors.pop(key, None)
                    self._bump_health(-1)
            except Exception as e:  # noqa: BLE001 — surfaced via errors
                self._record_error(key, e)
                self._bump_health(+2)
            finally:
                with self._mu:
                    self._queued.discard((op, key))
                    self._outstanding -= 1
                    if self._outstanding == 0:
                        self._idle.notify_all()
                self._q.task_done()

    # ---- lifecycle / statistics --------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every queued request has completed."""
        with self._idle:
            return self._idle.wait_for(lambda: self._outstanding == 0,
                                       timeout=timeout)

    def close(self):
        """Drain outstanding I/O and stop the workers. Dirty pages whose
        write-backs were queued are on disk when this returns."""
        if self._closed:
            return
        self.drain()
        with self._mu:
            self._closed = True
        for _ in self._workers:
            self._q.put(_SENTINEL)
        for w in self._workers:
            w.join(timeout=30.0)

    def stats(self) -> dict:
        with self._mu:
            mean = (self._depth_sum / self._depth_n) if self._depth_n else 0.0
            return {
                "io_reads": self.reads, "io_read_bytes": self.read_bytes,
                "io_writes": self.writes,
                "io_write_bytes": self.write_bytes,
                "io_dropped_readaheads": self.dropped,
                "io_queue_depth_peak": self._depth_peak,
                "io_queue_depth_mean": mean,
                "io_errors": self.error_count,
                "io_retries": self.retries,
                "io_degrade_level": self.degrade_level,
            }

    def autopace(self, compute_s: float) -> int:
        """Close the I/O pacing loop (ROADMAP "Measurement-driven
        planning"): set the live readahead depth to the number of page
        faults the measured per-fault latency says fit inside one
        superstep's compute window, clamped to [1, readahead_max].
        Prefetching deeper than that outruns the window the pipeline can
        hide and only pressures the eviction clock; shallower leaves
        hideable faults on the foreground path. Consumes and resets the
        interval fault-latency sample; with no faults observed this
        superstep the depth is left unchanged."""
        with self._mu:
            reads, read_s = self._int_reads, self._int_read_s
            self._int_reads, self._int_read_s = 0, 0.0
        if reads == 0 or read_s <= 0.0 or compute_s <= 0.0:
            return self.readahead_pages
        lat = read_s / reads
        k = int(compute_s / lat)
        self.readahead_pages = max(1, min(self.readahead_max, k))
        return self.readahead_pages

    def take_interval(self) -> dict:
        """Per-superstep view: returns current depth statistics —
        including the p50/p90/max of the queue-depth distribution — and
        resets the interval accumulators (the satellite counterpart of
        ``BufferPool.take_interval``)."""
        hist = (self._depth_hist.interval() if self._own_hist
                else self._depth_hist.snapshot())
        with self._mu:
            out = {
                "io_queue_depth_peak": self._depth_peak,
                "io_queue_depth_mean": (self._depth_sum / self._depth_n
                                        if self._depth_n else 0.0),
                "io_queue_depth_p50": hist["p50"],
                "io_queue_depth_p90": hist["p90"],
                "io_queue_depth_max": hist["max"],
                "readahead_depth": self.readahead_pages,
                "io_degrade_level": self.degrade_level,
            }
            self._depth_peak = self._outstanding
            self._depth_sum = 0
            self._depth_n = 0
            return out
