"""Background page-I/O engine: disk reads/writes off the critical path.

The disk tier (PR 4) made page faults and dirty write-backs *lazy*, but
they still ran synchronously on whichever thread touched the pool — the
OOC dispatcher paid a disk read for every faulted page and the collector
paid a disk write for every eviction under budget pressure. This module
owns that I/O on worker threads instead (GraphD/GraphH discipline: an
out-of-core engine must overlap its disk leg with everything else):

* **Readahead** — the executor announces the pages the next dispatchable
  destination will touch (``prefetch``); non-resident ones fault in from
  their spill files in the background, so the foreground ``get`` that
  follows is a DRAM hit. A readahead that loses the race to a foreground
  fault simply drops its bytes; a readahead that *fails* is recorded and
  retried synchronously by the foreground fault, which surfaces the real
  error to the caller.
* **Dirty-page drain** — under budget pressure the engine writes back
  cold dirty pages ahead of eviction (``clean_ahead`` targets pages in
  eviction order), so the evictor finds CLEAN victims and drops them
  without blocking on disk. Writes are COALESCED: a page queued while a
  write for it is already queued is enqueued once, and a page re-dirtied
  after its write-back simply stays dirty (the pool's per-page version
  counter detects the race) to be drained again later.
* **Pin-aware scheduling** — pages with in-flight engine I/O are marked
  ``io_busy`` and are never eviction victims (``pager._victim`` skips
  them), so eviction never blocks behind the engine; the engine likewise
  never writes a page mid-replacement (versioning) and performs all disk
  I/O *outside* the pool lock.

Worker failures never kill the run silently: per-key errors are kept in
``errors`` (read failures re-raise from the foreground fault; write
failures leave the page dirty for the synchronous ``flush`` fallback to
surface). ``close`` drains the queue — dirty pages handed to the engine
are on disk before shutdown returns.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Optional

from repro.obs import trace
from repro.obs.metrics import Histogram

_SENTINEL = object()


class IOEngine:
    """Worker thread(s) owning a ``BufferPool``'s spill-tier I/O."""

    def __init__(self, pool, *, threads: int = 1,
                 readahead_pages: int = 8, metrics=None):
        if threads < 1:
            raise ValueError("io engine needs at least one worker thread")
        self.pool = pool
        # `readahead_pages` is the configured CEILING; the live depth
        # adapts within [1, ceiling] from observed fault latency vs
        # compute time (`autopace`).
        self.readahead_max = max(int(readahead_pages), 1)
        self.readahead_pages = self.readahead_max
        self._q: queue.Queue = queue.Queue()
        self._mu = threading.Lock()
        self._queued: set = set()        # (op, key) pending — coalescing
        self._idle = threading.Condition(self._mu)
        self._outstanding = 0            # queued + in-flight items
        self.errors: dict = {}           # key -> last exception
        self.reads = 0                   # completed readahead faults
        self.read_bytes = 0
        self.writes = 0                  # completed background drains
        self.write_bytes = 0
        self.dropped = 0                 # readaheads beaten by foreground
        self._depth_peak = 0
        self._depth_sum = 0
        self._depth_n = 0
        # queue-depth distribution per superstep (p50/p90/max travel in
        # SuperstepStats.extra); shared with the run registry when given
        # — then take_interval only SNAPSHOTS it and leaves the reset to
        # the registry's own interval pass (StatsCollector.record runs
        # right after), so the same numbers appear on both streams
        self._own_hist = metrics is None
        self._depth_hist = (metrics.histogram("io.queue_depth")
                            if metrics is not None else Histogram())
        self._int_reads = 0              # interval fault-latency sample
        self._int_read_s = 0.0
        self._closed = False
        self._workers = [
            threading.Thread(target=self._run, name=f"pregelix-io-{k}",
                             daemon=True)
            for k in range(int(threads))]
        for w in self._workers:
            w.start()

    # ---- scheduling --------------------------------------------------
    def _enqueue(self, op: str, key) -> bool:
        with self._mu:
            if self._closed or (op, key) in self._queued:
                return False
            self._queued.add((op, key))
            self._outstanding += 1
            depth = self._outstanding
            self._depth_peak = max(self._depth_peak, depth)
            self._depth_sum += depth
            self._depth_n += 1
        self._depth_hist.observe(depth)
        self._q.put((op, key))
        return True

    def prefetch(self, keys) -> int:
        """Schedule background faults for up to ``readahead_pages`` of
        ``keys`` that are present-but-not-resident. Returns the number
        scheduled."""
        n = 0
        for key in keys:
            if n >= self.readahead_pages:
                break
            if self.pool.wants_prefetch(key) and self._enqueue("read", key):
                n += 1
        return n

    def clean_ahead(self, limit: int = 4) -> int:
        """Schedule write-backs for up to ``limit`` dirty unpinned pages
        in EVICTION ORDER (the pages the evictor would reach next), so a
        future eviction finds clean victims it can drop without I/O."""
        n = 0
        for key in self.pool.dirty_eviction_candidates(limit):
            if self._enqueue("write", key):
                n += 1
        return n

    # ---- worker ------------------------------------------------------
    def _run(self):
        while True:
            item = self._q.get()
            if item is _SENTINEL:
                self._q.task_done()
                return
            op, key = item
            try:
                if op == "read":
                    t0 = time.time()
                    with trace.span("fault_bg", "readahead"):
                        nbytes = self.pool.fault_background(key)
                    dt = time.time() - t0
                    with self._mu:
                        if nbytes is None:
                            self.dropped += 1
                        else:
                            self.reads += 1
                            self.read_bytes += nbytes
                            self._int_reads += 1
                            self._int_read_s += dt
                            self.errors.pop(key, None)
                else:
                    with trace.span("writeback_bg", "writeback"):
                        nbytes = self.pool.writeback_background(key)
                    if nbytes is not None:
                        with self._mu:
                            self.writes += 1
                            self.write_bytes += nbytes
                            self.errors.pop(key, None)
            except Exception as e:  # noqa: BLE001 — surfaced via errors
                with self._mu:
                    self.errors[key] = e
            finally:
                with self._mu:
                    self._queued.discard((op, key))
                    self._outstanding -= 1
                    if self._outstanding == 0:
                        self._idle.notify_all()
                self._q.task_done()

    # ---- lifecycle / statistics --------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every queued request has completed."""
        with self._idle:
            return self._idle.wait_for(lambda: self._outstanding == 0,
                                       timeout=timeout)

    def close(self):
        """Drain outstanding I/O and stop the workers. Dirty pages whose
        write-backs were queued are on disk when this returns."""
        if self._closed:
            return
        self.drain()
        with self._mu:
            self._closed = True
        for _ in self._workers:
            self._q.put(_SENTINEL)
        for w in self._workers:
            w.join(timeout=30.0)

    def stats(self) -> dict:
        with self._mu:
            mean = (self._depth_sum / self._depth_n) if self._depth_n else 0.0
            return {
                "io_reads": self.reads, "io_read_bytes": self.read_bytes,
                "io_writes": self.writes,
                "io_write_bytes": self.write_bytes,
                "io_dropped_readaheads": self.dropped,
                "io_queue_depth_peak": self._depth_peak,
                "io_queue_depth_mean": mean,
                "io_errors": len(self.errors),
            }

    def autopace(self, compute_s: float) -> int:
        """Close the I/O pacing loop (ROADMAP "Measurement-driven
        planning"): set the live readahead depth to the number of page
        faults the measured per-fault latency says fit inside one
        superstep's compute window, clamped to [1, readahead_max].
        Prefetching deeper than that outruns the window the pipeline can
        hide and only pressures the eviction clock; shallower leaves
        hideable faults on the foreground path. Consumes and resets the
        interval fault-latency sample; with no faults observed this
        superstep the depth is left unchanged."""
        with self._mu:
            reads, read_s = self._int_reads, self._int_read_s
            self._int_reads, self._int_read_s = 0, 0.0
        if reads == 0 or read_s <= 0.0 or compute_s <= 0.0:
            return self.readahead_pages
        lat = read_s / reads
        k = int(compute_s / lat)
        self.readahead_pages = max(1, min(self.readahead_max, k))
        return self.readahead_pages

    def take_interval(self) -> dict:
        """Per-superstep view: returns current depth statistics —
        including the p50/p90/max of the queue-depth distribution — and
        resets the interval accumulators (the satellite counterpart of
        ``BufferPool.take_interval``)."""
        hist = (self._depth_hist.interval() if self._own_hist
                else self._depth_hist.snapshot())
        with self._mu:
            out = {
                "io_queue_depth_peak": self._depth_peak,
                "io_queue_depth_mean": (self._depth_sum / self._depth_n
                                        if self._depth_n else 0.0),
                "io_queue_depth_p50": hist["p50"],
                "io_queue_depth_p90": hist["p90"],
                "io_queue_depth_max": hist["max"],
                "readahead_depth": self.readahead_pages,
            }
            self._depth_peak = self._outstanding
            self._depth_sum = 0
            self._depth_n = 0
            return out
