"""Schema-validated run reports: the audit trail as a CI artifact.

``build_report`` assembles one ``pregelix-run-report/v1`` document from
the three observability streams of a single run — the per-superstep
stats records (``RunResult.stats``), the plan-audit ledger
(:mod:`repro.obs.explain`) and the tier-occupancy ledger
(:mod:`repro.obs.memwatch`) — joined by superstep number. No leg is
re-timed: the report is a pure join over what the run already measured.

Document shape::

    {"schema": "pregelix-run-report/v1",
     "meta": {...free-form run identity...},
     "supersteps": [{"superstep": 0, "wall_s": ..., "active": ...,
                     "audit": {predicted/legs/drift_score}|absent,
                     "memory": {hbm/dram/ssd}|absent,
                     "extra": {...stats extras...}}, ...],
     "decisions": [{"superstep", "kind": replan|recalibrate, ...}],
     "faults": {recovery/stragglers/io/injected}|absent,
     "memory_peaks": {...memwatch watermarks...},
     "summary": {"supersteps", "wall_s", "mean_drift", "max_drift",
                 "replans", "recalibrations"}}

``validate_report`` collects EVERY violation (CI logs show all problems
in one run); ``compare`` diffs two reports and flags drift / occupancy
regressions with deliberately lenient default thresholds — two runs of
the same workload must compare clean despite scheduler noise.

CLI::

    python -m repro.obs.report --validate A.json [B.json ...]
    python -m repro.obs.report --compare BASE.json OTHER.json [--strict]
"""
from __future__ import annotations

import json
import math
from typing import List, Optional

SCHEMA = "pregelix-run-report/v1"

DECISION_KINDS = ("replan", "recalibrate")

# stats-extra keys promoted to top-level superstep-row fields
_ROW_FIELDS = ("active", "messages", "wall_s", "recompiled",
               "frontier_density", "bytes_exchanged")


# ---- assembly --------------------------------------------------------

def build_report(*, stats: Optional[list] = None, explain=None,
                 memwatch=None, meta: Optional[dict] = None,
                 recovery: Optional[list] = None) -> dict:
    """Join the run's observability streams into one document.

    ``stats`` is ``RunResult.stats`` (dict records; event records feed
    the decision log context but not the rows), ``explain`` an
    ``ExplainLedger`` (or its ``as_dict()``), ``memwatch`` a ``MemWatch``
    (or its ``as_dict()``), ``recovery`` a ``RunResult.recovery`` list
    (the failure manager's supervisor events). A ``faults`` section is
    emitted whenever the run saw recovery events, straggler flags, I/O
    retries/errors, or an active fault injector."""
    exd = explain.as_dict() if hasattr(explain, "as_dict") else \
        (explain or {})
    mwd = memwatch.as_dict() if hasattr(memwatch, "as_dict") else \
        (memwatch or {})
    audit_by_ss = {r["superstep"]: r for r in exd.get("supersteps", ())
                   if "superstep" in r}
    mem_by_ss = {s["superstep"]: s for s in mwd.get("samples", ())
                 if "superstep" in s}
    rows = []
    for rec in (stats or ()):
        if rec.get("event") is not None:
            continue
        i = rec["superstep"]
        row = {"superstep": int(i)}
        extra = {}
        for k, v in rec.items():
            if k == "superstep":
                continue
            (row if k in _ROW_FIELDS else extra)[k] = v
        if extra:
            row["extra"] = extra
        if i in audit_by_ss:
            audit = {k: v for k, v in audit_by_ss[i].items()
                     if k != "superstep"}
            row["audit"] = audit
        if i in mem_by_ss:
            row["memory"] = {k: v for k, v in mem_by_ss[i].items()
                            if k != "superstep"}
        rows.append(row)
    drifts = [r["audit"]["drift_score"] for r in rows
              if "audit" in r and "drift_score" in r["audit"]]
    decisions = list(exd.get("decisions", ()))
    summary = {
        "supersteps": len(rows),
        "wall_s": float(sum(r.get("wall_s", 0.0) for r in rows)),
        "mean_drift": (sum(drifts) / len(drifts)) if drifts else None,
        "max_drift": max(drifts) if drifts else None,
        "replans": sum(1 for d in decisions if d.get("kind") == "replan"),
        "recalibrations": sum(1 for d in decisions
                              if d.get("kind") == "recalibrate"),
    }
    report = {"schema": SCHEMA, "meta": dict(meta or {}),
              "supersteps": rows, "decisions": decisions,
              "memory_peaks": dict(mwd.get("peaks", {})),
              "summary": summary}
    faults_sec = _faults_section(rows, recovery)
    if faults_sec:
        report["faults"] = faults_sec
    if "memory_budget_bytes" in mwd:
        report["meta"].setdefault("memory_budget_bytes",
                                  mwd["memory_budget_bytes"])
    return report


def _faults_section(rows, recovery) -> dict:
    """The "Faults & recovery" stream: supervisor recovery events,
    straggler flags, the I/O retry/error/degradation counters summed
    over the rows' per-superstep metrics, and the fault injector's
    summary when a chaos plan is active."""
    sec: dict = {}
    if recovery:
        sec["recovery"] = list(recovery)
    stragglers = [r["extra"]["straggler"] for r in rows
                  if "straggler" in r.get("extra", {})]
    if stragglers:
        sec["stragglers"] = stragglers
    retries = errors = 0
    degrade_peak = 0
    seen_io = False
    for r in rows:
        m = r.get("extra", {}).get("metrics", {})
        e = r.get("extra", {})
        for src in (m, e):
            if any(k in src for k in ("io.retries", "io_retries",
                                      "io_errors", "io.errors")):
                seen_io = True
        retries += int(m.get("io.retries", e.get("io_retries", 0)) or 0)
        errors += int(m.get("io.errors", e.get("io_errors", 0)) or 0)
        degrade_peak = max(degrade_peak,
                           int(m.get("io.degrade_level",
                                     e.get("io_degrade_level", 0)) or 0))
    if seen_io and (retries or errors or degrade_peak):
        sec["io"] = {"retries": retries, "errors": errors,
                     "degrade_level_peak": degrade_peak}
    from repro.runtime import faults as _chaos
    if _chaos.enabled():
        sec["injected"] = _chaos.summary()
    return sec


def to_markdown(report: dict) -> str:
    """Human-readable digest: summary, per-superstep drift table, and
    the decision log."""
    out = [f"# Run report ({report.get('schema', '?')})", ""]
    meta = report.get("meta", {})
    if meta:
        out.append("| meta | value |")
        out.append("|---|---|")
        for k in sorted(meta):
            out.append(f"| {k} | {meta[k]} |")
        out.append("")
    s = report.get("summary", {})
    md = s.get("mean_drift")
    line = (f"**{s.get('supersteps', 0)} supersteps**, "
            f"wall {s.get('wall_s', 0.0):.3f}s, ")
    if md is not None:
        line += f"mean drift {md:.3f}, "
    line += (f"{s.get('replans', 0)} replan(s), "
             f"{s.get('recalibrations', 0)} recalibration(s)")
    out += [line, ""]
    out.append("| superstep | plan | wall s | predicted s | drift "
               "| dram occupancy |")
    out.append("|---|---|---|---|---|---|")
    for r in report.get("supersteps", ()):
        a = r.get("audit", {})
        occ = r.get("memory", {}).get("dram", {}).get("occupancy")
        out.append("| {} | {} | {:.4f} | {} | {} | {} |".format(
            r.get("superstep"), a.get("plan", "-"),
            r.get("wall_s", 0.0),
            f"{a['predicted_total_s']:.4f}"
            if "predicted_total_s" in a else "-",
            f"{a['drift_score']:.3f}" if "drift_score" in a else "-",
            f"{occ:.0%}" if occ is not None else "-"))
    decisions = report.get("decisions", ())
    if decisions:
        out += ["", "## Decisions", ""]
        for d in decisions:
            line = f"- superstep {d.get('superstep')}: {d.get('kind')}"
            if d.get("kind") == "replan":
                line += (f" {d.get('from', '?')} -> {d.get('to', '?')} "
                         f"({len(d.get('candidates', ()))} candidates "
                         "priced)")
            out.append(line)
    peaks = report.get("memory_peaks", {})
    if peaks:
        out += ["", "## Memory peaks", ""]
        for k in sorted(peaks):
            out.append(f"- {k}: {peaks[k]}")
    fl = report.get("faults", {})
    if fl:
        out += ["", "## Faults & recovery", ""]
        for ev in fl.get("recovery", ()):
            out.append(
                "- recovery #{}: restored from {} onto {} worker(s), "
                "blacklist {} — {}".format(
                    ev.get("attempt"),
                    ev.get("restored_from") or "initial relations",
                    ev.get("healthy_workers"),
                    ev.get("blacklist") or "[]",
                    ev.get("error", "?")))
        io = fl.get("io")
        if io:
            out.append(f"- I/O: {io.get('retries', 0)} retried op(s), "
                       f"{io.get('errors', 0)} exhausted failure(s), "
                       f"peak degradation level "
                       f"{io.get('degrade_level_peak', 0)}")
        for s in fl.get("stragglers", ()):
            out.append(f"- straggler: superstep {s.get('superstep')} "
                       f"took {s.get('wall_s', 0.0):.4f}s "
                       f"(median {s.get('median_s', 0.0):.4f}s)")
        inj = fl.get("injected")
        if inj:
            fired = sum(sp.get("fired", 0) for sp in inj.get("specs", ()))
            out.append(f"- fault injector ACTIVE (seed "
                       f"{inj.get('seed')}): {fired} fault(s) fired "
                       f"across {len(inj.get('specs', ()))} spec(s)")
    return "\n".join(out) + "\n"


def write_report(path: str, report: dict, *,
                 markdown: Optional[str] = None) -> dict:
    """Write the JSON document (and optionally a markdown digest)."""
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    if markdown:
        with open(markdown, "w") as f:
            f.write(to_markdown(report))
    return report.get("summary", {})


# ---- validation ------------------------------------------------------

def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool) \
        and math.isfinite(x)


def validate_report(obj) -> List[str]:
    """Schema-check a report document; returns the FULL list of
    violations (empty = valid). Never raises on malformed input."""
    errs: List[str] = []
    if not isinstance(obj, dict):
        return ["top level must be a dict"]
    if obj.get("schema") != SCHEMA:
        errs.append(f"schema must be {SCHEMA!r}, got "
                    f"{obj.get('schema')!r}")
    if not isinstance(obj.get("meta"), dict):
        errs.append("meta must be a dict")
    rows = obj.get("supersteps")
    if not isinstance(rows, list) or not rows:
        errs.append("supersteps must be a non-empty list")
        rows = []
    budget = obj.get("meta", {}).get("memory_budget_bytes") \
        if isinstance(obj.get("meta"), dict) else None
    for n, r in enumerate(rows):
        where = f"supersteps[{n}]"
        if not isinstance(r, dict):
            errs.append(f"{where} is not an object")
            continue
        if not isinstance(r.get("superstep"), int) \
                or r["superstep"] < 0:
            errs.append(f"{where} bad superstep")
        if not _num(r.get("wall_s", 0.0)) or r.get("wall_s", 0.0) < 0:
            errs.append(f"{where} bad wall_s")
        a = r.get("audit")
        if a is not None:
            if not isinstance(a, dict):
                errs.append(f"{where}.audit is not an object")
            elif "error" not in a:
                if not _num(a.get("drift_score")):
                    errs.append(f"{where}.audit drift_score must be a "
                                "finite number")
                legs = a.get("legs")
                if not isinstance(legs, dict):
                    errs.append(f"{where}.audit.legs must be a dict")
                else:
                    for leg, v in legs.items():
                        for k in ("predicted_s", "measured_s", "drift"):
                            if not _num(v.get(k)):
                                errs.append(f"{where}.audit.legs."
                                            f"{leg}.{k} must be a "
                                            "finite number")
                if not isinstance(a.get("predicted"), dict) \
                        or not a.get("predicted"):
                    errs.append(f"{where}.audit.predicted must be a "
                                "non-empty per-term dict")
        m = r.get("memory")
        if m is not None:
            dram = m.get("dram")
            if dram is not None:
                for k in ("resident_bytes", "dirty_bytes",
                          "pinned_bytes"):
                    if not _num(dram.get(k)) or dram.get(k) < 0:
                        errs.append(f"{where}.memory.dram.{k} must be "
                                    "a non-negative number")
                b = dram.get("budget_bytes") or budget
                if b and _num(dram.get("peak_resident_bytes", 0)) \
                        and dram.get("peak_resident_bytes", 0) > b:
                    errs.append(f"{where}.memory.dram peak "
                                f"{dram['peak_resident_bytes']} exceeds "
                                f"budget {b}")
            hbm = m.get("hbm")
            if hbm is not None and not _num(hbm.get("total_bytes")):
                errs.append(f"{where}.memory.hbm.total_bytes must be "
                            "a number")
    decisions = obj.get("decisions")
    if not isinstance(decisions, list):
        errs.append("decisions must be a list")
        decisions = []
    for n, d in enumerate(decisions):
        where = f"decisions[{n}]"
        if not isinstance(d, dict):
            errs.append(f"{where} is not an object")
            continue
        if d.get("kind") not in DECISION_KINDS:
            errs.append(f"{where} unknown kind {d.get('kind')!r}")
        if not isinstance(d.get("superstep"), int):
            errs.append(f"{where} missing superstep")
        if d.get("kind") == "replan":
            cands = d.get("candidates")
            if not isinstance(cands, list) or not cands:
                errs.append(f"{where} replan must carry a non-empty "
                            "candidate price table")
            else:
                for c in cands:
                    if not isinstance(c, dict) or "plan" not in c \
                            or not _num(c.get("seconds")):
                        errs.append(f"{where} bad candidate entry {c!r}")
                        break
    if not isinstance(obj.get("summary"), dict):
        errs.append("summary must be a dict")
    fl = obj.get("faults")
    if fl is not None:
        if not isinstance(fl, dict):
            errs.append("faults must be a dict")
        else:
            for key in ("recovery", "stragglers"):
                if key in fl and not isinstance(fl[key], list):
                    errs.append(f"faults.{key} must be a list")
            if "io" in fl and not isinstance(fl["io"], dict):
                errs.append("faults.io must be a dict")
    return errs


# ---- comparison ------------------------------------------------------

def compare(base: dict, other: dict, *, drift_tol: float = 1.5,
            occupancy_tol: float = 0.2) -> dict:
    """Diff two reports; flag drift / occupancy regressions in ``other``
    relative to ``base``.

    Thresholds are deliberately lenient — drift is a log-ratio, so
    ``drift_tol=1.5`` flags only a ~4.5x worsening of the
    prediction/measurement ratio, and occupancy must rise by 20
    percentage points — two runs of the same workload must compare
    clean despite scheduler and cache noise."""
    regressions = []
    bs, os_ = base.get("summary", {}), other.get("summary", {})
    bd, od = bs.get("mean_drift"), os_.get("mean_drift")
    if bd is not None and od is not None and od - bd > drift_tol:
        regressions.append({
            "kind": "drift", "metric": "mean_drift",
            "base": bd, "other": od,
            "detail": f"mean drift rose {bd:.3f} -> {od:.3f} "
                      f"(tol {drift_tol})"})
    bp = base.get("memory_peaks", {})
    op = other.get("memory_peaks", {})
    bo, oo = bp.get("dram_occupancy"), op.get("dram_occupancy")
    if bo is not None and oo is not None and oo - bo > occupancy_tol:
        regressions.append({
            "kind": "occupancy", "metric": "dram_occupancy",
            "base": bo, "other": oo,
            "detail": f"peak DRAM occupancy rose {bo:.0%} -> {oo:.0%} "
                      f"(tol {occupancy_tol:.0%})"})
    return {
        "ok": not regressions,
        "regressions": regressions,
        "base": {"supersteps": bs.get("supersteps"),
                 "wall_s": bs.get("wall_s"), "mean_drift": bd,
                 "dram_occupancy": bo},
        "other": {"supersteps": os_.get("supersteps"),
                  "wall_s": os_.get("wall_s"), "mean_drift": od,
                  "dram_occupancy": oo},
    }


# ---- CLI -------------------------------------------------------------

def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Validate or compare pregelix run reports.")
    ap.add_argument("--validate", nargs="+", metavar="PATH",
                    help="schema-check report file(s); lists EVERY "
                         "violation and exits nonzero on any")
    ap.add_argument("--compare", nargs=2, metavar=("BASE", "OTHER"),
                    help="diff two reports and print regressions")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero when --compare finds regressions")
    args = ap.parse_args(argv)
    if not args.validate and not args.compare:
        ap.error("one of --validate / --compare is required")
    rc = 0
    for path in (args.validate or ()):
        try:
            errs = validate_report(_load(path))
        except (OSError, ValueError) as e:
            errs = [f"unreadable: {e}"]
        if errs:
            rc = 1
            print(f"INVALID {path}: {len(errs)} violation(s)")
            for e in errs:
                print(f"  - {e}")
        else:
            obj = _load(path)
            s = obj.get("summary", {})
            print(f"OK {path}: {s.get('supersteps')} supersteps, "
                  f"{s.get('replans', 0)} replan(s), mean drift "
                  f"{s.get('mean_drift')}")
    if args.compare:
        base, other = (_load(p) for p in args.compare)
        diff = compare(base, other)
        print(json.dumps(diff, indent=1))
        if args.strict and not diff["ok"]:
            rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
