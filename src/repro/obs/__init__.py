"""Runtime observability: span tracing, metrics, Chrome-trace export.

* ``repro.obs.trace`` — thread-safe span recorder (per-thread buffers,
  nestable spans categorized by pipeline leg, instant/counter events;
  near-zero-cost when disabled).
* ``repro.obs.metrics`` — named counters/gauges/histograms whose
  per-superstep interval snapshot merges into ``SuperstepStats.extra``.
* ``repro.obs.export`` — Chrome trace-event JSON (Perfetto-loadable),
  one track per thread, plus the schema validator CI runs.
* ``repro.obs.progress`` — the human per-superstep progress line.
"""
from repro.obs import trace
from repro.obs.export import (chrome_trace, validate_chrome_trace,
                              write_chrome_trace)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.progress import fmt_plan, progress_line

__all__ = [
    "trace",
    "chrome_trace", "validate_chrome_trace", "write_chrome_trace",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "fmt_plan", "progress_line",
]
