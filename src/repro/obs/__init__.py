"""Runtime observability: span tracing, metrics, Chrome-trace export,
plan audit and tier-occupancy ledgers, and schema-validated run reports.

* ``repro.obs.trace`` — thread-safe span recorder (per-thread buffers,
  nestable spans categorized by pipeline leg, instant/counter events;
  near-zero-cost when disabled).
* ``repro.obs.metrics`` — named counters/gauges/histograms whose
  per-superstep interval snapshot merges into ``SuperstepStats.extra``.
* ``repro.obs.export`` — Chrome trace-event JSON (Perfetto-loadable),
  one track per thread, plus the schema validator CI runs.
* ``repro.obs.progress`` — the human per-superstep progress line.
* ``repro.obs.explain`` — per-superstep predicted-vs-measured ledger
  (the plan audit) plus the controller decision log.
* ``repro.obs.memwatch`` — HBM/DRAM/SSD occupancy samples with peak
  watermarks and the OOM-proximity gauge.
* ``repro.obs.report`` — assembles the above into a schema-validated
  ``BENCH_report.json``-style run report, with ``compare()``.
"""
from repro.obs import explain, memwatch, report, trace
from repro.obs.export import (chrome_trace, validate_chrome_trace,
                              write_chrome_trace)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.progress import fmt_plan, progress_line
from repro.obs.report import build_report, compare, validate_report, \
    write_report

__all__ = [
    "trace", "explain", "memwatch", "report",
    "chrome_trace", "validate_chrome_trace", "write_chrome_trace",
    "build_report", "compare", "validate_report", "write_report",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "fmt_plan", "progress_line",
]
