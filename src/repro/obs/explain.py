"""Plan-audit ledger: per-superstep predicted-vs-measured cost accounting.

The planner prices every candidate plan (``PlanCost.terms`` /
``PlanCost.detail``) and the runtime measures every pipeline leg (span
timers, exchange counters) — but until now nothing joined the two beyond
the two scalar EWMA closures (``Observation.serial_scale`` /
``net_scale``).  This module closes the audit gap: when enabled, drivers
feed each superstep's stats record through :func:`superstep`, which
re-prices the IN-EFFECT plan under the same ``Observation`` the adaptive
controller would build and joins the per-term predicted seconds against
the measured leg times of the same superstep.

The join is leg-granular, not term-granular — measured timers cover
pipeline legs (the device step, the host dispatch+commit, the serial
inbox rebuild, the exchange stage, the spill tier), each of which
aggregates one or more model terms:

=================  =============================================  =============================
leg                model terms                                    measured from
=================  =============================================  =============================
``device``         recv_groupby join_compute send sender_combine  ``collect_wait_s`` (OOC) or
                   connector exchange                             wall minus exchange stall
``host_io``        stream_io storage_writeback mutation_io        ``dispatch_s + commit_s``
``serial``         inbox_rebuild                                  ``readiness_stall_s``
``net``            exchange_net                                   ``exchange_stall_s``
``disk``           disk_io                                        spill bytes / disk bandwidth
=================  =============================================  =============================

Per-leg drift is the absolute log-ratio ``|ln((measured+eps) /
(predicted+eps))|`` — scale-free, symmetric in over/under-prediction,
and always finite; a row's ``drift_score`` is the mean over the legs the
run actually measured.  Terms whose leg has no measurement (e.g. the
disk leg of an in-memory run) stay in the predicted table but are
excluded from the join.

The ledger also keeps a decision log: every ``AdaptiveController``
replan carries the full candidate price table it chose from (the losing
candidates' prices), and every recalibration carries the refit
constants.  Static-plan runs get a SHADOW controller — constructed at
:func:`attach`, it reuses the controller's observation builder and EWMA
closures but never switches plans, so audit rows price exactly what ran.

Mirrors the tracer's module API: ``start()`` / ``stop()`` / ``get()`` /
``enabled()``; every record call is a no-op returning ``None`` while
disabled, so the hot path pays one predicate when audit is off.
"""
from __future__ import annotations

import math
from typing import Optional

_EPS = 1e-6

#: model term -> measured pipeline leg
TERM_LEG = {
    "recv_groupby": "device",
    "join_compute": "device",
    "send": "device",
    "sender_combine": "device",
    "connector": "device",
    "exchange": "device",
    "stream_io": "host_io",
    "storage_writeback": "host_io",
    "mutation_io": "host_io",
    "inbox_rebuild": "serial",
    "exchange_net": "net",
    "disk_io": "disk",
}

LEGS = ("device", "host_io", "serial", "net", "disk")

DECISION_KINDS = ("replan", "recalibrate")


def drift(predicted_s: float, measured_s: float) -> float:
    """Absolute log-ratio drift between a predicted and a measured time:
    0 = perfect, ~0.69 = off by 2x either way. Finite by construction."""
    return abs(math.log((measured_s + _EPS) / (predicted_s + _EPS)))


def measured_legs(rec, machine) -> dict:
    """Measured seconds per pipeline leg, lifted from a stats record.

    Only legs the run actually measured appear; the device leg always
    does (every driver measures wall time)."""
    ex = rec.extra
    legs = {}
    if "collect_wait_s" in ex:
        legs["device"] = float(ex["collect_wait_s"])
    else:
        dev = float(rec.wall_s)
        if "exchange_stall_s" in ex:
            dev = max(dev - float(ex["exchange_stall_s"]), 0.0)
        legs["device"] = dev
    if "dispatch_s" in ex or "commit_s" in ex:
        legs["host_io"] = (float(ex.get("dispatch_s", 0.0)) +
                           float(ex.get("commit_s", 0.0)))
    if "readiness_stall_s" in ex:
        legs["serial"] = float(ex["readiness_stall_s"])
    if "exchange_stall_s" in ex:
        legs["net"] = float(ex["exchange_stall_s"])
    if "spill_read_bytes" in ex or "spill_write_bytes" in ex:
        spill = (float(ex.get("spill_read_bytes", 0.0)) +
                 float(ex.get("spill_write_bytes", 0.0)))
        legs["disk"] = spill / machine.disk_bw
    return legs


class ExplainLedger:
    """Per-run audit state: superstep rows + the decision log.

    ``attach`` binds the run context (program / graph statistics /
    machine model / initial plan); until it is called, ``superstep``
    records nothing — e.g. an OOC resume from a bare spill directory has
    no vertex relation to derive statistics from."""

    def __init__(self):
        self.rows: list = []
        self.decisions: list = []
        self._auditor = None     # shadow AdaptiveController
        self._g = None

    # ---- run context -------------------------------------------------
    def attach(self, program, *, vert=None, g=None, plan=None,
               machine=None, config=None, space_kw=None):
        """Bind the run context. ``g`` wins over ``vert``; with neither
        the ledger stays decision-log-only. Safe to call once per run;
        a second call rebinds (drivers that resolve plans twice)."""
        if plan is None:
            return None
        from repro.planner.adaptive import (AdaptiveConfig,
                                            AdaptiveController)
        from repro.planner.cost import DEFAULT_MACHINE, GraphStats
        if g is None:
            if vert is None:
                return None
            g = GraphStats.from_vertex(vert, program)
        self._g = g
        self._auditor = AdaptiveController(
            program, g, plan, config or AdaptiveConfig(),
            machine=machine or DEFAULT_MACHINE, space_kw=space_kw or {})
        return self

    # ---- per-superstep audit row -------------------------------------
    def superstep(self, rec, *, plan=None, bucket_cap: int = 0):
        """Price the in-effect ``plan`` under this record's observation
        and join predicted terms against the measured legs. Returns the
        appended row, or None when unattached / on an event record.

        The audit layer must never take a run down: any modeling failure
        is recorded as an ``error`` row instead of raised."""
        aud = self._auditor
        if aud is None or getattr(rec, "event", None) is not None:
            return None
        try:
            from repro.obs.progress import fmt_plan
            from repro.planner.cost import estimate
            if plan is not None:
                aud.plan = plan        # shadow tracks the live plan
            plan = aud.plan
            aud._update_stall_ewma(rec)
            aud._update_exchange_ewma(rec)
            obs = aud._make_observation(rec, bucket_cap=bucket_cap)
            cost = estimate(plan, self._g, obs, aud.machine)
            machine = aud.machine
            predicted = {}
            for term, secs in cost.terms.items():
                d = {k: float(v)
                     for k, v in cost.detail.get(term, {}).items() if v}
                d["seconds"] = float(secs)
                d["leg"] = TERM_LEG.get(term, "device")
                predicted[term] = d
            leg_pred = {
                "device": cost.device_seconds(machine),
                "host_io": cost.host_seconds(machine),
                "serial": cost.serial_seconds,
                "net": cost.net_seconds,
                "disk": cost.disk_seconds(machine),
            }
            measured = measured_legs(rec, machine)
            legs, drifts = {}, []
            for leg in LEGS:
                pred = float(leg_pred.get(leg, 0.0))
                if leg not in measured:
                    continue    # leg never measured: excluded from join
                meas = float(measured[leg])
                d = drift(pred, meas)
                legs[leg] = {"predicted_s": pred, "measured_s": meas,
                             "drift": d}
                drifts.append(d)
            row = {
                "superstep": int(rec.superstep),
                "plan": fmt_plan(plan),
                "recompiled": bool(rec.recompiled),
                "predicted": predicted,
                "predicted_total_s": float(cost.seconds(machine)),
                "measured_wall_s": float(rec.wall_s),
                "legs": legs,
                "drift_score": (sum(drifts) / len(drifts)
                                if drifts else 0.0),
            }
        except Exception as e:  # pragma: no cover - defensive
            row = {"superstep": int(getattr(rec, "superstep", -1)),
                   "error": f"{type(e).__name__}: {e}"}
        self.rows.append(row)
        return row

    # ---- decision log ------------------------------------------------
    def decision(self, superstep: int, kind: str, **info):
        """Append a controller decision (``replan`` with its candidate
        price table, or ``recalibrate`` with the refit constants)."""
        d = {"superstep": int(superstep), "kind": str(kind)}
        d.update(info)
        self.decisions.append(d)
        return d

    def as_dict(self) -> dict:
        return {"supersteps": list(self.rows),
                "decisions": list(self.decisions)}


# ---- module-level switch (mirrors repro.obs.trace) -------------------

_LEDGER: Optional[ExplainLedger] = None


def start() -> ExplainLedger:
    """Install a fresh ledger; subsequent driver hooks record into it."""
    global _LEDGER
    _LEDGER = ExplainLedger()
    return _LEDGER


def stop() -> Optional[ExplainLedger]:
    """Uninstall and return the active ledger (None if none)."""
    global _LEDGER
    led, _LEDGER = _LEDGER, None
    return led


def get() -> Optional[ExplainLedger]:
    return _LEDGER


def enabled() -> bool:
    return _LEDGER is not None


def attach(program, **kw):
    """Fire-and-forget context bind — None when auditing is off."""
    led = _LEDGER
    return led.attach(program, **kw) if led is not None else None


def superstep(rec, **kw):
    """Fire-and-forget audit row — None when auditing is off."""
    led = _LEDGER
    return led.superstep(rec, **kw) if led is not None else None


def decision(superstep_, kind, **info):
    """Fire-and-forget decision note — None when auditing is off."""
    led = _LEDGER
    return (led.decision(superstep_, kind, **info)
            if led is not None else None)
