"""Thread-safe span tracing for the barrier-free pipeline.

The paper's statistics collector (Section 5.7) aggregates per-superstep
scalars; after PR 5 the executor is a concurrent system — a rolling
dispatcher/collector loop plus background I/O-engine worker threads —
whose behavior a flat dict cannot explain. This module records *spans*
(nested, timestamped intervals categorized by pipeline leg) plus instant
and counter events, into PER-THREAD buffers so recording never contends
on a lock in the steady state; ``repro.obs.export`` turns the buffers
into Chrome trace-event JSON with one track per thread, which is what
makes the dispatcher / collector / io-engine overlap — and the
readiness-stall gap — visible on a timeline.

Design constraints:

* **Disabled tracing is a near-zero-cost no-op.** Instrumentation stays
  in the hot path permanently, so ``span()`` with no active tracer
  returns one cached singleton context manager and allocates nothing
  (``tests/test_obs.py`` guards this). Callers on hot paths should pass
  no kwargs when possible — kwargs build a dict before the check.
* **Recording is thread-safe and lock-free per event.** Each thread owns
  a buffer (registered once under a lock on first use); appends are
  plain ``list.append``. Export snapshots the buffers concurrently with
  recording (``Tracer.drain``).
* **Device bridging is optional.** ``start(jax_annotations=True)`` makes
  ``annotate`` also enter a ``jax.profiler.TraceAnnotation``, so spans
  line up with device activity when the run is profiled with the JAX
  profiler.

Span categories (one per pipeline leg; ``CATEGORIES``): ``dispatch``,
``prepare``, ``compute``, ``collect``, ``commit``, ``fault``,
``readahead``, ``writeback``, ``checkpoint``, ``replan``, ``exchange``,
``retry`` / ``degrade`` (the I/O engine's fault-retry ladder)
(the sharded driver's all_to_all stage — what the planner's network
axis is calibrated against).
"""
from __future__ import annotations

import threading
import time
from typing import Optional

# pipeline legs; the exporter colors/filters by these
CATEGORIES = ("dispatch", "prepare", "compute", "collect", "commit",
              "fault", "readahead", "writeback", "checkpoint", "replan",
              "exchange", "retry", "degrade")

# event tuples stored in the per-thread buffers:
#   ("X", name, cat, t0, dur, args)   complete span (seconds, wall clock)
#   ("i", name, cat, t, args)         instant event
#   ("C", name, t, value)             counter sample


class _NullSpan:
    """The cached no-op context manager the disabled path returns."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    """A live span: appends one ("X", ...) event to its thread's buffer
    on exit. Created only when a tracer is active."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer, name, cat, args):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._t0 = time.time()
        return self

    def __exit__(self, *exc):
        t1 = time.time()
        self._tracer._buf().append(
            ("X", self._name, self._cat, self._t0, t1 - self._t0,
             self._args))
        return False


class _Annotated:
    """A span combined with a ``jax.profiler.TraceAnnotation`` (device
    bridging): both contexts enter/exit together."""

    __slots__ = ("_span", "_ann")

    def __init__(self, span, ann):
        self._span = span
        self._ann = ann

    def __enter__(self):
        self._span.__enter__()
        self._ann.__enter__()
        return self

    def __exit__(self, *exc):
        self._ann.__exit__(*exc)
        return self._span.__exit__(*exc)


class Tracer:
    """Per-thread span buffers + the clock origin for one recording."""

    def __init__(self, *, jax_annotations: bool = False):
        self._mu = threading.Lock()
        self._bufs: list = []            # [(tid, thread_name, events)]
        self._local = threading.local()
        self.t_origin = time.time()
        self.jax_annotation = None
        if jax_annotations:
            try:
                from jax.profiler import TraceAnnotation
                self.jax_annotation = TraceAnnotation
            except Exception:            # noqa: BLE001 — stays host-only
                self.jax_annotation = None

    def _buf(self) -> list:
        b = getattr(self._local, "buf", None)
        if b is None:
            th = threading.current_thread()
            b = []
            with self._mu:
                self._bufs.append((th.ident or 0, th.name, b))
            self._local.buf = b
        return b

    # ---- recording ---------------------------------------------------
    def span(self, name: str, cat: str, args: Optional[dict] = None):
        return _Span(self, name, cat, args)

    def complete(self, name: str, cat: str, t0: float, t1: float,
                 args: Optional[dict] = None):
        """Record a span with explicit wall-clock endpoints (for
        intervals measured elsewhere, e.g. the readiness stall)."""
        self._buf().append(("X", name, cat, t0, max(t1 - t0, 0.0), args))

    def instant(self, name: str, cat: str, args: Optional[dict] = None):
        self._buf().append(("i", name, cat, time.time(), args))

    def counter(self, name: str, value):
        self._buf().append(("C", name, time.time(), value))

    # ---- export surface ----------------------------------------------
    def drain(self) -> list:
        """Snapshot of (tid, thread_name, events) per thread. Safe while
        other threads keep recording: buffers are copied under the
        registry lock; appends racing the copy land in the next drain."""
        with self._mu:
            return [(tid, nm, list(ev)) for tid, nm, ev in self._bufs]

    def n_events(self) -> int:
        return sum(len(ev) for _, _, ev in self.drain())


# ---- module-level API (what the engine instruments against) ----------
_tracer: Optional[Tracer] = None


def start(*, jax_annotations: bool = False) -> Tracer:
    """Enable tracing globally; returns the (fresh) tracer."""
    global _tracer
    _tracer = Tracer(jax_annotations=jax_annotations)
    return _tracer


def stop() -> Optional[Tracer]:
    """Disable tracing; returns the detached tracer (for export)."""
    global _tracer
    t, _tracer = _tracer, None
    return t


def get() -> Optional[Tracer]:
    return _tracer


def enabled() -> bool:
    return _tracer is not None


def span(name: str, cat: str, **args):
    """Context manager timing one pipeline-leg interval on the calling
    thread. With no active tracer this returns a cached no-op singleton
    — no allocation, so instrumentation can stay on hot paths."""
    t = _tracer
    if t is None:
        return _NULL
    return t.span(name, cat, args or None)


def annotate(name: str, cat: str = "compute", **args):
    """Like ``span`` but also enters ``jax.profiler.TraceAnnotation``
    when the tracer was started with ``jax_annotations=True`` — bridges
    the host-side timeline to device activity under the JAX profiler."""
    t = _tracer
    if t is None:
        return _NULL
    s = t.span(name, cat, args or None)
    if t.jax_annotation is not None:
        return _Annotated(s, t.jax_annotation(name))
    return s


def complete(name: str, cat: str, t0: float, t1: float, **args):
    """Record a span with explicit wall-clock endpoints (no-op when
    disabled)."""
    t = _tracer
    if t is None:
        return
    t.complete(name, cat, t0, t1, args or None)


def instant(name: str, cat: str, **args):
    t = _tracer
    if t is None:
        return
    t.instant(name, cat, args or None)


def counter(name: str, value):
    """Sample a counter track (renders as a stacked area in Perfetto)."""
    t = _tracer
    if t is None:
        return
    t.counter(name, value)
