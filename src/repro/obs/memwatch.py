"""Tier-occupancy ledger: HBM / DRAM / SSD accounting per superstep.

Wall time is a late signal of memory pressure — a tier fills long before
the run slows (the out-of-core literature's consistent finding). This
module samples all three storage tiers at superstep boundaries:

* **HBM** — the device working set is static per plan: relation
  capacities from ``EngineConfig`` (``bucket_cap`` / ``frontier_cap`` /
  ``mutation_cap``) times the vertex/edge/message shapes, times the
  partitions resident at once (the OOC stream keeps
  ``budget_partitions``; in-memory drivers keep all of them).
* **DRAM** — live page accounting from the ``BufferPool``
  (:meth:`repro.storage.pager.BufferPool.occupancy`): resident / dirty /
  pinned bytes under the pool lock, plus the hard ``memory_budget_bytes``
  cap and the peak watermark. Sharded runs sum their per-worker stores.
* **SSD** — bytes actually on disk in the spill directory
  (:meth:`repro.storage.spillfile.SpillDir.bytes_on_disk`) plus the
  cumulative fault/write-back counters.

Each sample carries an OOM-proximity gauge for the budgeted DRAM tier:
``occupancy`` (resident / budget) and ``headroom_bytes`` — occupancy is
the early-warning signal, not wall time. Peaks/watermarks accumulate in
:attr:`MemWatch.peaks` across the run.

Mirrors the tracer's module switch (``start/stop/get/enabled``); all
record calls are no-ops returning ``None`` while disabled.
"""
from __future__ import annotations

from typing import Optional

# wire widths (mirror core shapes: int32 ids, float32 payloads, bool
# validity/halt masks)
_W = 4


def _msg_slot_bytes(msg_dims: int) -> int:
    # dst int32 + payload (D,) float32 + valid bool
    return (1 + msg_dims) * _W + 1


class MemWatch:
    """Per-run occupancy samples + peak watermarks for the three tiers."""

    def __init__(self):
        self.samples: list = []
        self.peaks: dict = {}
        self._hbm_ctx: Optional[dict] = None
        self._budget: Optional[int] = None

    # ---- run context -------------------------------------------------
    def configure(self, *, ec=None, Np: int = 0, Ep: int = 0,
                  value_dims: int = 1, msg_dims: int = 1,
                  budget_bytes: Optional[int] = None,
                  n_workers: int = 1):
        """Bind the shapes the HBM estimate needs (``ec`` is the
        resolved ``EngineConfig``) and the DRAM budget for the OOM
        gauge. Without it, samples carry only what the stores report."""
        if ec is not None:
            self._hbm_ctx = {
                "n_parts": int(ec.n_parts),
                "bucket_cap": int(ec.bucket_cap),
                "frontier_cap": int(ec.frontier_cap),
                "mutation_cap": int(ec.mutation_cap),
                "Np": int(Np), "Ep": int(Ep),
                "value_dims": int(value_dims),
                "msg_dims": int(msg_dims),
                "n_workers": max(int(n_workers), 1),
            }
        if budget_bytes is not None:
            self._budget = int(budget_bytes)
        return self

    def hbm_estimate(self, resident_parts: Optional[int] = None) -> \
            Optional[dict]:
        """Device-tier working set in bytes for ``resident_parts``
        partitions resident at once (None = all of them)."""
        c = self._hbm_ctx
        if c is None:
            return None
        P = c["n_parts"] if resident_parts is None \
            else max(int(resident_parts), 1)
        Np, Ep = c["Np"], c["Ep"]
        D, V = c["msg_dims"], c["value_dims"]
        vertex = P * Np * (2 * _W + 1 + V * _W)   # vid, halt, value
        edge = P * Ep * 3 * _W                    # src, dst, val
        msg = P * c["n_parts"] * c["bucket_cap"] * _msg_slot_bytes(D)
        frontier = P * c["frontier_cap"] * _W
        mutation = (P * c["n_parts"] * c["mutation_cap"]
                    * _msg_slot_bytes(V))
        total = (vertex + edge + msg + frontier
                 + mutation) * c["n_workers"]
        return {"total_bytes": total, "vertex_bytes": vertex,
                "edge_bytes": edge, "message_bytes": msg,
                "frontier_bytes": frontier, "mutation_bytes": mutation,
                "resident_parts": P}

    # ---- per-superstep sample ----------------------------------------
    def sample(self, superstep: int, *, store=None, stores=None,
               resident_parts: Optional[int] = None) -> dict:
        """Snapshot all tiers at a superstep boundary. ``store`` is the
        driver's ``TieredStore`` (or ``stores`` the sharded per-worker
        list); in-memory runs pass neither and get an HBM-only sample."""
        s = {"superstep": int(superstep)}
        hbm = self.hbm_estimate(resident_parts)
        if hbm is not None:
            s["hbm"] = hbm
            self._peak("hbm_bytes", hbm["total_bytes"])
        occs = []
        if store is not None:
            occs.append(store.occupancy())
        for st in (stores or ()):
            occs.append(st.occupancy())
        if occs:
            dram = {"resident_bytes": 0, "dirty_bytes": 0,
                    "pinned_bytes": 0, "peak_resident_bytes": 0,
                    "budget_bytes": None}
            ssd = {"spill_bytes": 0, "spill_read_bytes": 0,
                   "spill_write_bytes": 0}
            for o in occs:
                for k in ("resident_bytes", "dirty_bytes",
                          "pinned_bytes", "peak_resident_bytes"):
                    dram[k] += int(o.get(k, 0))
                if o.get("budget_bytes") is not None:
                    dram["budget_bytes"] = ((dram["budget_bytes"] or 0)
                                            + int(o["budget_bytes"]))
                for k in ssd:
                    ssd[k] += int(o.get(k, 0))
            budget = dram["budget_bytes"]
            if budget is None:
                budget = self._budget
                dram["budget_bytes"] = budget
            if budget:
                # OOM proximity: how full the budgeted tier is, and how
                # many bytes of slack remain before the pager must evict
                dram["occupancy"] = dram["resident_bytes"] / budget
                dram["headroom_bytes"] = budget - dram["resident_bytes"]
            s["dram"] = dram
            s["ssd"] = ssd
            self._peak("dram_resident_bytes", dram["resident_bytes"])
            self._peak("dram_dirty_bytes", dram["dirty_bytes"])
            self._peak("dram_pinned_bytes", dram["pinned_bytes"])
            self._peak("dram_peak_resident_bytes",
                       dram["peak_resident_bytes"])
            if budget:
                self._peak("dram_occupancy", dram["occupancy"])
            self._peak("ssd_spill_bytes", ssd["spill_bytes"])
        self.samples.append(s)
        return s

    def _peak(self, key: str, value):
        if value > self.peaks.get(key, 0):
            self.peaks[key] = value

    def as_dict(self) -> dict:
        d = {"samples": list(self.samples), "peaks": dict(self.peaks)}
        if self._budget is not None:
            d["memory_budget_bytes"] = self._budget
        return d


# ---- module-level switch (mirrors repro.obs.trace) -------------------

_WATCH: Optional[MemWatch] = None


def start() -> MemWatch:
    global _WATCH
    _WATCH = MemWatch()
    return _WATCH


def stop() -> Optional[MemWatch]:
    global _WATCH
    w, _WATCH = _WATCH, None
    return w


def get() -> Optional[MemWatch]:
    return _WATCH


def enabled() -> bool:
    return _WATCH is not None


def configure(**kw):
    """Fire-and-forget context bind — None when memwatch is off."""
    w = _WATCH
    return w.configure(**kw) if w is not None else None


def sample(superstep, **kw):
    """Fire-and-forget tier snapshot — None when memwatch is off."""
    w = _WATCH
    return w.sample(superstep, **kw) if w is not None else None
