"""Chrome trace-event JSON export + validation.

Converts a ``Tracer``'s per-thread span buffers into the trace-event
format that chrome://tracing and https://ui.perfetto.dev load directly:
one track (``tid``) per OS thread, named via ``thread_name`` metadata
events, so the dispatcher/collector main loop and the ``pregelix-io-*``
worker threads render as parallel timelines and the readiness-stall gap
between "inbox ready" and "first dispatch" is visible as a span on the
main track.

Event mapping (all timestamps microseconds relative to the earliest
event):

* span   → ``{"ph": "X", "name", "cat", "pid", "tid", "ts", "dur", "args"}``
* instant→ ``{"ph": "i", "s": "t", ...}``
* counter→ ``{"ph": "C", "args": {"value": v}}`` (a Perfetto area track)

``validate_chrome_trace`` is the schema check CI runs against the trace
artifact the disk-tier smoke benchmark writes:

    python -m repro.obs.export BENCH_trace.json --min-threads 3
"""
from __future__ import annotations

import json
from typing import Optional

from repro.obs import trace as _trace

_PID = 1  # single-process engine: one trace process


def chrome_trace(tracer: Optional[_trace.Tracer] = None) -> dict:
    """Render a tracer's buffers as a trace-event JSON object."""
    tracer = tracer if tracer is not None else _trace.get()
    if tracer is None:
        raise ValueError("no tracer: pass one or call trace.start() first")
    bufs = tracer.drain()
    t0 = tracer.t_origin
    for _, _, events in bufs:
        for ev in events:
            if ev[0] in ("X", "i"):
                t0 = min(t0, ev[3])
            else:
                t0 = min(t0, ev[2])
    out = []
    for tid, name, events in bufs:
        out.append({"ph": "M", "name": "thread_name", "pid": _PID,
                    "tid": tid, "args": {"name": name}})
        for ev in events:
            if ev[0] == "X":
                _, nm, cat, ts, dur, args = ev
                e = {"ph": "X", "name": nm, "cat": cat, "pid": _PID,
                     "tid": tid, "ts": (ts - t0) * 1e6, "dur": dur * 1e6}
                if args:
                    e["args"] = args
                out.append(e)
            elif ev[0] == "i":
                _, nm, cat, ts, args = ev
                e = {"ph": "i", "s": "t", "name": nm, "cat": cat,
                     "pid": _PID, "tid": tid, "ts": (ts - t0) * 1e6}
                if args:
                    e["args"] = args
                out.append(e)
            else:
                _, nm, ts, value = ev
                out.append({"ph": "C", "name": nm, "pid": _PID,
                            "tid": tid, "ts": (ts - t0) * 1e6,
                            "args": {"value": value}})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str,
                       tracer: Optional[_trace.Tracer] = None) -> dict:
    """Write the trace JSON to ``path``; returns the validation summary."""
    obj = chrome_trace(tracer)
    with open(path, "w") as f:
        json.dump(obj, f)
    return validate_chrome_trace(obj)


def trace_violations(obj, *, min_threads: int = 1):
    """Collect EVERY schema violation in a trace-event JSON object.
    Returns ``(violations, summary)`` — an empty list means valid. The
    first entry is always the violation ``validate_chrome_trace`` would
    raise (same scan order, same message)."""
    errs: list = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return (["trace: top level must be a dict with traceEvents"],
                None)
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["trace: traceEvents must be a list"], None
    span_threads: set = set()
    thread_names: dict = {}
    cats: set = set()
    n_spans = 0
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errs.append(f"trace: event {i} is not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "i", "C", "M"):
            errs.append(f"trace: event {i} has unknown phase {ph!r}")
        if "name" not in e or "pid" not in e or "tid" not in e:
            errs.append(f"trace: event {i} missing name/pid/tid")
        if ph == "M":
            if e.get("name") == "thread_name":
                thread_names[e.get("tid")] = \
                    e.get("args", {}).get("name", "")
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"trace: event {i} has bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"trace: event {i} has bad dur {dur!r}")
            if e.get("cat") not in _trace.CATEGORIES:
                errs.append(f"trace: event {i} has unknown category "
                            f"{e.get('cat')!r}")
            n_spans += 1
            span_threads.add(e.get("tid"))
            cats.add(e.get("cat"))
    if len(span_threads) < min_threads:
        errs.append(f"trace: spans on {len(span_threads)} thread(s), "
                    f"need >= {min_threads}")
    summary = {
        "events": len(events),
        "spans": n_spans,
        "span_threads": len(span_threads),
        "thread_names": sorted(thread_names.get(t, str(t))
                               for t in span_threads),
        "categories": sorted(c for c in cats if c is not None),
    }
    return errs, summary


def validate_chrome_trace(obj, *, min_threads: int = 1) -> dict:
    """Schema-check a trace-event JSON object. Raises ``ValueError`` on
    the first violation; returns a summary dict (event count, threads
    with spans, categories seen) on success. ``trace_violations`` is the
    collect-everything variant the CLI uses."""
    errs, summary = trace_violations(obj, min_threads=min_threads)
    if errs:
        raise ValueError(errs[0])
    return summary


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        description="Validate a Chrome trace-event JSON file.")
    p.add_argument("path")
    p.add_argument("--min-threads", type=int, default=1,
                   help="require spans from at least this many threads")
    args = p.parse_args(argv)
    with open(args.path) as f:
        obj = json.load(f)
    violations, summary = trace_violations(obj,
                                           min_threads=args.min_threads)
    if violations:
        # CI logs get the FULL list in one run, not just the first
        print(f"INVALID {args.path}: {len(violations)} violation(s)")
        for v in violations:
            print(f"  - {v}")
        return 1
    print(f"OK {args.path}: {summary['spans']} spans on "
          f"{summary['span_threads']} threads "
          f"{summary['thread_names']}, categories {summary['categories']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
