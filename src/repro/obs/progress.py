"""Human-readable per-superstep progress lines.

The paper's Section 5.7 statistics collector names two consumers: the
runtime (plan selection) and the *user* (job progress). The planner got
its feed in PR 2; this module serves the user one — ``pregel_run
--progress`` prints one line per superstep built from the same
``SuperstepStats`` records, e.g.::

    superstep   7  active 12.4k (19.0%)  msgs 48.2k  wall 0.031s  hit 0.97  stall 2.1ms  plan left_outer/sort/delta

Fields that a given execution mode does not measure (cache hit rate on
the in-memory path, stall on the barrier path) are simply omitted.
"""
from __future__ import annotations

from typing import Optional


def _si(n: float) -> str:
    n = float(n)
    for div, suf in ((1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(n) >= div:
            return f"{n / div:.1f}{suf}"
    return f"{int(n)}" if float(n).is_integer() else f"{n:.1f}"


def fmt_plan(plan) -> str:
    """Compact ``join/groupby/storage`` plan tag for the progress line."""
    if plan is None:
        return ""
    parts = []
    for attr in ("join", "groupby", "connector", "storage"):
        v = getattr(plan, attr, None)
        if v:
            parts.append(str(v))
    return "/".join(parts)


def progress_line(rec: dict, plan=None, *,
                  n_vertices: Optional[int] = None) -> str:
    """One progress line from a ``SuperstepStats`` dict (``rec`` is what
    ``StatsCollector.dicts()`` / the ``on_superstep`` callback yields)."""
    active = rec.get("active", 0)
    out = [f"superstep {rec.get('superstep', 0):>3}",
           f"active {_si(active)}"]
    dens = rec.get("frontier_density")
    if dens is None and n_vertices:
        dens = active / n_vertices
    if dens is not None:
        out[-1] += f" ({100.0 * dens:.1f}%)"
    out.append(f"msgs {_si(rec.get('messages', 0))}")
    out.append(f"wall {rec.get('wall_s', 0.0):.3f}s")
    hit = rec.get("cache_hit_rate")
    if hit is not None:
        out.append(f"hit {hit:.2f}")
    stall = rec.get("readiness_stall_s")
    if stall is not None:
        out.append(f"stall {1e3 * stall:.1f}ms")
    depth = rec.get("readahead_depth")
    if depth is not None:
        out.append(f"ra {int(depth)}")
    xstall = rec.get("exchange_stall_s")
    if xstall is not None:
        out.append(f"xstall {1e3 * xstall:.1f}ms")
    xbytes = rec.get("exchange_bytes")
    if xbytes is not None:
        out.append(f"xbytes {_si(xbytes)}")
    tag = fmt_plan(plan)
    if tag:
        out.append(f"plan {tag}")
    if rec.get("recompiled"):
        out.append("[recompile]")
    if rec.get("event"):
        out.append(f"[{rec['event']}]")
    return "  ".join(out)
