"""Named counters, gauges, and histograms for the runtime.

The second consumer of the paper's Section 5.7 statistics stream is
numeric rather than visual: the adaptive planner and the benchmark
harness want per-superstep scalars, not timelines. A ``MetricsRegistry``
holds the run's instruments; ``StatsCollector`` calls
``registry.interval()`` once per superstep and merges the snapshot into
``SuperstepStats.extra["metrics"]``, so every downstream consumer (plan
controller, progress line, BENCH JSON) sees the same numbers.

Instruments:

* ``Counter`` — monotonic count; ``interval()`` reports the delta since
  the previous superstep, ``snapshot()`` the cumulative total.
* ``Gauge`` — last-set value (both views report the current level).
* ``Histogram`` — bounded reservoir of observations; the interval view
  reports ``count``/``mean``/``p50``/``p90``/``max`` over the superstep's
  observations and resets. This is what promotes ``io_queue_depth`` from
  a single mean to real percentiles (ISSUE 6 satellite).

All instruments are thread-safe: the I/O-engine workers observe queue
depths and read latencies concurrently with the main loop reading the
interval snapshot.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional


def percentile(sorted_vals: List[float], f: float) -> float:
    """Nearest-rank percentile over an already-sorted list."""
    if not sorted_vals:
        return 0.0
    i = int(round(f * (len(sorted_vals) - 1)))
    return float(sorted_vals[i])


class Counter:
    __slots__ = ("_mu", "_total", "_mark")

    def __init__(self):
        self._mu = threading.Lock()
        self._total = 0.0
        self._mark = 0.0          # total at the last interval() call

    def inc(self, n: float = 1.0):
        with self._mu:
            self._total += n

    @property
    def value(self) -> float:
        return self._total

    def snapshot(self) -> float:
        return self._total

    def interval(self) -> float:
        with self._mu:
            delta, self._mark = self._total - self._mark, self._total
        return delta


class Gauge:
    __slots__ = ("_value",)

    def __init__(self):
        self._value = 0.0

    def set(self, v: float):
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> float:
        return self._value

    def interval(self) -> float:
        return self._value


class Histogram:
    """Reservoir of observations since the last interval. The reservoir
    is bounded (default 4096) so a pathological superstep cannot grow
    memory without bound; overflow keeps the first ``cap`` observations
    and still counts the rest."""

    __slots__ = ("_mu", "_vals", "_count", "_sum", "_max", "cap")

    def __init__(self, cap: int = 4096):
        self._mu = threading.Lock()
        self._vals: List[float] = []
        self._count = 0
        self._sum = 0.0
        self._max = 0.0
        self.cap = int(cap)

    def observe(self, v: float):
        v = float(v)
        with self._mu:
            self._count += 1
            self._sum += v
            if v > self._max:
                self._max = v
            if len(self._vals) < self.cap:
                self._vals.append(v)

    def snapshot(self) -> dict:
        with self._mu:
            vals = sorted(self._vals)
            return {
                "count": self._count,
                "mean": (self._sum / self._count) if self._count else 0.0,
                "p50": percentile(vals, 0.50),
                "p90": percentile(vals, 0.90),
                "max": self._max,
            }

    def interval(self) -> dict:
        out = self.snapshot()
        with self._mu:
            self._vals.clear()
            self._count = 0
            self._sum = 0.0
            self._max = 0.0
        return out


class MetricsRegistry:
    """Get-or-create registry of named instruments for one run."""

    def __init__(self):
        self._mu = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._mu:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._mu:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(self, name: str, cap: int = 4096) -> Histogram:
        with self._mu:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(cap)
            return h

    def _merge(self, view: str) -> dict:
        with self._mu:
            items = (list(self._counters.items())
                     + list(self._gauges.items())
                     + list(self._hists.items()))
        return {name: getattr(inst, view)() for name, inst in items}

    def snapshot(self) -> dict:
        """Non-destructive view: counter totals, gauge levels, histogram
        percentiles over the current (un-reset) interval."""
        return self._merge("snapshot")

    def interval(self) -> dict:
        """Per-superstep view: counter deltas, gauge levels, histogram
        percentiles since the previous call; resets interval state.
        Empty dict when no instrument was ever registered."""
        return self._merge("interval")
