"""Checkpointing + elastic recovery (paper Section 5.5).

Checkpoints store the GLOBAL relations (Vertex, Msg, GS) as npz (the HDFS
stand-in). Restore can re-partition onto a DIFFERENT partition count P'
(the paper's "newly selected set of failure-free worker machines"): vids
are re-hashed vid % P' and edges re-bucketed — this is what makes recovery
elastic after blacklisting failed nodes.

OUT-OF-CORE checkpoints (``save_ooc_checkpoint``) snapshot the disk tier
at the FILE level: the TieredStore's spill pages are hard-linked (the
atomic page write-back makes links immutable-safe) or kernel-copied into
the checkpoint directory instead of being re-serialized through DRAM —
a disk-resident job checkpoints without ever materializing its relations
in memory. ``run_out_of_core(resume_from=<dir>)`` restarts a job
directly from such a directory, faulting pages in on first touch.

VALIDITY: every checkpoint carries an atomic ``COMMIT`` manifest,
written LAST (npz checkpoints get a ``<name>.COMMIT`` sidecar, OOC
directories a ``COMMIT.json``), recording the snapshot's files with
sizes and checksums. A writer that dies mid-checkpoint leaves a
manifest-less partial that ``latest_checkpoint``/``latest_ooc_checkpoint``
skip — the ``LATEST`` markers are hints, never trusted over the
manifest — and ``verify_ooc_checkpoint`` walks the manifest plus the
per-page CRC trailers so the recovery supervisor can fail over from a
corrupt snapshot to the previous valid one. The gap between payload and
manifest is a chaos-harness site (``checkpoint.commit``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core.relations import (N_OVERFLOW, GlobalState, MsgRel,
                                  VertexRel)
from repro.obs import trace
from repro.storage.spillfile import page_checksum, verify_page_file

# the host-resident relations an OOC checkpoint carries (one spill page
# per super-partition each) plus the run-structured inbox chunks
OOC_RELATIONS = ("vid", "halt", "value", "edge_src", "edge_dst",
                 "edge_val")
OOC_INBOX = ("inbox_dst", "inbox_pay", "inbox_val")

OOC_COMMIT = "COMMIT.json"


class CheckpointCorruption(RuntimeError):
    """A checkpoint failed its manifest/CRC check. Recoverable: the
    supervisor fails over to the previous valid snapshot."""

    def __init__(self, path, detail: str):
        super().__init__(f"corrupt checkpoint {path}: {detail}")
        self.path = str(path)


def _faults():
    from repro.runtime import faults
    return faults


def _file_crc(path: Path) -> tuple:
    algo, crc = page_checksum(path.read_bytes())
    return algo, crc


def _write_commit(path: Path, doc: dict):
    """Atomic manifest publish (tmp + os.replace in the same dir)."""
    tmp = path.with_name(f".{path.name}.tmp")
    tmp.write_text(json.dumps(doc))
    os.replace(tmp, path)


def save_checkpoint(ckpt_dir: str, superstep: int, vert: VertexRel,
                    msg: MsgRel, gs: GlobalState) -> str:
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    path = d / f"ckpt_{superstep:06d}.npz"
    tmp = d / f".tmp_{superstep:06d}.npz"
    with trace.span("save_checkpoint", "checkpoint"):
        np.savez_compressed(
            tmp,
            vid=np.asarray(vert.vid), halt=np.asarray(vert.halt),
            value=np.asarray(vert.value),
            edge_src=np.asarray(vert.edge_src),
            edge_dst=np.asarray(vert.edge_dst),
            edge_val=np.asarray(vert.edge_val),
            m_dst=np.asarray(msg.dst), m_pay=np.asarray(msg.payload),
            m_val=np.asarray(msg.valid),
            gs_halt=np.asarray(gs.halt), gs_agg=np.asarray(gs.aggregate),
            gs_step=np.asarray(gs.superstep),
            gs_overflow=np.asarray(gs.overflow),
            gs_active=np.asarray(gs.active_count),
            gs_msgs=np.asarray(gs.msg_count))
        os.replace(tmp, path)  # atomic payload publish
        # the crash-mid-checkpoint window: payload visible, no manifest
        _faults().hit("checkpoint.commit", path.name)
        algo, crc = _file_crc(path)
        _write_commit(d / f"{path.name}.COMMIT",
                      {"superstep": int(superstep), "file": path.name,
                       "bytes": path.stat().st_size,
                       "crc_algo": algo, "crc": crc,
                       "saved_at": time.time()})
        (d / "LATEST").write_text(path.name)
    return str(path)


def save_ooc_checkpoint(ckpt_dir: str, superstep: int, store, gs, *,
                        inbox_gen: int, inbox_width: int,
                        sp: int, plan=None, ec=None,
                        controller_state=None) -> str:
    """Snapshot an out-of-core job at a superstep boundary. Pages move at
    the file level (hard-link for immutable inbox generations, kernel
    copy otherwise — no DRAM round-trip on the disk tier; every exported
    page carries its CRC trailer). The directory is written in place and
    COMMITTED by the atomic ``COMMIT.json`` manifest at the end — a
    writer that dies mid-export leaves a manifest-less partial that the
    checkpoint selectors skip."""
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    name = f"ooc_{superstep:06d}"
    tmp = d / name
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    with trace.span("export_pages", "checkpoint"):
        for nm in OOC_RELATIONS:
            for s in range(store.n_sp):
                store.export_page((nm, s), tmp / f"{nm}_{s}.npy")
        for nm in OOC_INBOX:
            for q in range(store.n_sp):
                store.export_page((nm, inbox_gen, q),
                                  tmp / f"{nm}_{q}.npy")
    np.savez(tmp / "gs.npz",
             halt=np.asarray(gs.halt), aggregate=np.asarray(gs.aggregate),
             superstep=np.asarray(gs.superstep),
             overflow=np.asarray(gs.overflow),
             active=np.asarray(gs.active_count),
             msgs=np.asarray(gs.msg_count))
    (tmp / "meta.json").write_text(json.dumps(
        {"format": 1, "superstep": int(superstep), "n_sp": store.n_sp,
         "sp": int(sp), "inbox_width": int(inbox_width),
         # the plan IN EFFECT — it produced the checkpointed inbox's run
         # layout, and resume restarts plan="auto" jobs from it instead
         # of re-choosing blind over a foreign inbox
         "plan": dataclasses.asdict(plan) if plan is not None else None,
         # the (possibly overflow-regrown) capacities, so a resume does
         # not replay the whole regrow cascade from the defaults
         "caps": ({"bucket_cap": ec.bucket_cap,
                   "frontier_cap": ec.frontier_cap,
                   "mutation_cap": ec.mutation_cap}
                  if ec is not None else None),
         # the AdaptiveController's hysteresis state (pending-switch
         # candidate / streak / cooldown clock), so a resume right
         # before a pending plan switch does not re-pay the patience
         # window from scratch
         "controller": controller_state,
         "saved_at": time.time()}))
    # the crash-mid-checkpoint window: pages + meta visible, no manifest
    _faults().hit("checkpoint.commit", name)
    files = {}
    crcs = {}
    for f in sorted(tmp.iterdir()):
        if f.name == OOC_COMMIT or f.name.startswith("."):
            continue
        files[f.name] = f.stat().st_size
        if f.suffix != ".npy":   # page files carry their own CRC trailer
            algo, crc = _file_crc(f)
            crcs[f.name] = [algo, crc]
    _write_commit(tmp / OOC_COMMIT,
                  {"superstep": int(superstep), "files": files,
                   "crcs": crcs, "saved_at": time.time()})
    (d / "LATEST_OOC").write_text(name)
    return str(tmp)


def verify_ooc_checkpoint(path, *, deep: bool = True) -> list:
    """Validity check against the COMMIT manifest: every listed file
    present with its recorded size, manifest'd CRCs matching, and (deep)
    every page file passing its embedded CRC trailer. Returns the list
    of violations — empty means the snapshot is safe to resume from."""
    p = Path(path)
    errs = []
    commit = p / OOC_COMMIT
    if not commit.exists():
        return [f"{p.name}: no {OOC_COMMIT} manifest (partial checkpoint)"]
    try:
        doc = json.loads(commit.read_text())
    except (OSError, ValueError) as e:
        return [f"{p.name}: unreadable manifest ({e})"]
    for name, size in doc.get("files", {}).items():
        f = p / name
        if not f.exists():
            errs.append(f"{p.name}/{name}: listed in manifest but missing")
            continue
        if f.stat().st_size != size:
            errs.append(f"{p.name}/{name}: size {f.stat().st_size} != "
                        f"manifest {size}")
            continue
        if name in doc.get("crcs", {}):
            algo, want = doc["crcs"][name]
            got_algo, got = _file_crc(f)
            if got_algo == algo and got != want:
                errs.append(f"{p.name}/{name}: CRC mismatch")
        elif deep and name.endswith(".npy"):
            if not verify_page_file(f):
                errs.append(f"{p.name}/{name}: page CRC trailer mismatch")
    return errs


def ooc_checkpoints(ckpt_dir: str) -> list:
    """COMMITTED checkpoint directories under ``ckpt_dir``, oldest
    first. Partials (no manifest) are never listed."""
    d = Path(ckpt_dir)
    if not d.is_dir():
        return []
    return sorted(str(p) for p in d.iterdir()
                  if p.is_dir() and p.name.startswith("ooc_")
                  and (p / OOC_COMMIT).exists())


def latest_ooc_checkpoint(ckpt_dir: str, *, skip=(), deep: bool = False):
    """Newest VALID out-of-core checkpoint: committed manifest, not in
    ``skip``, and (``deep=True``, the recovery path) passing full page
    CRC verification. The LATEST_OOC marker is only a hint — a partial
    or corrupt directory is never selected."""
    skip = {str(Path(s)) for s in skip}
    for p in reversed(ooc_checkpoints(ckpt_dir)):
        if str(Path(p)) in skip:
            continue
        if deep and verify_ooc_checkpoint(p, deep=True):
            continue
        return p
    return None


def load_ooc_meta(path: str):
    """Resolve an OOC checkpoint path (either a checkpoint directory or
    a parent directory of checkpoints) and load its metadata. Parent
    resolution only ever lands on a COMMITTED snapshot.
    Returns (meta dict, gs npz mapping, checkpoint Path)."""
    p = Path(path)
    if not (p / "meta.json").exists():
        cand = latest_ooc_checkpoint(p)
        if cand is None:
            raise FileNotFoundError(
                f"{path!r} is not an out-of-core checkpoint (no meta.json "
                "and no committed checkpoints inside)")
        p = Path(cand)
    elif not (p / OOC_COMMIT).exists():
        raise CheckpointCorruption(p, "no COMMIT manifest (partial)")
    meta = json.loads((p / "meta.json").read_text())
    gs = dict(np.load(p / "gs.npz"))
    return meta, gs, p


def checkpoints(ckpt_dir: str) -> list:
    """COMMITTED npz checkpoints under ``ckpt_dir``, oldest first."""
    d = Path(ckpt_dir)
    if not d.is_dir():
        return []
    return sorted(str(p) for p in d.iterdir()
                  if p.name.startswith("ckpt_") and p.suffix == ".npz"
                  and p.with_name(f"{p.name}.COMMIT").exists())


def latest_checkpoint(ckpt_dir: str, *, skip=(), verify: bool = False):
    """Newest VALID npz checkpoint (committed sidecar present; with
    ``verify=True`` the npz's CRC is recomputed against it). Partial or
    corrupt snapshots are never selected; LATEST is just a hint."""
    skip = {str(Path(s)) for s in skip}
    for p in reversed(checkpoints(ckpt_dir)):
        if str(Path(p)) in skip:
            continue
        if verify and _npz_commit_errors(Path(p)):
            continue
        return p
    return None


def _npz_commit_errors(path: Path) -> list:
    commit = path.with_name(f"{path.name}.COMMIT")
    if not commit.exists():
        return [f"{path.name}: no COMMIT sidecar (partial checkpoint)"]
    try:
        doc = json.loads(commit.read_text())
    except (OSError, ValueError) as e:
        return [f"{path.name}: unreadable COMMIT sidecar ({e})"]
    if path.stat().st_size != doc.get("bytes"):
        return [f"{path.name}: size != manifest"]
    algo, got = _file_crc(path)
    if algo == doc.get("crc_algo") and got != doc.get("crc"):
        return [f"{path.name}: CRC mismatch"]
    return []


def load_checkpoint(path: str):
    p = Path(path)
    if p.with_name(f"{p.name}.COMMIT").exists():
        errs = _npz_commit_errors(p)
        if errs:
            raise CheckpointCorruption(p, "; ".join(errs))
    z = dict(np.load(path))
    if z["gs_overflow"].ndim == 0:
        # pre-split checkpoint: one aggregated counter — restore it into
        # the bucket slot (the only source the old regrow could attribute)
        ovf = np.zeros((N_OVERFLOW,), np.int32)
        ovf[0] = int(z["gs_overflow"])
        z["gs_overflow"] = ovf
    vert = VertexRel(vid=jnp.asarray(z["vid"]),
                     halt=jnp.asarray(z["halt"]),
                     value=jnp.asarray(z["value"]),
                     edge_src=jnp.asarray(z["edge_src"]),
                     edge_dst=jnp.asarray(z["edge_dst"]),
                     edge_val=jnp.asarray(z["edge_val"]))
    msg = MsgRel(dst=jnp.asarray(z["m_dst"]),
                 payload=jnp.asarray(z["m_pay"]),
                 valid=jnp.asarray(z["m_val"]))
    gs = GlobalState(halt=jnp.asarray(z["gs_halt"]),
                     aggregate=jnp.asarray(z["gs_agg"]),
                     superstep=jnp.asarray(z["gs_step"]),
                     overflow=jnp.asarray(z["gs_overflow"]),
                     active_count=jnp.asarray(z["gs_active"]),
                     msg_count=jnp.asarray(z["gs_msgs"]))
    return vert, msg, gs


def repartition(vert: VertexRel, msg: MsgRel, new_P: int,
                capacity_factor: float = 1.3):
    """Elastic restore: re-hash the global relations onto P' partitions.
    (Step 1/2 of the paper's recovery: scan, partition, sort, bulk load.)"""
    old_P, Np, V = vert.value.shape
    vid = np.asarray(vert.vid).reshape(-1)
    ok = vid >= 0
    vids = vid[ok].astype(np.int64)
    halt = np.asarray(vert.halt).reshape(-1)[ok]
    value = np.asarray(vert.value).reshape(-1, V)[ok]
    n_max = int(vids.max()) + 1 if len(vids) else 1
    Np2 = int(np.ceil(n_max / new_P) * capacity_factor) + 1
    nv = np.full((new_P, Np2), -1, np.int32)
    nh = np.zeros((new_P, Np2), bool)
    nval = np.zeros((new_P, Np2, V), np.float32)
    p, s = vids % new_P, vids // new_P
    nv[p, s] = vids.astype(np.int32)
    nh[p, s] = halt
    nval[p, s] = value
    # edges: owner follows the (re-hashed) source vid
    e_src_slot = np.asarray(vert.edge_src)
    e_dst = np.asarray(vert.edge_dst)
    e_val = np.asarray(vert.edge_val)
    part_idx = np.repeat(np.arange(old_P), e_src_slot.shape[1]) \
        .reshape(e_src_slot.shape)
    ok_e = e_src_slot >= 0
    src_vid = (e_src_slot.astype(np.int64) * old_P + part_idx)[ok_e]
    dst = e_dst[ok_e].astype(np.int64)
    val = e_val[ok_e]
    owner = src_vid % new_P
    order = np.argsort(owner, kind="stable")
    src_vid, dst, val, owner = (src_vid[order], dst[order], val[order],
                                owner[order])
    counts = np.bincount(owner, minlength=new_P)
    Ep2 = int(max(counts.max(), 1))
    ns = np.full((new_P, Ep2), -1, np.int32)
    nd = np.full((new_P, Ep2), -1, np.int32)
    nev = np.zeros((new_P, Ep2), np.float32)
    start = 0
    for q in range(new_P):
        c = counts[q]
        ns[q, :c] = (src_vid[start:start + c] // new_P).astype(np.int32)
        nd[q, :c] = dst[start:start + c].astype(np.int32)
        nev[q, :c] = val[start:start + c]
        start += c
    new_vert = VertexRel(vid=jnp.asarray(nv), halt=jnp.asarray(nh),
                         value=jnp.asarray(nval), edge_src=jnp.asarray(ns),
                         edge_dst=jnp.asarray(nd), edge_val=jnp.asarray(nev))
    # messages: re-bucket by dst % P' (step 2 of recovery)
    m_dst = np.asarray(msg.dst).reshape(-1)
    m_pay = np.asarray(msg.payload).reshape(-1, msg.payload.shape[-1])
    m_ok = np.asarray(msg.valid).reshape(-1)
    dsts = m_dst[m_ok]
    pays = m_pay[m_ok]
    owner = dsts.astype(np.int64) % new_P
    counts = np.bincount(owner, minlength=new_P)
    M2 = int(max(counts.max(), 1) + 8)
    nmd = np.full((new_P, M2), -1, np.int32)
    nmp = np.zeros((new_P, M2, m_pay.shape[-1]), np.float32)
    nmv = np.zeros((new_P, M2), bool)
    order = np.argsort(owner, kind="stable")
    dsts, pays, owner = dsts[order], pays[order], owner[order]
    start = 0
    for q in range(new_P):
        c = counts[q]
        nmd[q, :c] = dsts[start:start + c]
        nmp[q, :c] = pays[start:start + c]
        nmv[q, :c] = True
        start += c
    new_msg = MsgRel(dst=jnp.asarray(nmd), payload=jnp.asarray(nmp),
                     valid=jnp.asarray(nmv))
    return new_vert, new_msg
