"""Deterministic chaos harness: seeded fault plans injected at named
runtime sites.

The chaos loop (paper Section 5.7 is about *recovering* from failures;
this module is how we *cause* them on demand): a ``FaultPlan`` is a
seeded list of ``FaultSpec``s, each naming an injection site
("spill.read", "checkpoint.commit", "superstep", ...), a fault kind
(transient/permanent I/O error, page corruption, latency spike, worker
failure) and firing rules (skip the first ``after`` hits, fire at most
``times`` times, per-hit probability ``p`` drawn from the plan's seeded
RNG). The storage and driver layers call the module-level hooks at
their sites; with no plan installed every hook is a near-free early
return, mirroring ``obs.trace``'s process-global start/stop idiom.

Sites wired through the runtime:

====================  =====================================================
site                  hook point
====================  =====================================================
``spill.read``        ``SpillSlot.load`` — before reading a page file
``spill.write``       ``SpillSlot.store`` — before writing a page file
``page.corrupt``      ``SpillSlot.store`` — flips bytes in the written
                      page so the CRC check catches it on fault-in
``pager.fault``       ``BufferPool`` fault-in (foreground + background)
``io.bg``             ``IOEngine`` worker loop, per background op
``checkpoint.commit`` both checkpoint savers, between payload export and
                      the COMMIT manifest (the crash-mid-checkpoint site)
``superstep``         driver loop top; ``kind="worker"`` raises
                      ``WorkerFailure(worker)`` at ``superstep == k``
``sharded.exchange``  ``run_sharded``'s all_to_all exchange stage
====================  =====================================================

Determinism: with ``p=1.0`` (the default) firing depends only on hit
counts, which the plan controls via ``after``/``times``; with ``p<1``
draws come from ``random.Random(plan.seed)``. Injector state is
process-global and survives recovery attempts, so a ``times=1`` fault
fires once and the replay passes — exactly the transient-failure model
the recovery supervisor is built for.

``REPRO_FAULT_PLAN`` (honored by ``pregel_run``) is either a path to a
plan JSON or the JSON itself (starts with ``{``).
"""
from __future__ import annotations

import json
import os
import random
import threading
import time
from dataclasses import asdict, dataclass, field
from typing import List, Optional

from repro.runtime.failure import WorkerFailure

SITES = ("spill.read", "spill.write", "page.corrupt", "pager.fault",
         "io.bg", "checkpoint.commit", "superstep", "sharded.exchange")
KINDS = ("transient", "permanent", "corrupt", "delay", "worker")

ENV_PLAN = "REPRO_FAULT_PLAN"


class InjectedFault(OSError):
    """A planned disk/I-O fault. Subclasses OSError so the retry ladder
    and the failure manager treat it exactly like a real EIO."""

    def __init__(self, site: str, tag: str, spec_index: int):
        super().__init__(f"injected fault at {site} ({tag or 'untagged'})")
        self.site = site
        self.tag = tag
        self.spec_index = spec_index


@dataclass
class FaultSpec:
    """One planned fault. ``match`` substring-filters the hit tag (page
    key / file path / driver name); ``after`` hits pass unharmed first;
    ``times`` caps firings (``0`` = unlimited, i.e. a permanent fault);
    ``p`` is the per-hit firing probability under the plan's seed."""
    site: str
    kind: str = "transient"
    times: int = 1
    after: int = 0
    p: float = 1.0
    match: str = ""
    superstep: int = -1        # kind="worker": fire when superstep == this
    worker: int = 0            # worker id carried by the WorkerFailure
    delay_s: float = 0.0       # kind="delay": injected latency

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"sites: {SITES}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"kinds: {KINDS}")


@dataclass
class FaultPlan:
    """A seeded, serializable chaos schedule."""
    faults: List[FaultSpec] = field(default_factory=list)
    seed: int = 0

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "faults": [asdict(f) for f in self.faults]})

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        doc = json.loads(text)
        return cls(seed=int(doc.get("seed", 0)),
                   faults=[FaultSpec(**f) for f in doc.get("faults", [])])


class FaultInjector:
    """Evaluates a FaultPlan at runtime. All counter state is behind a
    lock (the I/O engine hits sites from worker threads)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rng = random.Random(plan.seed)
        self._lock = threading.Lock()
        self._hits = [0] * len(plan.faults)    # matching hits per spec
        self._fired = [0] * len(plan.faults)   # injections per spec
        self.site_hits: dict = {}

    # -- firing decision ------------------------------------------------
    def _should_fire(self, idx: int, spec: FaultSpec) -> bool:
        """Caller holds the lock; the hit already matched site+tag."""
        self._hits[idx] += 1
        if self._hits[idx] <= spec.after:
            return False
        if spec.times > 0 and self._fired[idx] >= spec.times:
            return False
        if spec.p < 1.0 and self._rng.random() >= spec.p:
            return False
        self._fired[idx] += 1
        return True

    def _matching(self, site: str, tag: str):
        for idx, spec in enumerate(self.plan.faults):
            if spec.site == site and (not spec.match or spec.match in tag):
                yield idx, spec

    # -- hooks ----------------------------------------------------------
    def hit(self, site: str, tag: str = ""):
        """Error/latency hook: may sleep (kind=delay) and/or raise
        InjectedFault (kind=transient/permanent)."""
        delay = 0.0
        fire: Optional[int] = None
        with self._lock:
            self.site_hits[site] = self.site_hits.get(site, 0) + 1
            for idx, spec in self._matching(site, tag):
                if spec.kind not in ("transient", "permanent", "delay"):
                    continue
                if self._should_fire(idx, spec):
                    if spec.kind == "delay":
                        delay = max(delay, spec.delay_s)
                    elif fire is None:
                        fire = idx
        if delay > 0.0:
            time.sleep(delay)
        if fire is not None:
            raise InjectedFault(site, tag, fire)

    def corrupt(self, site: str, tag: str = "") -> bool:
        """Corruption hook: True tells the caller to damage the payload
        it just wrote (the CRC trailer was computed on the clean bytes,
        so verification on the next fault-in raises PageCorruption)."""
        with self._lock:
            self.site_hits[site] = self.site_hits.get(site, 0) + 1
            for idx, spec in self._matching(site, tag):
                if spec.kind == "corrupt" and self._should_fire(idx, spec):
                    return True
        return False

    def superstep_tick(self, superstep: int, driver: str = ""):
        """Driver-loop hook: raises WorkerFailure when a kind="worker"
        spec targets this superstep (and, via ``match``, this driver)."""
        fire: Optional[FaultSpec] = None
        with self._lock:
            self.site_hits["superstep"] = \
                self.site_hits.get("superstep", 0) + 1
            for idx, spec in self._matching("superstep", driver):
                if spec.kind != "worker" or spec.superstep != superstep:
                    continue
                if self._should_fire(idx, spec):
                    fire = spec
                    break
        if fire is not None:
            raise WorkerFailure(fire.worker,
                                f"injected at superstep {superstep}"
                                f" ({driver or 'any driver'})")

    # -- reporting ------------------------------------------------------
    def summary(self) -> dict:
        with self._lock:
            return {
                "seed": self.plan.seed,
                "site_hits": dict(self.site_hits),
                "specs": [{"site": s.site, "kind": s.kind,
                           "match": s.match, "hits": h, "fired": f}
                          for s, h, f in zip(self.plan.faults,
                                             self._hits, self._fired)],
            }


# -- process-global switch (the obs.trace idiom) ------------------------
_injector: Optional[FaultInjector] = None


def install(plan: FaultPlan) -> FaultInjector:
    """Arm the chaos harness for this process."""
    global _injector
    _injector = FaultInjector(plan)
    return _injector


def clear() -> Optional[FaultInjector]:
    """Disarm; returns the injector (for its summary())."""
    global _injector
    inj, _injector = _injector, None
    return inj


def get() -> Optional[FaultInjector]:
    return _injector


def enabled() -> bool:
    return _injector is not None


def install_from_env() -> Optional[FaultInjector]:
    """Arm from ``REPRO_FAULT_PLAN`` — inline JSON or a path to it."""
    raw = os.environ.get(ENV_PLAN)
    if not raw:
        return None
    text = raw if raw.lstrip().startswith("{") else \
        open(raw, encoding="utf-8").read()
    return install(FaultPlan.from_json(text))


# Module-level hooks: near-free when no plan is installed (one global
# load + None check), so they sit on the storage hot paths safely.
def hit(site: str, tag: str = ""):
    if _injector is not None:
        _injector.hit(site, tag)


def corrupt(site: str, tag: str = "") -> bool:
    if _injector is not None:
        return _injector.corrupt(site, tag)
    return False


def superstep_tick(superstep: int, driver: str = ""):
    if _injector is not None:
        _injector.superstep_tick(superstep, driver)


def summary() -> Optional[dict]:
    return _injector.summary() if _injector is not None else None
