"""Failure manager (paper Section 5.7): analyzes failures, blacklists
machines, recovers recoverable errors from the latest checkpoint onto the
surviving partitions; application errors are forwarded to the user.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Optional


class WorkerFailure(RuntimeError):
    """Infrastructure failure (machine power-off / disk IO) — recoverable."""

    def __init__(self, worker: int, msg: str = ""):
        super().__init__(f"worker {worker} failed: {msg}")
        self.worker = worker


def _corruption_types():
    # typed corruption is infrastructure damage (recoverable via a
    # checkpoint restore); lazy import keeps runtime <-> storage acyclic
    from repro.runtime.checkpoint import CheckpointCorruption
    from repro.storage.spillfile import PageCorruption
    return PageCorruption, CheckpointCorruption


@dataclass
class FailureManager:
    n_workers: int
    blacklist: set = field(default_factory=set)
    events: list = field(default_factory=list)
    max_retries: int = 3
    failure_counts: dict = field(default_factory=dict)

    def healthy_workers(self) -> int:
        return self.n_workers - len(self.blacklist)

    def record(self, exc: Exception, worker=None) -> bool:
        """-> True if recoverable (infrastructure), False for application
        errors (forwarded to the user, as in the paper).

        A ``WorkerFailure`` blacklists its worker immediately; any OTHER
        recoverable failure attributable to a worker (the ``worker``
        kwarg, e.g. the sharded driver naming the worker whose store
        faulted) counts against it, and a repeat offender is blacklisted
        after ``max_retries`` recoverable failures — a machine with a
        sick disk must not get an infinite benefit of the doubt."""
        recoverable = isinstance(
            exc, (WorkerFailure, OSError, IOError) + _corruption_types())
        if isinstance(exc, WorkerFailure):
            worker = exc.worker
        self.events.append({"time": time.time(), "error": repr(exc),
                            "recoverable": recoverable, "worker": worker})
        if recoverable and worker is not None:
            self.failure_counts[worker] = \
                self.failure_counts.get(worker, 0) + 1
            if isinstance(exc, WorkerFailure) \
                    or self.failure_counts[worker] >= self.max_retries:
                self.blacklist.add(worker)
        return recoverable

    def run_with_recovery(self, run_fn, restore_fn):
        """run_fn(n_workers) -> result; restore_fn(n_workers) re-shards the
        latest checkpoint onto the surviving workers and returns fresh
        state for run_fn."""
        attempt = 0
        while True:
            try:
                return run_fn(self.healthy_workers())
            except Exception as exc:  # noqa: BLE001
                if not self.record(exc) or attempt >= self.max_retries:
                    raise
                attempt += 1
                if self.healthy_workers() < 1:
                    raise RuntimeError("no healthy workers left") from exc
                restore_fn(self.healthy_workers())


def supervised_run(run_attempt, pick_checkpoint, *, n_workers: int,
                   max_retries: int = 3, initial_resume=None):
    """The drivers' shared recovery supervisor (each driver's
    ``recover=True`` path lands here, on ``run_with_recovery``).

    ``run_attempt(healthy_workers, resume_from)`` runs the job once;
    ``pick_checkpoint(bad)`` returns the newest VALID checkpoint not in
    ``bad`` (or None — restart from the initial relations). On a
    recoverable failure the supervisor re-picks, excluding any snapshot
    whose restore raised typed corruption (the fail-over-to-previous
    rule), and replays; every recovery event is prepended to the final
    ``RunResult.recovery`` so the run report can show the story."""
    corruption = _corruption_types()
    fm = FailureManager(n_workers=n_workers, max_retries=max_retries)
    state = {"resume": initial_resume, "bad": set(), "events": []}

    def attempt(healthy):
        try:
            res = run_attempt(healthy, state["resume"])
        except corruption:
            if state["resume"] is not None:
                # a restore that surfaced corruption taints its snapshot:
                # never select it again, fail over to the previous one
                state["bad"].add(str(state["resume"]))
            raise
        if state["events"]:
            res.recovery[:0] = state["events"]
        return res

    def restore(healthy):
        ck = pick_checkpoint(state["bad"])
        state["resume"] = ck
        state["events"].append({
            "event": "recovery",
            "attempt": len(state["events"]) + 1,
            "error": fm.events[-1]["error"] if fm.events else None,
            "recoverable": True,
            "restored_from": ck,
            "healthy_workers": healthy,
            "blacklist": sorted(fm.blacklist),
            "time": time.time()})

    return fm.run_with_recovery(attempt, restore)


@dataclass
class StragglerMonitor:
    """Per-superstep straggler detection from the statistics collector's
    wall times: flags partitions (BSP steps) slower than k x median."""
    threshold: float = 2.0
    history: list = field(default_factory=list)

    def observe(self, superstep: int, wall_s: float):
        self.history.append(wall_s)
        if len(self.history) < 5:
            return None
        import statistics
        med = statistics.median(self.history[:-1])
        if wall_s > self.threshold * med:
            return {"superstep": superstep, "wall_s": wall_s,
                    "median_s": med, "action": "flag-straggler"}
        return None
