"""Failure manager (paper Section 5.7): analyzes failures, blacklists
machines, recovers recoverable errors from the latest checkpoint onto the
surviving partitions; application errors are forwarded to the user.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Optional


class WorkerFailure(RuntimeError):
    """Infrastructure failure (machine power-off / disk IO) — recoverable."""

    def __init__(self, worker: int, msg: str = ""):
        super().__init__(f"worker {worker} failed: {msg}")
        self.worker = worker


@dataclass
class FailureManager:
    n_workers: int
    blacklist: set = field(default_factory=set)
    events: list = field(default_factory=list)
    max_retries: int = 3

    def healthy_workers(self) -> int:
        return self.n_workers - len(self.blacklist)

    def record(self, exc: Exception) -> bool:
        """-> True if recoverable (infrastructure), False for application
        errors (forwarded to the user, as in the paper)."""
        recoverable = isinstance(exc, (WorkerFailure, OSError, IOError))
        self.events.append({"time": time.time(), "error": repr(exc),
                            "recoverable": recoverable})
        if isinstance(exc, WorkerFailure):
            self.blacklist.add(exc.worker)
        return recoverable

    def run_with_recovery(self, run_fn, restore_fn):
        """run_fn(n_workers) -> result; restore_fn(n_workers) re-shards the
        latest checkpoint onto the surviving workers and returns fresh
        state for run_fn."""
        attempt = 0
        while True:
            try:
                return run_fn(self.healthy_workers())
            except Exception as exc:  # noqa: BLE001
                if not self.record(exc) or attempt >= self.max_retries:
                    raise
                attempt += 1
                if self.healthy_workers() < 1:
                    raise RuntimeError("no healthy workers left") from exc
                restore_fn(self.healthy_workers())


@dataclass
class StragglerMonitor:
    """Per-superstep straggler detection from the statistics collector's
    wall times: flags partitions (BSP steps) slower than k x median."""
    threshold: float = 2.0
    history: list = field(default_factory=list)

    def observe(self, superstep: int, wall_s: float):
        self.history.append(wall_s)
        if len(self.history) < 5:
            return None
        import statistics
        med = statistics.median(self.history[:-1])
        if wall_s > self.threshold * med:
            return {"superstep": superstep, "wall_s": wall_s,
                    "median_s": med, "action": "flag-straggler"}
        return None
