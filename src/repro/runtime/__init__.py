from repro.runtime.checkpoint import (CheckpointCorruption,
                                      latest_checkpoint,
                                      latest_ooc_checkpoint,
                                      load_checkpoint, repartition,
                                      save_checkpoint,
                                      verify_ooc_checkpoint)
from repro.runtime.failure import (FailureManager, StragglerMonitor,
                                   WorkerFailure)
from repro.runtime.faults import (FaultInjector, FaultPlan, FaultSpec,
                                  InjectedFault)

__all__ = ["latest_checkpoint", "latest_ooc_checkpoint", "load_checkpoint",
           "repartition", "save_checkpoint", "verify_ooc_checkpoint",
           "CheckpointCorruption", "FailureManager", "StragglerMonitor",
           "WorkerFailure", "FaultInjector", "FaultPlan", "FaultSpec",
           "InjectedFault"]
