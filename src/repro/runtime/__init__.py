from repro.runtime.checkpoint import (latest_checkpoint, load_checkpoint,
                                      repartition, save_checkpoint)
from repro.runtime.failure import (FailureManager, StragglerMonitor,
                                   WorkerFailure)

__all__ = ["latest_checkpoint", "load_checkpoint", "repartition",
           "save_checkpoint", "FailureManager", "StragglerMonitor",
           "WorkerFailure"]
