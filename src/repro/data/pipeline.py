"""Deterministic synthetic token pipeline (the data substrate).

Produces an infinite, seeded stream of packed LM batches, sharded by
data-parallel host: each host materializes only its shard (production
pattern), with a skewed unigram distribution plus Markov structure so the
loss actually decreases during the example runs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class TokenStream:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # skewed unigram + sparse bigram structure (learnable signal)
        self.unigram = rng.dirichlet(np.full(min(v, 4096), 0.1))
        self.hot = rng.integers(0, v, size=(min(v, 4096),))
        self.step = 0

    def next_batch(self) -> dict:
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed, self.step, c.host_id, 7919))
        self.step += 1
        idx = rng.choice(len(self.unigram), p=self.unigram,
                         size=(self.local_batch, c.seq_len))
        toks = self.hot[idx]
        # Markov smoothing: each token sometimes repeats its predecessor
        rep = rng.random((self.local_batch, c.seq_len)) < 0.3
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        tokens = toks.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((self.local_batch, 1), -1, np.int32)],
            axis=1)
        return {"tokens": tokens, "labels": labels}

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state["step"])
