"""Quickstart: single-source shortest paths on a synthetic web graph,
using the public Pregelix-on-JAX API (mirrors the paper's Figure 9
ShortestPathsVertex, including the physical plan hints)."""
import numpy as np

from repro.core import PhysicalPlan, gather_values, load_graph, run_host
from repro.graph import SSSP, rmat_graph

N = 5_000
edges = rmat_graph(N, 10 * N, seed=0)

# the paper's Figure 9 hints: LEFT-OUTER join + hash group-by + unmerged
# connector for the message-sparse SSSP
plan = PhysicalPlan(join="left_outer", groupby="scatter",
                    connector="partitioning", sender_combine=True)

vert = load_graph(edges, N, P=4, value_dims=1)
res = run_host(vert, SSSP(source=0), plan, max_supersteps=40)

dist = gather_values(res.vertex, N)[:, 0]
reached = dist < 1e37
print(f"supersteps: {res.supersteps}, wall: {res.wall_s:.2f}s")
print(f"reached {reached.sum()} / {N} vertices")
print(f"max finite distance: {dist[reached].max():.0f}")
print("per-superstep active counts:",
      [s["active"] for s in res.stats if "active" in s])
