"""End-to-end LM training driver: trains a reduced h2o-danube-3-4b config
for a few hundred steps on the synthetic pipeline and checks that the loss
drops. ``--arch``/``--steps`` select other assigned architectures.

(For the real 100M+ scale run use:
  python -m repro.launch.train --arch <id> --preset smoke --steps 300)
"""
import argparse

from repro.launch.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="h2o-danube-3-4b")
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

_, hist = train(args.arch, steps=args.steps, preset="smoke",
                global_batch=8, seq_len=128, log_every=20)
first, last = hist[0][1], hist[-1][1]
assert last < first, f"loss did not improve: {first} -> {last}"
print(f"OK: loss improved {first:.4f} -> {last:.4f} over "
      f"{args.steps} steps")
