"""End-to-end graph analytics driver (the paper's kind of workload):
PageRank on the Webmap stand-in with checkpointing, statistics collection,
and a post-hoc top-k report. Also demonstrates recovery: the run is
resumed from its own checkpoint onto a DIFFERENT partition count."""
import tempfile

import numpy as np

from repro.core import gather_values, load_graph, run_host
from repro.graph import DATASETS, PageRank
from repro.runtime import latest_checkpoint, load_checkpoint, repartition

edges, n = DATASETS["webmap-tiny"]()
pr = PageRank(n, iterations=12)
vert = load_graph(edges, n, P=4, value_dims=2)

with tempfile.TemporaryDirectory() as ckpt:
    res = run_host(vert, pr, pr.suggested_plan, max_supersteps=14,
                   checkpoint_every=5, checkpoint_dir=ckpt)
    ranks = gather_values(res.vertex, n)[:, 0]
    top = np.argsort(-ranks)[:5]
    print(f"PageRank on webmap-tiny ({n} vertices, {len(edges)} edges)")
    print(f"supersteps={res.supersteps} wall={res.wall_s:.2f}s")
    print("top-5:", [(int(v), round(float(ranks[v]), 6)) for v in top])

    # elastic recovery drill: reload the latest checkpoint onto 3 workers
    v, m, gs = load_checkpoint(latest_checkpoint(ckpt))
    v3, m3 = repartition(v, m, new_P=3)
    print(f"recovered checkpoint at superstep {int(gs.superstep)} "
          f"onto P=3 partitions: {v3.vid.shape}")
