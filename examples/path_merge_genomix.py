"""Genomix-style graph mutation demo (paper Section 6, genome assembly):
iterative chain compaction with vertex deletion, the resolve UDF, and the
message-resurrection semantics of the full-outer join. Uses the delta
(LSM-analogue) storage plan the paper recommends for mutation-heavy jobs."""
import numpy as np

from repro.core import load_graph, run_host
from repro.graph import PathMerge, chain_graph

n = 200
edges = chain_graph(n)  # a simple path, like a resolved genome contig
pm = PathMerge(rounds=16)
vert = load_graph(edges, n, P=4, value_dims=2)
res = run_host(vert, pm, pm.suggested_plan, max_supersteps=18)

vid = np.asarray(res.vertex.vid).reshape(-1)
vals = np.asarray(res.vertex.value).reshape(-1, 2)
alive = vid >= 0
acc = vals[alive, 0]
print(f"chain of {n} vertices compacted to {alive.sum()} "
      f"in {res.supersteps} supersteps")
print(f"accumulated length mass conserved: {acc.sum():.0f} == {n}")
assert np.isclose(acc.sum(), n)
